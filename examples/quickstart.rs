//! Quickstart: train a tiny LM with and without DropCompute in a noisy
//! simulated cluster, and compare loss-at-equal-virtual-time.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use dropcompute::config::{Config, NoiseKind, ThresholdPolicy};
use dropcompute::report::{f, pct, Table};
use dropcompute::train::Trainer;

fn base_config() -> Config {
    let mut cfg = Config::default();
    cfg.train.model_size = "tiny".into();
    cfg.train.steps = 40;
    cfg.train.lr = 2e-3;
    cfg.train.log_every = 10;
    cfg.cluster.workers = 8;
    cfg.cluster.accumulations = 8;
    // the paper's simulated-delay environment (App. B.1)
    cfg.cluster.noise = NoiseKind::PaperLogNormal {
        mu: 4.0,
        sigma: 1.0,
        alpha: 2.0 * (4.5f64).exp(),
        beta: 5.5,
    };
    cfg
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut baseline_cfg = base_config();
    baseline_cfg.dropcompute.policy = ThresholdPolicy::Off;
    let mut dc_cfg = base_config();
    dc_cfg.dropcompute.policy = ThresholdPolicy::Auto;

    println!("== baseline synchronous training ==");
    let base_log = Trainer::new(&baseline_cfg)?.train()?;
    println!("\n== DropCompute (Algorithm 2 auto threshold) ==");
    let mut dc_trainer = Trainer::new(&dc_cfg)?;
    let dc_log = dc_trainer.train()?;

    let mut t = Table::new(
        "quickstart: tiny LM, 8 workers, simulated delay",
        &["run", "final loss", "drop", "virtual time", "mb/s"],
    );
    for (name, log) in [("baseline", &base_log), ("DropCompute", &dc_log)] {
        t.row(vec![
            name.into(),
            f(log.final_loss(), 4),
            pct(log.mean_drop_rate()),
            f(log.total_virtual_time(), 1),
            f(log.throughput(), 2),
        ]);
    }
    t.print();
    println!(
        "time saved: {:.1}%  (tau* = {:.2}s, predicted speedup {:.3})",
        100.0 * (1.0 - dc_log.total_virtual_time() / base_log.total_virtual_time()),
        dc_trainer.threshold.unwrap_or(f64::NAN),
        dc_trainer
            .calibration
            .as_ref()
            .map(|c| c.speedup)
            .unwrap_or(f64::NAN)
    );
    Ok(())
}
