//! Algorithm 2 walkthrough: record a latency trace, synchronize the
//! empirical distribution across workers with a *real* ring AllGather
//! (one thread per worker), and let every worker independently compute
//! the same `tau*` — then show the analytical model's agreement.
//!
//! ```sh
//! cargo run --release --example threshold_tuning
//! ```

use dropcompute::analysis::{choose_threshold, Setting};
use dropcompute::config::{ClusterConfig, NoiseKind};
use dropcompute::coordinator::decentralized_calibration;
use dropcompute::report::{f, pct, Table};
use dropcompute::sim::{ClusterSim, LatencyModel};

fn main() {
    let cfg = ClusterConfig {
        workers: 16,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        comm_latency: 0.5,
        noise: NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        },
        ..Default::default()
    };

    // 1. measure I calibration iterations (no drops)
    let mut sim = ClusterSim::new(&cfg, 42);
    let trace = sim.record_trace(20);
    let (mu, var) = trace.microbatch_moments();
    println!(
        "measured micro-batch latency: mean {mu:.3}s var {var:.4} over {} samples",
        trace.all_samples().len()
    );

    // 2. decentralized: one thread per worker, ring AllGather, local argmax
    let choices = decentralized_calibration(&trace, 256);
    let tau0 = choices[0].tau;
    let consensus =
        choices.iter().all(|c| c.tau.to_bits() == tau0.to_bits());
    println!(
        "decentralized consensus across {} workers: {} (tau* = {tau0:.3}s)",
        choices.len(),
        if consensus { "YES" } else { "NO (bug!)" }
    );

    // 3. the sweep (Fig 3c): effective speedup / completion / step speedup
    let central = choose_threshold(&trace, 256);
    let mut t = Table::new(
        "Fig 3c — S_eff(tau) trade-off",
        &["tau", "S_eff", "completion", "step speedup"],
    );
    for p in central.sweep.iter().step_by(central.sweep.len() / 14) {
        t.row(vec![
            f(p.tau, 2),
            f(p.effective_speedup, 4),
            pct(p.completion_rate),
            f(p.step_speedup, 4),
        ]);
    }
    t.print();

    // 4. analytical model (Eq. 5 + Eq. 4) vs the empirical choice
    let model = LatencyModel::from_config(&cfg);
    let s = Setting {
        workers: cfg.workers,
        accums: cfg.accumulations,
        mu: model.mean(),
        sigma2: model.variance(),
        comm: cfg.comm_latency,
    };
    let (tau_analytic, s_analytic) = s.optimal_threshold(512);
    println!(
        "empirical  tau* {:.3}  S_eff {:.4}\nanalytical tau* {:.3}  S_eff {:.4} \
         (Gaussian E[T]; see Fig 3b for why heavy tails shift this)",
        central.tau, central.speedup, tau_analytic, s_analytic
    );
}
