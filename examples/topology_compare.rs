//! Compare collective topologies three ways:
//!
//!  1. schedule shape (phases, messages, closed-form uniform cost);
//!  2. virtual time under a straggling arrival pattern — with and
//!     without the bounded-wait DropComm membership rule;
//!  3. real execution over OS threads: every topology's schedule is run
//!     on the mpsc mesh and checked (bitwise) against the hand-written
//!     ring collective.
//!
//! ```sh
//! cargo run --release --example topology_compare
//! ```

use std::thread;

use dropcompute::collective::{
    ring_all_reduce, topology_all_reduce, Communicator, MeshComm,
};
use dropcompute::report::{f, Table};
use dropcompute::sim::CommModel;
use dropcompute::topology::TopologyKind;

const N: usize = 16;
const LAT: f64 = 25e-6; // 25us per hop
const BW: f64 = 12.5e9; // 100 Gb/s links
const BYTES: f64 = 4.0 * 33.7e6; // `large` model fp32 gradient

fn main() {
    println!("== collective topologies at N={N} ==\n");

    // 1 + 2: schedule shape and event-driven timing, step-level and
    // per-phase DropComm (the `deadline=` / `phase-deadline=` policy
    // clauses) side by side.
    let mut arrivals = vec![0.0f64; N];
    arrivals[5] = 2.0; // one worker 2s late
    let phase_offsets =
        dropcompute::policy::cumulative_offsets(&[0.5, 0.05, 0.05]);
    let mut t = Table::new(
        "schedules and timing (one worker 2s late, deadline 0.5s)",
        &["topology", "phases", "msgs", "uniform T^c", "straggled",
          "DropComm", "dropped", "per-phase", "dropped"],
    );
    for kind in TopologyKind::ALL {
        let sched = kind.build(N);
        let model = CommModel::Topology {
            kind,
            latency: LAT,
            bandwidth: BW,
            bytes: BYTES,
        };
        let uniform = model.serial_latency(N);
        let straggled = model.completion_time(&arrivals);
        let (survivors, bounded) =
            model.bounded_wait_completion(&arrivals, 0.5);
        let dropped = survivors.iter().filter(|&&s| !s).count();
        let (pp_survivors, per_phase) = model.per_phase_bounded_completion(
            &arrivals,
            &phase_offsets,
            Some(&sched),
        );
        let pp_dropped = pp_survivors.iter().filter(|&&s| !s).count();
        t.row(vec![
            kind.name().to_string(),
            sched.phase_count().to_string(),
            sched.transfer_count().to_string(),
            f(uniform, 4),
            f(straggled, 4),
            f(bounded, 4),
            dropped.to_string(),
            f(per_phase, 4),
            pp_dropped.to_string(),
        ]);
    }
    t.print();
    println!(
        "the straggler adds its full 2s to every synchronous collective;\n\
         the bounded wait sheds it once the 0.5s membership deadline\n\
         passes, and the per-phase budgets (0.5/0.05/0.05 — the\n\
         `phase-deadline=` policy clause) additionally police the first\n\
         phases of the collective itself.\n"
    );

    // 3: execute each topology's schedule on real threads and check it
    // against the ring collective (integer payloads: exact sums, so all
    // associations agree bitwise).
    let len = 1000;
    let input = move |rank: usize| -> Vec<f32> {
        (0..len).map(|i| ((rank + 1) * (i % 17 + 1)) as f32).collect()
    };
    let ring_ref: Vec<Vec<f32>> = {
        let comms = Communicator::ring(N);
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                thread::spawn(move || {
                    let mut buf = input(rank);
                    ring_all_reduce(&comm, &mut buf);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };
    for kind in TopologyKind::ALL {
        let comms = MeshComm::<f32>::full(N);
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                thread::spawn(move || {
                    let mut buf = input(rank);
                    topology_all_reduce(&comm, kind, &mut buf);
                    buf
                })
            })
            .collect();
        let got: Vec<Vec<f32>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got, ring_ref, "{} disagrees with ring", kind.name());
        println!(
            "{:<13} thread-mesh execution matches ring_all_reduce \
             bitwise on {}x{} f32",
            kind.name(),
            N,
            len
        );
    }
    println!("\nall topologies agree with the ring collective.");
}
