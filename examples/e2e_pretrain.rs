//! END-TO-END DRIVER: pretrain a transformer LM through the full stack
//! (Rust coordinator -> PJRT -> JAX-lowered HLO -> Pallas kernels) on
//! the synthetic Zipf–Markov corpus, in the paper's simulated-delay
//! environment, baseline vs DropCompute — the Fig 5 experiment.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example e2e_pretrain -- \
//!     [--size base] [--steps 300] [--workers 16] [--out runs/e2e]
//! ```
//!
//! Defaults train the `small` model (~1.1M params; pass `--size base`/`large`
//! for the 6.9M/33.7M-param configs or `--size xl` for 110M) for 200 steps and
//! report the loss curve in both steps and virtual time. Results are
//! recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use dropcompute::cli::Spec;
use dropcompute::config::{Config, NoiseKind, ThresholdPolicy};
use dropcompute::report::{f, pct, Table};
use dropcompute::train::Trainer;
use dropcompute::util::Stopwatch;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Spec::new()
        .value_keys(&["size", "steps", "workers", "accums", "out", "seed"])
        .parse(std::env::args().skip(1))?;
    let size = args.str_or("size", "small");
    let steps = args.usize_or("steps", 200)?;
    let workers = args.usize_or("workers", 8)?;
    let accums = args.usize_or("accums", 4)?;

    let mut cfg = Config::default();
    cfg.train.model_size = size.clone();
    cfg.train.steps = steps;
    cfg.train.lr = 1.5e-3;
    cfg.train.seed = args.u64_or("seed", 0)?;
    cfg.train.log_every = 20;
    cfg.cluster.workers = workers;
    cfg.cluster.accumulations = accums;
    cfg.cluster.comm_latency = 0.35;
    cfg.cluster.noise = NoiseKind::PaperLogNormal {
        mu: 4.0,
        sigma: 1.0,
        alpha: 2.0 * (4.5f64).exp(),
        beta: 5.5,
    };

    println!(
        "e2e pretrain: size={size} N={workers} M={accums} steps={steps}"
    );
    let sw = Stopwatch::start();

    let mut base_cfg = cfg.clone();
    base_cfg.dropcompute.policy = ThresholdPolicy::Off;
    let mut base = Trainer::new(&base_cfg)?;
    println!(
        "model: {} params, {:.1} MFLOP/microbatch",
        base.runtime.manifest.param_count,
        base.runtime.manifest.flops_per_microbatch / 1e6
    );
    let base_log = base.train()?;

    let mut dc_cfg = cfg.clone();
    dc_cfg.dropcompute.policy = ThresholdPolicy::Auto;
    let mut dc = Trainer::new(&dc_cfg)?;
    let dc_log = dc.train()?;

    // Loss-vs-steps and loss-vs-virtual-time tables (Fig 5 left/right).
    let mut t = Table::new(
        "Fig 5 — loss curve (steps and virtual time)",
        &["step", "base loss", "base t(s)", "dc loss", "dc t(s)"],
    );
    let stride = (steps / 12).max(1);
    for i in (0..steps).step_by(stride) {
        t.row(vec![
            i.to_string(),
            f(base_log.steps[i].loss, 4),
            f(base_log.steps[i].virtual_time, 0),
            f(dc_log.steps[i].loss, 4),
            f(dc_log.steps[i].virtual_time, 0),
        ]);
    }
    t.print();

    // Headline: time to reach the baseline's final loss.
    let target = base_log.final_loss();
    let dc_hit = dc_log
        .steps
        .iter()
        .find(|s| s.loss <= target)
        .map(|s| (s.step, s.virtual_time));
    let mut s = Table::new("summary", &["metric", "baseline", "DropCompute"]);
    s.row(vec![
        "final loss".into(),
        f(base_log.final_loss(), 4),
        f(dc_log.final_loss(), 4),
    ]);
    s.row(vec![
        "eval loss".into(),
        f(base_log.summary["final_eval_loss"], 4),
        f(dc_log.summary["final_eval_loss"], 4),
    ]);
    s.row(vec![
        "drop rate".into(),
        pct(base_log.mean_drop_rate()),
        pct(dc_log.mean_drop_rate()),
    ]);
    s.row(vec![
        "virtual time (s)".into(),
        f(base_log.total_virtual_time(), 0),
        f(dc_log.total_virtual_time(), 0),
    ]);
    s.row(vec![
        "throughput (mb/s)".into(),
        f(base_log.throughput(), 2),
        f(dc_log.throughput(), 2),
    ]);
    s.print();
    match dc_hit {
        Some((step, vt)) => println!(
            "DropCompute reached baseline final loss {target:.4} at step {step} \
             / {vt:.0}s virtual ({:+.1}% steps, {:.1}% less time)",
            100.0 * (step as f64 / steps as f64 - 1.0),
            100.0 * (1.0 - vt / base_log.total_virtual_time()),
        ),
        None => println!(
            "DropCompute did not reach baseline loss within {steps} steps \
             (final {:.4} vs {target:.4}) — increase --steps",
            dc_log.final_loss()
        ),
    }
    println!("wall-clock: {:.1}s", sw.seconds());

    if let Some(out) = args.get("out") {
        let dir = PathBuf::from(out);
        base_log.write_csv(&dir.join("baseline.csv"))?;
        dc_log.write_csv(&dir.join("dropcompute.csv"))?;
        println!("wrote {}/{{baseline,dropcompute}}.csv", dir.display());
    }
    Ok(())
}
