//! Local-SGD + DropCompute (App. B.3): real training with periodic
//! parameter averaging under straggler injection, comparing plain
//! Local-SGD against Local-SGD + DropCompute.
//!
//! ```sh
//! make artifacts && cargo run --release --example local_sgd
//! ```

use dropcompute::config::{Config, StragglerKind};
use dropcompute::report::{f, pct, Table};
use dropcompute::train::LocalSgdTrainer;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = Config::default();
    cfg.train.model_size = "tiny".into();
    cfg.train.lr = 2e-3;
    cfg.train.local_sgd_period = 4;
    cfg.cluster.workers = 6;
    cfg.cluster.accumulations = 1;
    cfg.cluster.microbatch_mean = 0.45;
    cfg.cluster.comm_latency = 0.5;
    // Fig 12's setting: workers straggle randomly, 1s penalty.
    cfg.cluster.stragglers = StragglerKind::Uniform { p: 0.2, delay: 1.0 };

    let periods = 20;
    let mut t = Table::new(
        "Local-SGD (H=4) under uniform stragglers",
        &["run", "final loss", "drop", "virtual time (s)", "speed vs plain"],
    );
    let plain_log = LocalSgdTrainer::new(&cfg, None)?.train(periods)?;
    // threshold slightly above the nominal microbatch time drops
    // straggling local steps
    let dc_log = LocalSgdTrainer::new(&cfg, Some(0.9))?.train(periods)?;
    for (name, log) in [("local-sgd", &plain_log), ("+DropCompute", &dc_log)] {
        t.row(vec![
            name.into(),
            f(log.final_loss(), 4),
            pct(log.mean_drop_rate()),
            f(log.total_virtual_time(), 1),
            f(
                plain_log.total_virtual_time() / log.total_virtual_time(),
                3,
            ),
        ]);
    }
    t.print();
    Ok(())
}
