//! Drop policies in one sweep (timing-only): the unified `DropPolicy`
//! surface expresses every drop decision — the paper's compute
//! threshold, step-level DropComm, OptiReduce-style per-phase
//! deadlines, Local-SGD periods and compositions — as one sweep axis,
//! here compared on a straggler-heavy torus cluster.
//!
//! ```sh
//! cargo run --release --example drop_policies -- \
//!     [--workers 24] [--iters 60] [--policy SPEC]...
//! ```
//!
//! Pass repeated `--policy` specs (e.g. `tau=9`,
//! `phase-deadline=3/0.5/0.5`, `tau=9+deadline=3`) to replace the
//! default axis.

use dropcompute::cli::Spec;
use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::policy::DropPolicy;
use dropcompute::report::{f, pct, Table};
use dropcompute::sweep::SweepSpec;
use dropcompute::topology::TopologyKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Spec::new()
        .value_keys(&["workers", "iters", "policy"])
        .parse(std::env::args().skip(1))?;
    let workers = args.usize_or("workers", 24)?;
    let iters = args.usize_or("iters", 60)?;
    let specs = args.get_all("policy");
    let policies: Vec<DropPolicy> = if specs.is_empty() {
        [
            "none",
            "tau=9",
            "deadline=3",
            "phase-deadline=3/0.5/0.5",
            "tau=9+deadline=3",
            "local-sgd=4+tau=0.9",
        ]
        .iter()
        .map(|s| DropPolicy::parse(s).expect("built-in specs are valid"))
        .collect()
    } else {
        specs
            .iter()
            .map(|s| DropPolicy::parse(s))
            .collect::<dropcompute::util::Result<_>>()?
    };

    // the paper's delay environment plus uniform stragglers, on an
    // event-driven torus collective — compute and comm tails both bite
    let base = ClusterConfig {
        workers,
        accumulations: 12,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        },
        stragglers: StragglerKind::Uniform { p: 0.06, delay: 4.0 },
        topology: Some(TopologyKind::Torus { rows: 0 }),
        link_latency: 25e-6,
        link_bandwidth: 12.5e9,
        grad_bytes: 4.0 * 335e6,
        ..Default::default()
    };

    let result = SweepSpec::new(base)
        .workers(&[workers])
        .policies(&policies)
        .seeds(&[7])
        .iters(iters)
        .progress(false)
        .run();

    let baseline = result.points[0].mean_iter_time;
    let mut t = Table::new(
        format!("drop policies — torus, N={workers}, {iters} iters"),
        &["policy", "iter time", "mb/s", "drop", "speedup"],
    );
    for p in &result.points {
        t.row(vec![
            p.policy.clone().unwrap_or_else(|| "none".into()),
            f(p.mean_iter_time, 3),
            f(p.throughput, 1),
            pct(p.drop_rate),
            f(baseline / p.mean_iter_time, 3),
        ]);
    }
    t.print();
    println!(
        "\n(spec grammar: none | tau=T[,preempt|,between] | deadline=D | \
         phase-deadline=B0[/B1...] | local-sgd=H, composed with `+`)"
    );
    Ok(())
}
