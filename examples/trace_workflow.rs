//! Trace record -> replay -> fit, end to end.
//!
//! 1. record a replayable trace from a live straggler-heavy run;
//! 2. round-trip it through the versioned JSON format;
//! 3. replay it on both timing paths and verify the recorded outcomes
//!    reproduce bitwise (the conformance contract);
//! 4. fit drop budgets (tau + step-level and per-phase DropComm
//!    deadlines) from the trace and compare the fitted policies by
//!    replay — the Algorithm-2 analogue for the comm side.
//!
//! Run: `cargo run --release --example trace_workflow`

use dropcompute::analysis::{evaluate_policy, fit_budgets};
use dropcompute::config::{ClusterConfig, NoiseKind, StragglerKind};
use dropcompute::policy::DropPolicy;
use dropcompute::sim::{ClusterSim, StepOutcome, TraceRecord};
use dropcompute::topology::TopologyKind;

fn main() {
    let cfg = ClusterConfig {
        workers: 16,
        accumulations: 8,
        microbatch_mean: 0.45,
        microbatch_std: 0.02,
        noise: NoiseKind::Exponential { mean: 0.25 },
        stragglers: StragglerKind::Uniform { p: 0.15, delay: 5.0 },
        topology: Some(TopologyKind::Torus { rows: 0 }),
        link_latency: 25e-6,
        link_bandwidth: 12.5e9,
        grad_bytes: 4.0 * 33.7e6,
        ..Default::default()
    };

    // 1. record a live run (no drops, so the trace is fit-ready)
    let mut live = ClusterSim::new(&cfg, 42);
    live.start_recording();
    let mut out = StepOutcome::default();
    for _ in 0..60 {
        live.step_installed_into(&mut out);
    }
    let trace = live.finish_recording().expect("consistent recording");
    println!(
        "recorded {} steps (N={} M={}), policy `{}`",
        trace.len(),
        trace.meta.workers,
        trace.meta.accums,
        trace.meta.policy
    );

    // 2. JSON round trip is bitwise-lossless
    let parsed = TraceRecord::parse(&trace.to_json()).expect("parse back");
    assert_eq!(parsed, trace);
    println!("JSON round trip: {} bytes, lossless", trace.to_json().len());

    // 3. replay reproduces the recorded outcomes bitwise on both paths
    for (label, reference) in [("compiled", false), ("event-queue", true)] {
        let mut replay = ClusterSim::from_trace(&parsed).expect("replayable");
        if reference {
            replay = replay.with_reference_timing();
        }
        let outs = replay.replay_all().expect("whole trace");
        let ok = parsed
            .outcomes
            .iter()
            .zip(&outs)
            .filter(|(rec, out)| rec.matches(out))
            .count();
        println!("replay [{label}]: {ok}/{} steps bitwise", parsed.len());
        assert_eq!(ok, parsed.len());
    }

    // 4. fit drop budgets from the recorded reality
    let fit = fit_budgets(&parsed, 12, 24).expect("fit");
    println!("\nfitted policies (predictions measured by replay):");
    for (label, e) in [
        ("baseline", None),
        ("step-level", Some(&fit.step_level)),
        ("deadline", Some(&fit.deadline_level)),
        ("per-phase", Some(&fit.per_phase)),
        ("best", Some(&fit.best)),
    ] {
        match e {
            None => println!(
                "  {label:10} none                          iter {:.3}s",
                fit.baseline_iter_time
            ),
            Some(e) => println!(
                "  {label:10} {:28} S_eff {:.4}  completion {:.1}%  iter {:.3}s",
                e.spec,
                e.speedup,
                e.completion * 100.0,
                e.mean_iter_time
            ),
        }
    }

    // the emitted spec is directly usable as --policy / [policy] spec
    let refit = DropPolicy::parse(&fit.best.spec).expect("parseable spec");
    let (t, _) = evaluate_policy(&parsed, &refit).expect("replayable");
    assert_eq!(t.to_bits(), fit.best.mean_iter_time.to_bits());
    println!("\nready-to-use spec: --policy '{}'", fit.best.spec);
}
