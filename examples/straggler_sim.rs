//! Straggler robustness demo (timing-only, no model compute): how the
//! iteration time of synchronous training degrades with cluster size
//! under several noise families, and what DropCompute recovers.
//!
//! ```sh
//! cargo run --release --example straggler_sim -- [--workers 8,32,128]
//! ```

use dropcompute::analysis::Setting;
use dropcompute::cli::Spec;
use dropcompute::config::{ClusterConfig, NoiseKind};
use dropcompute::coordinator::ScaleRun;
use dropcompute::report::{ascii_series, f, pct, Table};
use dropcompute::sim::LatencyModel;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Spec::new()
        .value_keys(&["workers"])
        .parse(std::env::args().skip(1))?;
    let ns: Vec<usize> = args
        .str_or("workers", "4,16,64,200")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();

    for (label, noise) in [
        ("no noise", NoiseKind::None),
        (
            "paper lognormal delay",
            NoiseKind::PaperLogNormal {
                mu: 4.0,
                sigma: 1.0,
                alpha: 2.0 * (4.5f64).exp(),
                beta: 5.5,
            },
        ),
        ("exponential", NoiseKind::Exponential { mean: 0.225 }),
    ] {
        let base = ClusterConfig {
            workers: 1,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.5,
            noise: noise.clone(),
            ..Default::default()
        };
        let run = ScaleRun { base: base.clone(), ..Default::default() };
        let pts = run.sweep(&ns);
        let mut t = Table::new(
            format!("scaling under `{label}`"),
            &["N", "baseline mb/s", "DropCompute mb/s", "linear", "drop", "recovered"],
        );
        for p in &pts {
            let gap = p.linear_throughput - p.baseline_throughput;
            let rec = if gap > 1e-9 {
                (p.dropcompute_throughput - p.baseline_throughput) / gap
            } else {
                0.0
            };
            t.row(vec![
                p.workers.to_string(),
                f(p.baseline_throughput, 1),
                f(p.dropcompute_throughput, 1),
                f(p.linear_throughput, 1),
                pct(p.drop_rate),
                pct(rec.clamp(0.0, 1.0)),
            ]);
        }
        t.print();

        // analytical scaling-efficiency curve for the same noise
        let model = LatencyModel::from_config(&base);
        let series: Vec<(String, f64)> = ns
            .iter()
            .map(|&n| {
                let s = Setting {
                    workers: n,
                    accums: 12,
                    mu: model.mean(),
                    sigma2: model.variance(),
                    comm: 0.5,
                };
                (
                    format!("N={n}"),
                    dropcompute::analysis::scaling_efficiency(&s),
                )
            })
            .collect();
        println!("{}", ascii_series("analytic scaling efficiency", &series, 40));
    }
    Ok(())
}
