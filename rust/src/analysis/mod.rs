//! The paper's analytical runtime model and threshold selection.
//!
//! * [`order_stats`] — Eq. 4 / App. C.2: expected max iteration time;
//! * [`speedup`] — Eq. 5/6/11: `E[M~]`, `S_eff`, scale-law extrapolation;
//! * [`threshold`] — Algorithm 2: empirical `tau*` selection from traces;
//! * [`budget_fit`] — the Algorithm-2 analogue for the comm side:
//!   fit `tau` + DropComm deadlines (step-level and per-phase) from a
//!   recorded replayable trace, predictions measured by replay.

pub mod budget_fit;
pub mod order_stats;
pub mod speedup;
pub mod threshold;

pub use budget_fit::{evaluate_policy, fit_budgets, BudgetFit, FitEval};
pub use order_stats::{
    asymptotic_max_normal, expected_max_cdf, expected_max_normal,
    expected_max_normal_exact, expected_step_max, EULER_GAMMA,
};
pub use speedup::{expected_completed, extrapolate_speedup, scaling_efficiency, Setting};
pub use threshold::{
    choose_per_worker_thresholds, evaluate_per_worker,
    choose_threshold, evaluate_threshold, threshold_for_drop_rate,
    SweepPoint, ThresholdChoice,
};
