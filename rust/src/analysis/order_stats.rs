//! Order statistics of the iteration time: `T = max_n T_n` (§4.2).
//!
//! * [`expected_max_normal`] — Eq. 4: Bailey et al.'s approximation of
//!   `E[max of N iid N(mu, sigma^2)]`;
//! * [`expected_max_cdf`] — exact `E[max]` for any CDF by numerically
//!   integrating `E[T] = lo + ∫ (1 - F(x)^N) dx` (used where the Gaussian
//!   assumption C.2 breaks, cf. Fig 3b);
//! * [`asymptotic_max_normal`] — the `Θ(√log N)` tail (App. C.2), behind
//!   the Fig 1-right extrapolation.

use crate::stats::normal::{phi, phi_inv};

/// Euler–Mascheroni constant (the paper's `gamma`).
pub const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;

/// Eq. 4: `E[max_n T_n]` for `T_n ~ N(mu, sigma^2)` iid over `n`.
pub fn expected_max_normal(n: usize, mu: f64, sigma: f64) -> f64 {
    if n <= 1 {
        return mu;
    }
    let nf = n as f64;
    sigma
        * ((1.0 - EULER_GAMMA) * phi_inv(1.0 - 1.0 / nf)
            + EULER_GAMMA * phi_inv(1.0 - 1.0 / (std::f64::consts::E * nf)))
        + mu
}

/// Asymptotic form: `E[T] - mu = Θ(sigma sqrt(log N))` (App. C.2).
///
/// Uses the two-term Gumbel expansion
/// `E[max] ≈ b_N + gamma/a_N`, `a_N = sqrt(2 ln N)`,
/// `b_N = a_N - (ln ln N + ln 4π)/(2 a_N)` — the leading `sqrt(2 ln N)`
/// alone overshoots badly at practical N (convergence is O(1/log N)).
pub fn asymptotic_max_normal(n: usize, mu: f64, sigma: f64) -> f64 {
    if n <= 2 {
        return expected_max_normal(n, mu, sigma);
    }
    let ln_n = (n as f64).ln();
    let a = (2.0 * ln_n).sqrt();
    let b = a - (ln_n.ln() + (4.0 * std::f64::consts::PI).ln()) / (2.0 * a);
    mu + sigma * (b + EULER_GAMMA / a)
}

/// Exact `E[max of N]` for iid samples with CDF `cdf`, via
/// `E[T] = lo + ∫_{lo}^{hi} (1 - F(x)^N) dx` (Simpson's rule).
///
/// `lo` must satisfy `F(lo) ≈ 0`; `hi` must satisfy `F(hi)^N ≈ 1`.
pub fn expected_max_cdf(
    n: usize,
    cdf: impl Fn(f64) -> f64,
    lo: f64,
    hi: f64,
    intervals: usize,
) -> f64 {
    assert!(hi > lo && intervals >= 2);
    let steps = intervals + (intervals % 2); // even for Simpson
    let h = (hi - lo) / steps as f64;
    let g = |x: f64| 1.0 - cdf(x).clamp(0.0, 1.0).powi(n as i32);
    let mut sum = g(lo) + g(hi);
    for k in 1..steps {
        let w = if k % 2 == 1 { 4.0 } else { 2.0 };
        sum += w * g(lo + h * k as f64);
    }
    lo + sum * h / 3.0
}

/// `E[max]` of N iid normals by the exact integral (reference for Eq. 4).
pub fn expected_max_normal_exact(n: usize, mu: f64, sigma: f64) -> f64 {
    let lo = mu - 8.0 * sigma;
    let hi = mu + (8.0 + 2.0 * (n as f64).ln().sqrt()) * sigma;
    expected_max_cdf(n, |x| phi((x - mu) / sigma), lo, hi, 4000)
}

/// `E[max]` of N iid sums of `m` micro-batches under CLT
/// (`T_n ~ N(m*mu, m*sigma^2)`, Eq. 7 with the `T^c` term excluded).
pub fn expected_step_max(n: usize, m: usize, mu: f64, sigma2: f64) -> f64 {
    expected_max_normal(n, m as f64 * mu, (m as f64 * sigma2).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Normal, Xoshiro256pp};

    /// Monte-Carlo `E[max of N]`.
    fn mc_max(n: usize, d: &dyn Distribution, reps: usize, seed: u64) -> f64 {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut sum = 0.0;
        for _ in 0..reps {
            let mut mx = f64::NEG_INFINITY;
            for _ in 0..n {
                mx = mx.max(d.sample(&mut rng));
            }
            sum += mx;
        }
        sum / reps as f64
    }

    #[test]
    fn bailey_matches_monte_carlo() {
        let d = Normal::new(1.0, 0.2);
        for n in [2usize, 8, 32, 128] {
            let approx = expected_max_normal(n, 1.0, 0.2);
            let mc = mc_max(n, &d, 20_000, n as u64);
            assert!(
                (approx - mc).abs() < 0.02,
                "n={n}: bailey {approx} vs mc {mc}"
            );
        }
    }

    #[test]
    fn bailey_matches_exact_integral() {
        // Bailey et al.'s formula is an approximation (~3% relative);
        // check it tracks the exact integral across three decades.
        for n in [2usize, 10, 100, 1000] {
            let a = expected_max_normal(n, 0.0, 1.0);
            let e = expected_max_normal_exact(n, 0.0, 1.0);
            assert!((a / e - 1.0).abs() < 0.09, "n={n}: {a} vs {e}");
        }
    }

    #[test]
    fn grows_like_sqrt_log_n() {
        // E[max(N^2)]/E[max(N)] -> sqrt(2); finite-N convergence is slow
        // (O(1/log N) corrections), so allow a one-sided band.
        let e1 = expected_max_normal(100, 0.0, 1.0);
        let e2 = expected_max_normal(10_000, 0.0, 1.0);
        let ratio = e2 / e1;
        let want = 2.0f64.sqrt();
        assert!(ratio > want * 0.97 && ratio < want * 1.12, "ratio {ratio}");
    }

    #[test]
    fn degenerate_cases() {
        assert_eq!(expected_max_normal(1, 5.0, 1.0), 5.0);
        assert_eq!(expected_max_normal(0, 5.0, 1.0), 5.0);
        // zero variance: max == mu at any N
        assert!((expected_max_normal(64, 2.0, 0.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn exact_integral_for_uniform() {
        // max of N uniforms on [0,1] has E = N/(N+1).
        for n in [1usize, 3, 10] {
            let e = expected_max_cdf(n, |x| x.clamp(0.0, 1.0), 0.0, 1.0, 2000);
            let want = n as f64 / (n as f64 + 1.0);
            assert!((e - want).abs() < 1e-6, "n={n}: {e} vs {want}");
        }
    }

    #[test]
    fn asymptotic_tracks_bailey_at_large_n() {
        for n in [1usize << 10, 1 << 16] {
            let a = expected_max_normal(n, 0.0, 1.0);
            let b = asymptotic_max_normal(n, 0.0, 1.0);
            assert!((a / b - 1.0).abs() < 0.03, "n={n}: {a} vs {b}");
        }
    }

    #[test]
    fn asymptotic_matches_exact_at_large_n() {
        let n = 1usize << 14;
        let a = asymptotic_max_normal(n, 0.0, 1.0);
        let e = expected_max_normal_exact(n, 0.0, 1.0);
        assert!((a / e - 1.0).abs() < 0.02, "{a} vs {e}");
    }

    #[test]
    fn step_max_scales_with_accumulations() {
        let t12 = expected_step_max(64, 12, 0.45, 0.02 * 0.02);
        let t24 = expected_step_max(64, 24, 0.45, 0.02 * 0.02);
        assert!(t24 > 2.0 * t12 * 0.98 && t24 < 2.05 * t12);
    }
}
