//! Budget fitting from recorded traces — the Algorithm-2 analogue for
//! the communication side.
//!
//! Algorithm 2 chooses the compute threshold `tau*` from *observed*
//! iteration statistics; OptiReduce (arXiv:2310.06993) derives its
//! per-phase collective deadlines from *measured* tail latencies the
//! same way. [`fit_budgets`] closes that loop for this crate: it scans
//! a recorded [`TraceRecord`] and fits
//!
//! * a compute threshold `tau*`,
//! * a step-level DropComm deadline `D*`, and
//! * per-phase budgets whose lumped total is **bitwise** `D*`
//!   (so the fitted per-phase policy degrades exactly to the fitted
//!   step-level policy — the `policy_equivalence` identity),
//!
//! all by maximizing *predicted effective speedup* over the trace. The
//! predictor is replay itself ([`ClusterSim::from_trace`] +
//! [`ClusterSim::replay_into`]): every candidate
//! [`DropPolicy`] is re-timed against the recorded compute draws
//! through the real timing paths, so the prediction is exact for the
//! recorded world, not a closed-form approximation.
//!
//! Candidate generation is boundary-aware: DropComm membership only
//! changes at the *observed* arrival offsets `a_{i,n} - min_n a_{i,n}`,
//! and for a fixed membership set a smaller deadline strictly shortens
//! the restart, so the per-step observed offsets are exactly the
//! candidate deadlines worth evaluating (subsampled to a cap when the
//! trace is large). Compute thresholds sweep the same
//! `[mean/2, max]` range Algorithm 2 uses.
//!
//! The fitted best policy is emitted as a ready-to-use spec string
//! (`tau=..+deadline=..` / `tau=..+phase-deadline=..`), consumable by
//! `--policy`, the `[policy]` config section and the sweep policy axis.

use crate::policy::{cumulative_offsets, DropPolicy};
use crate::sim::{ClusterSim, StepOutcome, TraceComm, TraceMode, TraceRecord};
use crate::util::{Error, Result};

/// One candidate policy's replay-measured prediction.
#[derive(Debug, Clone)]
pub struct FitEval {
    pub policy: DropPolicy,
    /// `policy.spec()` — parseable by [`DropPolicy::parse`].
    pub spec: String,
    /// Mean iteration time over the replayed trace.
    pub mean_iter_time: f64,
    /// Completed micro-batches relative to the no-drop baseline.
    pub completion: f64,
    /// Predicted effective speedup
    /// `(T_base / T_policy) * completion` (paper Eq. 6 shape).
    pub speedup: f64,
}

/// Result of [`fit_budgets`].
#[derive(Debug, Clone)]
pub struct BudgetFit {
    /// Mean iteration time of the no-drop baseline replay.
    pub baseline_iter_time: f64,
    /// Best `tau`/`deadline` combination from the grid (may be
    /// tau-only, or even the no-drop baseline on a quiet trace).
    pub step_level: FitEval,
    /// Best *deadline-bearing* combination — the fitted comm-side
    /// budget `D*` even when a pure compute threshold wins overall
    /// (Algorithm 2 always reports a tau; this always reports a
    /// deadline).
    pub deadline_level: FitEval,
    /// Best per-phase shaping of `deadline_level`'s `D*` (never worse
    /// than `deadline_level`: the lumped shape is in its candidate
    /// set).
    pub per_phase: FitEval,
    /// The overall winner (what the CLI emits).
    pub best: FitEval,
    /// Every grid candidate evaluated, in enumeration order.
    pub evaluated: Vec<FitEval>,
    /// The fitted step-level deadline `D*` (from `deadline_level`;
    /// `None` only for degenerate traces with no deadline candidates).
    pub step_deadline: Option<f64>,
    /// The fitted per-phase budgets; their cumulative total is bitwise
    /// `D*` (empty when `step_deadline` is `None`).
    pub phase_budgets: Vec<f64>,
    /// Candidate grids (diagnostics / property tests).
    pub taus: Vec<f64>,
    pub deadlines: Vec<f64>,
    /// The trace was recorded under a compute-tau policy, so its
    /// samples are already censored at the recorded threshold: the
    /// "no-drop baseline" is that censored world, not a true no-drop
    /// run, and every speedup here is *relative to the recorded
    /// policy's compute behavior*. Record without a tau clause for
    /// absolute numbers (the CLI prints a warning when this is set).
    pub censored: bool,
}

/// Replay `trace` under `policy` and measure it: mean iteration time
/// and total completed micro-batches. Typed errors for period traces
/// replayed under step policies (and vice versa), empty traces, or
/// invalid records.
pub fn evaluate_policy(
    trace: &TraceRecord,
    policy: &DropPolicy,
) -> Result<(f64, usize)> {
    if trace.is_empty() {
        return Err(Error::Data("budget fit: empty trace".into()));
    }
    let mut sim = ClusterSim::from_trace(trace)?;
    measure(&mut sim, trace.len(), policy)
}

/// [`evaluate_policy`]'s inner loop on an already-built replay sim:
/// install the policy, rewind the cursor, replay every step. The fit
/// reuses one sim this way — hundreds of candidate policies re-time
/// one cursor instead of deep-copying the trace per candidate —
/// bitwise identical to a fresh sim (replay consumes no RNG and the
/// survivor cache is pure memoization).
fn measure(
    sim: &mut ClusterSim,
    steps: usize,
    policy: &DropPolicy,
) -> Result<(f64, usize)> {
    sim.set_policy(policy);
    sim.rewind_replay()?;
    let mut out = StepOutcome::default();
    let mut t_sum = 0.0;
    let mut completed = 0usize;
    for _ in 0..steps {
        sim.replay_into(&mut out)?;
        t_sum += out.iter_time;
        completed += out.total_completed();
    }
    Ok((t_sum / steps as f64, completed))
}

/// Per-(step, worker) no-drop arrival times implied by the recorded
/// draws: `straggle + sum(samples)`.
fn arrivals(trace: &TraceRecord) -> Vec<Vec<f64>> {
    trace
        .steps
        .iter()
        .map(|st| {
            st.straggle
                .iter()
                .zip(&st.samples)
                .map(|(&straggle, row)| {
                    let mut t = straggle;
                    for &s in row {
                        t += s;
                    }
                    t
                })
                .collect()
        })
        .collect()
}

/// Compute-threshold candidates: `grid + 1` points spanning
/// `[mean/2, max]` of the observed per-worker step times (Algorithm 2's
/// range), non-positive values skipped so every emitted spec validates.
fn tau_candidates(arrivals: &[Vec<f64>], grid: usize) -> Vec<f64> {
    let mut t_max = f64::NEG_INFINITY;
    let mut t_sum = 0.0;
    let mut count = 0usize;
    for step in arrivals {
        for &a in step {
            t_max = t_max.max(a);
            t_sum += a;
            count += 1;
        }
    }
    if count == 0 {
        return Vec::new();
    }
    let lo = 0.5 * (t_sum / count as f64);
    let hi = t_max;
    (0..=grid)
        .map(|k| lo + (hi - lo) * k as f64 / grid as f64)
        .filter(|&t| t.is_finite() && t > 0.0)
        .collect()
}

/// Deadline candidates: the observed per-step arrival offsets
/// (`a - first`) — the exact membership decision boundaries — deduped,
/// sorted, and quantile-subsampled down to `cap` (the largest offset is
/// always kept, so the loose no-drop arm is always evaluated).
fn deadline_candidates(arrivals: &[Vec<f64>], cap: usize) -> Vec<f64> {
    let mut offsets: Vec<f64> = Vec::new();
    for step in arrivals {
        let first = step.iter().cloned().fold(f64::INFINITY, f64::min);
        for &a in step {
            let off = a - first;
            if off.is_finite() && off >= 0.0 {
                offsets.push(off);
            }
        }
    }
    offsets.sort_by(|a, b| a.partial_cmp(b).expect("finite offsets"));
    offsets.dedup_by(|a, b| a.to_bits() == b.to_bits());
    if offsets.len() > cap && cap > 0 {
        let last = offsets.len() - 1;
        if cap == 1 {
            // the promise is that the loose (no-drop) arm survives
            // subsampling; with a single slot that IS the largest
            offsets = vec![offsets[last]];
        } else {
            let picks: Vec<f64> =
                (0..cap).map(|j| offsets[j * last / (cap - 1)]).collect();
            offsets = picks;
            offsets.dedup_by(|a, b| a.to_bits() == b.to_bits());
        }
    }
    offsets
}

fn compose(tau: Option<f64>, deadline: Option<f64>) -> DropPolicy {
    let mut p = DropPolicy::None;
    if let Some(t) = tau {
        p = p.and(DropPolicy::compute_tau(t));
    }
    if let Some(d) = deadline {
        p = p.and(DropPolicy::comm_deadline(d));
    }
    p
}

/// Split deadline `D` into `checkpoints` per-phase budgets with entry
/// fraction `f`, the rest distributed over the remaining checkpoints —
/// constructed so the sequential cumulative sum
/// ([`cumulative_offsets`]) lands on `D` **bitwise** (the last budget
/// is the exact Sterbenz remainder `D - cum`).
fn shape_budgets(deadline: f64, f: f64, checkpoints: usize) -> Vec<f64> {
    if checkpoints <= 1 || f >= 1.0 {
        return vec![deadline];
    }
    let mut budgets = vec![f * deadline];
    let mut cum = f * deadline;
    for j in 1..checkpoints {
        let b = if j + 1 == checkpoints {
            deadline - cum
        } else {
            (deadline - cum) / (checkpoints - j) as f64
        };
        budgets.push(b);
        cum += b;
    }
    budgets
}

/// Fit drop budgets to a recorded trace (see the module docs): sweep
/// `tau x deadline` candidates by replay, then shape the winning
/// deadline into per-phase budgets and keep whichever form predicts the
/// higher effective speedup. `grid` is the compute-threshold
/// resolution; `deadline_cap` bounds how many observed arrival offsets
/// are evaluated as deadline candidates.
pub fn fit_budgets(
    trace: &TraceRecord,
    grid: usize,
    deadline_cap: usize,
) -> Result<BudgetFit> {
    if trace.meta.mode != TraceMode::Step {
        return Err(Error::Data(
            "budget fit: only step-mode traces are supported (record \
             without a local-sgd policy)"
            .into(),
        ));
    }
    if trace.is_empty() {
        return Err(Error::Data("budget fit: empty trace".into()));
    }
    let arr = arrivals(trace);
    let taus = tau_candidates(&arr, grid.max(2));
    let deadlines = deadline_candidates(&arr, deadline_cap.max(1));
    // tau-censored recordings stopped drawing at the recorded
    // threshold, so the replay "baseline" is that censored world —
    // surfaced, not silently folded into the numbers
    let censored = DropPolicy::parse(&trace.meta.policy)?
        .compute_cutoff()
        .is_some();

    // one shared replay sim for the whole grid: candidates re-time the
    // cursor instead of deep-copying the trace per evaluation
    let mut sim = ClusterSim::from_trace(trace)?;
    let steps = trace.len();
    let (t_base, completed_base) =
        measure(&mut sim, steps, &DropPolicy::None)?;
    let make_eval = |policy: DropPolicy, t: f64, completed: usize| {
        let completion = if completed_base == 0 {
            1.0
        } else {
            completed as f64 / completed_base as f64
        };
        let speedup = if t > 0.0 { (t_base / t) * completion } else { 0.0 };
        FitEval {
            spec: policy.spec(),
            policy,
            mean_iter_time: t,
            completion,
            speedup,
        }
    };

    let mut evaluated = Vec::new();
    let mut tau_axis: Vec<Option<f64>> = vec![None];
    tau_axis.extend(taus.iter().copied().map(Some));
    let mut deadline_axis: Vec<Option<f64>> = vec![None];
    deadline_axis.extend(deadlines.iter().copied().map(Some));
    for &tau in &tau_axis {
        for &deadline in &deadline_axis {
            let policy = compose(tau, deadline);
            let (t, completed) = if policy.is_none() {
                (t_base, completed_base)
            } else {
                measure(&mut sim, steps, &policy)?
            };
            evaluated.push((make_eval(policy, t, completed), tau, deadline));
        }
    }
    // deterministic argmaxes: strictly-greater wins, enumeration order
    // breaks ties. `best_idx` is the global optimum; `best_d_idx` the
    // optimum among deadline-bearing combos (the fitted comm budget —
    // reported even when a pure compute threshold wins overall).
    let mut best_idx = 0usize;
    let mut best_d_idx: Option<usize> = None;
    for (i, (e, _, deadline)) in evaluated.iter().enumerate() {
        if e.speedup > evaluated[best_idx].0.speedup {
            best_idx = i;
        }
        if deadline.is_some()
            && best_d_idx
                .map(|j| e.speedup > evaluated[j].0.speedup)
                .unwrap_or(true)
        {
            best_d_idx = Some(i);
        }
    }
    let (step_level, _, _) = evaluated[best_idx].clone();
    let (deadline_level, d_tau, step_deadline) = match best_d_idx {
        Some(j) => evaluated[j].clone(),
        None => evaluated[best_idx].clone(),
    };

    // shape the fitted deadline across the topology's phases; the f=1.0
    // arm is the lumped identity (bitwise the deadline-level policy)
    let phase_count = match &trace.meta.comm {
        TraceComm::Fixed { .. } => 0,
        TraceComm::Topology { kind, .. } => {
            kind.build(trace.meta.workers).phase_count()
        }
    };
    let (per_phase, phase_budgets) = match step_deadline {
        Some(deadline) if phase_count >= 2 => {
            let checkpoints = phase_count.min(3);
            let mut best: Option<(FitEval, Vec<f64>)> = None;
            for f in [1.0, 0.75, 0.5] {
                let budgets = shape_budgets(deadline, f, checkpoints);
                debug_assert_eq!(
                    cumulative_offsets(&budgets)
                        .last()
                        .expect("non-empty budgets")
                        .to_bits(),
                    deadline.to_bits(),
                    "shaped budgets must lump to the fitted deadline"
                );
                let policy = match d_tau {
                    Some(t) => DropPolicy::compute_tau(t)
                        .and(DropPolicy::per_phase_deadline(budgets.clone())),
                    None => DropPolicy::per_phase_deadline(budgets.clone()),
                };
                let (t, completed) = measure(&mut sim, steps, &policy)?;
                let eval = make_eval(policy, t, completed);
                if best
                    .as_ref()
                    .map(|(b, _)| eval.speedup > b.speedup)
                    .unwrap_or(true)
                {
                    best = Some((eval, budgets));
                }
            }
            best.expect("at least the lumped shape was evaluated")
        }
        Some(deadline) => {
            // no phase structure to shape into: the per-phase form is
            // the lumped single budget
            (deadline_level.clone(), vec![deadline])
        }
        None => (deadline_level.clone(), Vec::new()),
    };

    let best = if per_phase.speedup > step_level.speedup {
        per_phase.clone()
    } else {
        step_level.clone()
    };
    Ok(BudgetFit {
        baseline_iter_time: t_base,
        step_level,
        deadline_level,
        per_phase,
        best,
        evaluated: evaluated.into_iter().map(|(e, _, _)| e).collect(),
        step_deadline,
        phase_budgets,
        taus,
        deadlines,
        censored,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, NoiseKind, StragglerKind};
    use crate::topology::TopologyKind;

    fn tail_heavy_trace(seed: u64) -> TraceRecord {
        let cfg = ClusterConfig {
            workers: 8,
            accumulations: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.3 },
            stragglers: StragglerKind::Uniform { p: 0.25, delay: 4.0 },
            topology: Some(TopologyKind::Ring),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let mut sim = ClusterSim::new(&cfg, seed);
        sim.start_recording();
        for _ in 0..25 {
            sim.step(None);
        }
        sim.finish_recording().expect("consistent recording")
    }

    #[test]
    fn fit_finds_speedup_on_a_tail_heavy_trace_and_spec_parses() {
        let trace = tail_heavy_trace(0xF17);
        let fit = fit_budgets(&trace, 8, 16).unwrap();
        assert!(
            fit.best.speedup > 1.05,
            "a heavy straggler tail must be worth dropping: {}",
            fit.best.speedup
        );
        assert!(fit.best.completion > 0.5, "{}", fit.best.completion);
        // the emitted spec is ready to use
        let parsed = DropPolicy::parse(&fit.best.spec).expect("parseable");
        assert_eq!(parsed, fit.best.policy);
        for e in &fit.evaluated {
            assert!(DropPolicy::parse(&e.spec).is_ok(), "{}", e.spec);
            assert!(
                fit.best.speedup >= e.speedup,
                "argmax: {} vs {}",
                fit.best.speedup,
                e.speedup
            );
        }
        // baseline is in the grid, so the winner never loses to it
        assert!(fit.step_level.speedup >= 1.0 - 1e-12);
        // recorded with no compute clause: not censored
        assert!(!fit.censored);
    }

    #[test]
    fn tau_recorded_traces_are_flagged_as_censored() {
        let cfg = ClusterConfig {
            workers: 5,
            accumulations: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            noise: NoiseKind::Exponential { mean: 0.4 },
            topology: Some(TopologyKind::Ring),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let mut sim = ClusterSim::new(&cfg, 9)
            .with_policy(DropPolicy::compute_tau(1.5));
        sim.start_recording();
        let mut out = StepOutcome::default();
        for _ in 0..10 {
            sim.step_installed_into(&mut out);
        }
        let trace = sim.finish_recording().unwrap();
        let fit = fit_budgets(&trace, 4, 8).unwrap();
        assert!(
            fit.censored,
            "tau-recorded samples are censored at the threshold"
        );
    }

    #[test]
    fn fitted_phase_budgets_lump_bitwise_to_the_step_deadline() {
        let trace = tail_heavy_trace(0xB17);
        let fit = fit_budgets(&trace, 6, 12).unwrap();
        let deadline = fit.step_deadline.expect("straggler tail fits a deadline");
        assert!(!fit.phase_budgets.is_empty());
        let lumped = *cumulative_offsets(&fit.phase_budgets)
            .last()
            .expect("non-empty");
        assert_eq!(
            lumped.to_bits(),
            deadline.to_bits(),
            "lumping the fitted budgets must reproduce D* bitwise"
        );
        assert!(fit.phase_budgets.iter().all(|&b| b >= 0.0));
        // the per-phase arm never predicts worse than the fitted
        // deadline-level combo (the lumped shape is in its candidate
        // set), and the overall best dominates both public arms
        assert!(fit.per_phase.speedup >= fit.deadline_level.speedup);
        assert!(fit.step_level.speedup >= fit.deadline_level.speedup);
        assert!(
            fit.best.speedup
                >= fit.per_phase.speedup.max(fit.step_level.speedup) - 1e-15
        );
    }

    #[test]
    fn fit_matches_denser_exhaustive_grid_within_tolerance() {
        // the fit's boundary-aware deadline candidates + coarse tau grid
        // against an independently enumerated denser grid: the fit must
        // come within 5% of the exhaustive optimum
        let trace = tail_heavy_trace(0xEE);
        // same deadline cap on both arms (identical candidate sets), so
        // only the tau resolution differs between fit and exhaustive
        let fit = fit_budgets(&trace, 12, 64).unwrap();
        let arr = super::arrivals(&trace);
        let dense_taus = super::tau_candidates(&arr, 48);
        let dense_deadlines = super::deadline_candidates(&arr, 64);
        let (t_base, completed_base) =
            evaluate_policy(&trace, &DropPolicy::None).unwrap();
        let mut dense_best = 1.0f64;
        let mut tau_axis: Vec<Option<f64>> = vec![None];
        tau_axis.extend(dense_taus.iter().copied().map(Some));
        let mut d_axis: Vec<Option<f64>> = vec![None];
        d_axis.extend(dense_deadlines.iter().copied().map(Some));
        for &tau in &tau_axis {
            for &d in &d_axis {
                let policy = super::compose(tau, d);
                let (t, completed) =
                    evaluate_policy(&trace, &policy).unwrap();
                let s = (t_base / t)
                    * (completed as f64 / completed_base as f64);
                dense_best = dense_best.max(s);
            }
        }
        assert!(
            fit.step_level.speedup >= 0.93 * dense_best,
            "fit {} vs exhaustive {}",
            fit.step_level.speedup,
            dense_best
        );
    }

    #[test]
    fn fit_rejects_period_and_empty_traces() {
        let cfg = ClusterConfig {
            workers: 3,
            accumulations: 1,
            stragglers: StragglerKind::Uniform { p: 0.3, delay: 1.0 },
            ..Default::default()
        };
        let mut sim = ClusterSim::new(&cfg, 2)
            .with_policy(DropPolicy::parse("local-sgd=3").unwrap());
        sim.start_recording();
        let mut out = StepOutcome::default();
        for _ in 0..3 {
            sim.step_installed_into(&mut out);
        }
        let period = sim.finish_recording().unwrap();
        assert!(fit_budgets(&period, 4, 4).is_err(), "period trace");

        let mut empty = tail_heavy_trace(1);
        empty.steps.clear();
        empty.outcomes.clear();
        assert!(fit_budgets(&empty, 4, 4).is_err(), "empty trace");
    }

    #[test]
    fn shape_budgets_always_lump_exactly() {
        for deadline in [0.1, 1.0, 3.7, 1234.5678, 1e-9] {
            for f in [1.0, 0.75, 0.5] {
                for checkpoints in [1usize, 2, 3, 5] {
                    let b = shape_budgets(deadline, f, checkpoints);
                    assert!(b.iter().all(|&x| x >= 0.0), "{b:?}");
                    let lump =
                        *cumulative_offsets(&b).last().expect("non-empty");
                    assert_eq!(
                        lump.to_bits(),
                        deadline.to_bits(),
                        "D={deadline} f={f} c={checkpoints}"
                    );
                }
            }
        }
    }

    #[test]
    fn quiet_trace_prefers_no_drops() {
        // without a tail there is nothing to gain: the fitted best must
        // stay at (or negligibly near) the baseline
        let cfg = ClusterConfig {
            workers: 6,
            accumulations: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.005,
            topology: Some(TopologyKind::Ring),
            link_latency: 1e-4,
            link_bandwidth: 1e9,
            grad_bytes: 4e6,
            ..Default::default()
        };
        let mut sim = ClusterSim::new(&cfg, 3);
        sim.start_recording();
        for _ in 0..15 {
            sim.step(None);
        }
        let trace = sim.finish_recording().unwrap();
        let fit = fit_budgets(&trace, 6, 8).unwrap();
        assert!(fit.best.speedup < 1.05, "{}", fit.best.speedup);
        assert!(fit.best.completion > 0.9);
    }
}
