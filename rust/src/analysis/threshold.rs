//! Algorithm 2 (App. C.1): automatic, decentralized selection of the
//! drop threshold `tau*` from measured micro-batch latencies.
//!
//! Each worker measures `t_{i,n}^{(m)}` for `I` calibration iterations;
//! the empirical distributions are synchronized (here: an AllGather of
//! the trace — see `collective`), after which **every worker runs the
//! same deterministic argmax** and therefore arrives at the same `tau*`
//! without a central coordinator.

use crate::sim::Trace;

/// Result of the threshold search.
#[derive(Debug, Clone)]
pub struct ThresholdChoice {
    /// The chosen `tau*` (seconds of per-step compute).
    pub tau: f64,
    /// Predicted effective speedup at `tau*`.
    pub speedup: f64,
    /// Predicted micro-batch completion rate `M~/M` at `tau*`.
    pub completion_rate: f64,
    /// The full sweep: (tau, S_eff(tau), completion, step_speedup).
    pub sweep: Vec<SweepPoint>,
}

/// One candidate threshold's evaluation (the Fig 3c curves).
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    pub tau: f64,
    pub effective_speedup: f64,
    pub completion_rate: f64,
    /// Raw step-time speedup `(T+T^c)/(min(tau,T)+T^c)` ignoring drops.
    pub step_speedup: f64,
    /// Empirical drop rate at this tau.
    pub drop_rate: f64,
}

/// Evaluate `S_eff` for one candidate `tau` over a recorded trace —
/// the inner loop of Algorithm 2, exactly as in App. C.1:
/// `S_i(tau) = (T_i + T^c_i)/(min(tau,T_i) + T^c_i) * M~_i(tau)/M`.
pub fn evaluate_threshold(trace: &Trace, tau: f64) -> SweepPoint {
    let m = trace.accums as f64;
    let mut s_eff = 0.0;
    let mut completion = 0.0;
    let mut step_speed = 0.0;
    for i in 0..trace.iters {
        let mut t_i = f64::NEG_INFINITY;
        let mut m_i = 0.0;
        for n in 0..trace.workers {
            let mut cum = 0.0;
            let mut done = 0usize;
            for mm in 0..trace.accums {
                cum += trace.get(i, n, mm);
                if cum < tau {
                    done += 1;
                }
            }
            t_i = t_i.max(cum);
            m_i += done as f64 / trace.workers as f64;
        }
        let tc = trace.comm[i];
        let step = (t_i + tc) / (tau.min(t_i) + tc);
        s_eff += step * (m_i / m);
        completion += m_i / m;
        step_speed += step;
    }
    let iters = trace.iters as f64;
    SweepPoint {
        tau,
        effective_speedup: s_eff / iters,
        completion_rate: completion / iters,
        step_speedup: step_speed / iters,
        drop_rate: 1.0 - completion / iters,
    }
}

/// Algorithm 2: sweep a grid of candidate thresholds over the trace and
/// return the argmax. The grid spans `[min worker-step time / 2, max
/// worker-step time]` which covers Assumption C.3's valid range.
pub fn choose_threshold(trace: &Trace, grid: usize) -> ThresholdChoice {
    assert!(trace.iters > 0 && grid >= 2);
    let mut t_max = f64::NEG_INFINITY;
    let mut t_sum = 0.0;
    for i in 0..trace.iters {
        for n in 0..trace.workers {
            let t = trace.worker_step_time(i, n);
            t_max = t_max.max(t);
            t_sum += t;
        }
    }
    let t_mean = t_sum / (trace.iters * trace.workers) as f64;
    let lo = 0.5 * t_mean;
    let hi = t_max;

    let mut sweep = Vec::with_capacity(grid + 1);
    for k in 0..=grid {
        let tau = lo + (hi - lo) * k as f64 / grid as f64;
        sweep.push(evaluate_threshold(trace, tau));
    }
    let best = sweep
        .iter()
        .cloned()
        .max_by(|a, b| {
            a.effective_speedup
                .partial_cmp(&b.effective_speedup)
                .unwrap()
        })
        .unwrap();
    ThresholdChoice {
        tau: best.tau,
        speedup: best.effective_speedup,
        completion_rate: best.completion_rate,
        sweep,
    }
}

/// Find the threshold achieving a target drop rate (bisection over the
/// empirically monotone drop-rate(tau) curve). Used by the Fig 4 /
/// Table 1 benches that are parameterized by drop rate, not by tau.
pub fn threshold_for_drop_rate(trace: &Trace, target: f64) -> f64 {
    assert!((0.0..1.0).contains(&target));
    let mut lo = 0.0f64;
    let mut hi = (0..trace.iters)
        .map(|i| trace.step_time(i))
        .fold(f64::NEG_INFINITY, f64::max);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        let p = evaluate_threshold(trace, mid);
        if p.drop_rate > target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Evaluate *per-worker* thresholds `taus[n]` over a trace (the
/// heterogeneous-worker extension sketched in App. C.2: "it is possible
/// to derive similar properties with nonidentical workers, each with
/// their own mu_n, sigma_n"). Preemptive semantics: worker n computes
/// `min(tau_n, T_{i,n})` and completes micro-batches below its own tau.
pub fn evaluate_per_worker(trace: &Trace, taus: &[f64]) -> SweepPoint {
    assert_eq!(taus.len(), trace.workers);
    let m = trace.accums as f64;
    let mut s_eff = 0.0;
    let mut completion = 0.0;
    let mut step_speed = 0.0;
    for i in 0..trace.iters {
        let mut t_full = f64::NEG_INFINITY;
        let mut t_clipped = f64::NEG_INFINITY;
        let mut m_i = 0.0;
        for n in 0..trace.workers {
            let mut cum = 0.0;
            let mut done = 0usize;
            for mm in 0..trace.accums {
                cum += trace.get(i, n, mm);
                if cum < taus[n] {
                    done += 1;
                }
            }
            t_full = t_full.max(cum);
            t_clipped = t_clipped.max(cum.min(taus[n]));
            m_i += done as f64 / trace.workers as f64;
        }
        let tc = trace.comm[i];
        let step = (t_full + tc) / (t_clipped + tc);
        s_eff += step * (m_i / m);
        completion += m_i / m;
        step_speed += step;
    }
    let iters = trace.iters as f64;
    SweepPoint {
        tau: taus.iter().sum::<f64>() / taus.len() as f64,
        effective_speedup: s_eff / iters,
        completion_rate: completion / iters,
        step_speedup: step_speed / iters,
        drop_rate: 1.0 - completion / iters,
    }
}

/// Per-worker threshold selection for heterogeneous clusters: each
/// worker's tau is `c * mean_n(T_n)` with a single shared factor `c`
/// chosen by the same decentralized argmax.
///
/// Design finding (tested below, recorded in DESIGN.md): proportional
/// per-worker thresholds equalize *drop probability* across workers —
/// persistent stragglers keep contributing data instead of being
/// starved — at the cost of raw `S_eff`, because a *global* tau gets its
/// speedup precisely by hard-capping the slow worker. This is the
/// fairness/speedup trade-off implied by App. C.2's non-identical-worker
/// remark; the global rule remains the default (it matches the paper).
pub fn choose_per_worker_thresholds(trace: &Trace, grid: usize)
    -> (Vec<f64>, SweepPoint)
{
    assert!(trace.iters > 0 && grid >= 2);
    let means: Vec<f64> = (0..trace.workers)
        .map(|n| {
            (0..trace.iters)
                .map(|i| trace.worker_step_time(i, n))
                .sum::<f64>()
                / trace.iters as f64
        })
        .collect();
    let mut best: Option<(f64, SweepPoint)> = None;
    for k in 0..=grid {
        let c = 0.5 + 1.5 * k as f64 / grid as f64; // c in [0.5, 2.0]
        let taus: Vec<f64> = means.iter().map(|&m| c * m).collect();
        let p = evaluate_per_worker(trace, &taus);
        if best
            .as_ref()
            .map(|(_, b)| p.effective_speedup > b.effective_speedup)
            .unwrap_or(true)
        {
            best = Some((c, p));
        }
    }
    let (c, point) = best.unwrap();
    (means.iter().map(|&m| c * m).collect(), point)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, NoiseKind};
    use crate::sim::ClusterSim;

    fn noisy_trace(workers: usize, iters: usize) -> Trace {
        let cfg = ClusterConfig {
            workers,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.5,
            noise: NoiseKind::PaperLogNormal {
                mu: 4.0,
                sigma: 1.0,
                alpha: 2.0 * (4.5f64).exp(),
                beta: 5.5,
            },
            ..Default::default()
        };
        ClusterSim::new(&cfg, 123).record_trace(iters)
    }

    #[test]
    fn infinite_threshold_is_baseline() {
        let trace = noisy_trace(16, 20);
        let p = evaluate_threshold(&trace, 1e9);
        assert!((p.effective_speedup - 1.0).abs() < 1e-9);
        assert!((p.completion_rate - 1.0).abs() < 1e-9);
        assert_eq!(p.drop_rate, 0.0);
    }

    #[test]
    fn chooses_speedup_above_one_under_noise() {
        let trace = noisy_trace(64, 30);
        let choice = choose_threshold(&trace, 128);
        assert!(
            choice.speedup > 1.02,
            "heavy-tailed noise must give real speedup, got {}",
            choice.speedup
        );
        assert!(choice.completion_rate > 0.7, "{}", choice.completion_rate);
        assert!(choice.completion_rate < 1.0);
        // sweep includes both extremes of the trade-off
        assert!(choice.sweep.len() == 129);
    }

    #[test]
    fn deterministic_consensus() {
        // Decentralization requirement: same trace -> same tau on every
        // worker (bitwise).
        let trace = noisy_trace(8, 10);
        let a = choose_threshold(&trace, 64);
        let b = choose_threshold(&trace, 64);
        assert_eq!(a.tau.to_bits(), b.tau.to_bits());
    }

    #[test]
    fn drop_rate_inversion() {
        let trace = noisy_trace(32, 20);
        for target in [0.02, 0.05, 0.10, 0.20] {
            let tau = threshold_for_drop_rate(&trace, target);
            let got = evaluate_threshold(&trace, tau).drop_rate;
            assert!(
                (got - target).abs() < 0.02,
                "target {target}: tau {tau} gives {got}"
            );
        }
    }

    #[test]
    fn step_speedup_dominates_effective() {
        // S_eff = step_speedup * completion <= step_speedup.
        let trace = noisy_trace(16, 15);
        for tau in [4.0, 6.0, 8.0] {
            let p = evaluate_threshold(&trace, tau);
            assert!(p.effective_speedup <= p.step_speedup + 1e-12);
        }
    }

    #[test]
    fn per_worker_matches_global_when_homogeneous() {
        // With identical workers the per-worker scheme degenerates to a
        // global threshold and must not lose to it.
        let trace = noisy_trace(16, 20);
        let global = choose_threshold(&trace, 128);
        let (_, per) = choose_per_worker_thresholds(&trace, 128);
        assert!(
            per.effective_speedup > global.speedup - 0.06,
            "per-worker {} vs global {}",
            per.effective_speedup,
            global.speedup
        );
    }

    #[test]
    fn per_worker_wins_under_heterogeneity() {
        // One 1.6x-slow worker: a global tau either drops most of the
        // slow worker's batches or helps nobody; per-worker taus adapt.
        use crate::sim::{ClusterSim, CommModel, LatencyModel};
        let cfg = ClusterConfig {
            workers: 8,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.5,
            noise: NoiseKind::LogNormal { mean: 0.1, var: 0.02 },
            ..Default::default()
        };
        let mut scales = vec![1.0; 8];
        scales[0] = 1.6;
        let model = LatencyModel::from_config(&cfg).with_worker_scales(scales);
        let mut sim = ClusterSim::with_model(
            8, 12, model, CommModel::Fixed(0.5), 321,
        );
        let trace = sim.record_trace(30);
        let global = choose_threshold(&trace, 128);
        let (taus, _per) = choose_per_worker_thresholds(&trace, 128);
        // the slow worker gets a proportionally larger budget
        assert!(taus[0] > 1.3 * taus[1], "{taus:?}");

        // fairness: under the global tau the slow worker is starved
        // (its drop rate far exceeds the others'); proportional taus
        // equalize drop rates.
        let drop_rate_of = |n: usize, tau: f64| -> f64 {
            let mut done = 0usize;
            for i in 0..trace.iters {
                let mut cum = 0.0;
                for mm in 0..trace.accums {
                    cum += trace.get(i, n, mm);
                    if cum < tau {
                        done += 1;
                    }
                }
            }
            1.0 - done as f64 / (trace.iters * trace.accums) as f64
        };
        let slow_global = drop_rate_of(0, global.tau);
        let fast_global = drop_rate_of(1, global.tau);
        let slow_per = drop_rate_of(0, taus[0]);
        let fast_per = drop_rate_of(1, taus[1]);
        assert!(
            slow_global > fast_global + 0.2,
            "global tau starves the slow worker: {slow_global} vs {fast_global}"
        );
        assert!(
            (slow_per - fast_per).abs() < 0.1,
            "per-worker taus equalize drops: {slow_per} vs {fast_per}"
        );
    }

    #[test]
    fn per_worker_infinite_tau_is_baseline() {
        let trace = noisy_trace(6, 10);
        let p = evaluate_per_worker(&trace, &vec![1e9; 6]);
        assert!((p.effective_speedup - 1.0).abs() < 1e-9);
        assert_eq!(p.drop_rate, 0.0);
    }

    #[test]
    fn quiet_cluster_prefers_no_drops() {
        // Without noise the optimum is ~no dropping, speedup ~1.
        let cfg = ClusterConfig {
            workers: 16,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.005,
            comm_latency: 0.5,
            noise: NoiseKind::None,
            ..Default::default()
        };
        let trace = ClusterSim::new(&cfg, 7).record_trace(20);
        let choice = choose_threshold(&trace, 128);
        assert!(choice.speedup < 1.02, "{}", choice.speedup);
        assert!(choice.completion_rate > 0.97);
    }
}
