//! The paper's analytical runtime model (§4.3–4.4, App. C.2).
//!
//! * Eq. 5 / 10 — `E[M~(tau)] = Σ_m Φ((tau - m mu)/sqrt(m) sigma)`;
//! * Eq. 6 / 11 — effective speedup
//!   `S_eff(tau) = M~ (T + T^c) / (M (min(tau,T) + T^c))`;
//! * the analytic `tau* = argmax (1/(tau+T^c)) Σ_m Φ(...)`;
//! * the Fig 1-right scale-law extrapolation.

use crate::stats::normal::phi;

use super::order_stats::expected_step_max;

/// Statistical characteristics of one training setting: everything the
/// analytical model needs (micro-batch latency moments + `M`, `N`, `T^c`).
#[derive(Debug, Clone, Copy)]
pub struct Setting {
    /// Workers `N`.
    pub workers: usize,
    /// Micro-batches per step `M`.
    pub accums: usize,
    /// Mean micro-batch latency `mu`.
    pub mu: f64,
    /// Variance of micro-batch latency `sigma^2`.
    pub sigma2: f64,
    /// Serial per-iteration latency `T^c`.
    pub comm: f64,
}

impl Setting {
    /// Eq. 5: expected completed micro-batches per worker at threshold.
    pub fn expected_completed(&self, tau: f64) -> f64 {
        expected_completed(tau, self.accums, self.mu, self.sigma2)
    }

    /// Eq. 7/12: `E[T]` — expected baseline step compute time (no comm).
    pub fn expected_step_time(&self) -> f64 {
        expected_step_max(self.workers, self.accums, self.mu, self.sigma2)
    }

    /// Eq. 11 given an externally measured `E[T]` ("analytical given
    /// E[T]" in Fig 3) — more accurate when CLT assumption C.2 is poor.
    pub fn effective_speedup_given_t(&self, tau: f64, expected_t: f64) -> f64 {
        let m_tilde = self.expected_completed(tau);
        let m = self.accums as f64;
        (m_tilde / m) * (expected_t + self.comm)
            / (tau.min(expected_t) + self.comm)
    }

    /// Eq. 11 fully analytical (Gaussian `E[T]` via Eq. 12).
    pub fn effective_speedup(&self, tau: f64) -> f64 {
        self.effective_speedup_given_t(tau, self.expected_step_time())
    }

    /// Analytic optimal threshold:
    /// `tau* = argmax (1/(tau+T^c)) Σ_m Φ((tau-m mu)/sqrt(m sigma^2))`,
    /// grid-searched over `[M mu / 2, E[T]]` (Assumption C.3 lower bound).
    pub fn optimal_threshold(&self, grid: usize) -> (f64, f64) {
        let t_max = self.expected_step_time();
        let lo = 0.5 * self.accums as f64 * self.mu;
        let hi = t_max.max(lo * 1.0001);
        let mut best = (hi, self.effective_speedup(hi));
        for k in 0..=grid {
            let tau = lo + (hi - lo) * k as f64 / grid as f64;
            let s = self.effective_speedup(tau);
            if s > best.1 {
                best = (tau, s);
            }
        }
        best
    }

    /// Expected drop rate at threshold: `1 - E[M~]/M`.
    pub fn drop_rate(&self, tau: f64) -> f64 {
        1.0 - self.expected_completed(tau) / self.accums as f64
    }
}

/// Eq. 5 standalone: `E[M~(tau)] = Σ_{m=1..M} Φ((tau - m mu)/(sqrt(m) s))`.
pub fn expected_completed(tau: f64, accums: usize, mu: f64, sigma2: f64) -> f64 {
    let sigma = sigma2.max(0.0).sqrt();
    (1..=accums)
        .map(|m| {
            let mf = m as f64;
            if sigma == 0.0 {
                if tau > mf * mu {
                    1.0
                } else {
                    0.0
                }
            } else {
                phi((tau - mf * mu) / (mf.sqrt() * sigma))
            }
        })
        .sum()
}

/// Scale-law point: throughput of one setting relative to one worker —
/// the Fig 1 scale graph ordinate. Perfect scaling doubles throughput
/// with N; stragglers bend the curve.
pub fn scaling_efficiency(setting: &Setting) -> f64 {
    // single-worker iteration time: E[T_n] + T^c
    let single = setting.accums as f64 * setting.mu + setting.comm;
    let cluster = setting.expected_step_time() + setting.comm;
    single / cluster
}

/// Fig 1-right: extrapolated speedup of DropCompute(tau*) over baseline
/// as N grows, holding per-worker statistics fixed.
pub fn extrapolate_speedup(base: &Setting, ns: &[usize], grid: usize)
    -> Vec<(usize, f64)>
{
    ns.iter()
        .map(|&n| {
            let s = Setting { workers: n, ..*base };
            let (_, speed) = s.optimal_threshold(grid);
            (n, speed)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Normal, Xoshiro256pp};

    fn setting() -> Setting {
        Setting {
            workers: 64,
            accums: 12,
            mu: 0.45,
            sigma2: 0.05,
            comm: 0.5,
        }
    }

    #[test]
    fn expected_completed_monte_carlo() {
        // Eq. 5 vs simulation with normal micro-batch latencies.
        let s = setting();
        let d = Normal::new(s.mu, s.sigma2.sqrt());
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        for tau in [3.0, 4.5, 5.4, 6.0] {
            let mut done = 0usize;
            let reps = 40_000;
            for _ in 0..reps {
                let mut t = 0.0;
                for _ in 0..s.accums {
                    t += d.sample(&mut rng).max(0.0);
                    if t < tau {
                        done += 1;
                    }
                }
            }
            let mc = done as f64 / reps as f64;
            let analytic = s.expected_completed(tau);
            assert!(
                (mc - analytic).abs() < 0.05,
                "tau={tau}: mc {mc} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn completed_limits() {
        let s = setting();
        // huge threshold -> all M complete; near-zero threshold -> ~none
        // (the CLT form keeps a little sub-zero Gaussian mass, cf. the
        // Markov-bound discussion around Eq. 8).
        assert!((s.expected_completed(1e9) - 12.0).abs() < 1e-9);
        assert!(s.expected_completed(1e-9) < 0.05);
        // monotone in tau
        let mut prev = 0.0;
        for k in 1..40 {
            let v = s.expected_completed(k as f64 * 0.2);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
    }

    #[test]
    fn speedup_one_at_infinite_threshold() {
        let s = setting();
        let t = s.expected_step_time();
        // tau >= T: no drops, no time saved -> S_eff == 1.
        let speed = s.effective_speedup(t * 1.5);
        assert!((speed - 1.0).abs() < 1e-3, "{speed}");
    }

    #[test]
    fn speedup_has_interior_maximum() {
        // Fig 3c: S_eff rises then falls as tau decreases from T.
        let s = Setting { sigma2: 0.15, ..setting() };
        let (tau_star, best) = s.optimal_threshold(512);
        assert!(best > 1.0, "optimal speedup {best} should beat baseline");
        let t = s.expected_step_time();
        assert!(tau_star < t, "tau* {tau_star} below E[T] {t}");
        // speedup at much lower tau is worse than at tau*
        let low = s.effective_speedup(0.55 * s.accums as f64 * s.mu);
        assert!(low < best);
    }

    #[test]
    fn speedup_grows_with_workers() {
        // §4.4: E[S_eff](N) -> infinity as N -> infinity.
        let base = setting();
        let speeds = extrapolate_speedup(&base, &[8, 64, 512, 4096], 256);
        for w in speeds.windows(2) {
            assert!(
                w[1].1 >= w[0].1 - 1e-9,
                "speedup should be nondecreasing in N: {speeds:?}"
            );
        }
        assert!(speeds.last().unwrap().1 > speeds[0].1 + 0.01);
    }

    #[test]
    fn scaling_efficiency_degrades_with_noise() {
        let quiet = Setting { sigma2: 1e-6, ..setting() };
        let noisy = Setting { sigma2: 0.3, ..setting() };
        assert!(scaling_efficiency(&quiet) > scaling_efficiency(&noisy));
        assert!(scaling_efficiency(&quiet) <= 1.0 + 1e-9);
    }

    #[test]
    fn drop_rate_tracks_completed() {
        let s = setting();
        let tau = 5.0;
        let r = s.drop_rate(tau);
        assert!((r - (1.0 - s.expected_completed(tau) / 12.0)).abs() < 1e-12);
        assert!((0.0..=1.0).contains(&r));
    }

    #[test]
    fn end_to_end_against_cluster_sim() {
        // The analytical S_eff must match the virtual-clock simulator
        // within tolerance under Gaussian noise (Fig 3a's agreement).
        use crate::config::{ClusterConfig, NoiseKind};
        use crate::sim::ClusterSim;
        // Noise mean is kept 4 sigma above zero so the physical floor
        // clamp never bites and Gaussian analytics apply exactly.
        let s = Setting {
            workers: 32,
            mu: 0.45 + 0.6,
            sigma2: 0.02 * 0.02 + 0.0221,
            ..setting()
        };
        let cfg = ClusterConfig {
            workers: 32,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: s.comm,
            noise: NoiseKind::Normal { mean: 0.6, var: 0.0221 },
            ..Default::default()
        };
        let tau = 12.9;
        let mut base = ClusterSim::new(&cfg, 5);
        let mut dc = ClusterSim::new(&cfg, 5);
        let iters = 400;
        let t_base = base.mean_iter_time(iters, None);
        let mut t_dc = 0.0;
        let mut completed = 0.0;
        for _ in 0..iters {
            let out = dc.step(Some(tau));
            t_dc += out.iter_time / iters as f64;
            completed += out.total_completed() as f64 / (32.0 * iters as f64);
        }
        let sim_speedup = (completed / 12.0) * t_base / t_dc;
        let analytic = s.effective_speedup(tau);
        assert!(
            (sim_speedup - analytic).abs() < 0.05,
            "sim {sim_speedup} vs analytic {analytic}"
        );
    }
}
