//! DropCompute: robust distributed synchronous training via compute
//! variance reduction (NeurIPS 2023) — reference reproduction.
//!
//! Layer 3 (this crate): the distributed-training coordinator — worker
//! pool, decentralized AllReduce, gradient-accumulation scheduler with
//! the DropCompute compute-threshold (Algorithm 1), automatic threshold
//! selection (Algorithm 2), Local-SGD mode, optimizers, data pipeline,
//! discrete-event cluster simulator (with a compiled, heapless
//! schedule-timing fast path, [`sim::CompiledSchedule`]), the
//! analytical runtime model (Eqs. 4/5/6/11), the topology-aware
//! collective engine ([`topology`]: pluggable ring / tree /
//! hierarchical / torus schedules plus the bounded-wait DropComm
//! all-reduce), the unified drop-decision surface
//! ([`policy::DropPolicy`]: compute-tau, step-level and per-phase
//! DropComm deadlines, Local-SGD periods, composed), and the
//! deterministic parallel scenario-sweep engine ([`sweep`]), and the
//! opt-in zero-overhead observability layer ([`obs`]: step probes,
//! mergeable tail histograms, straggler attribution, Prometheus/JSON
//! export).
//!
//! Layers 2/1 (build-time python): JAX transformer fwd/bwd calling
//! Pallas kernels, AOT-lowered to HLO text loaded by [`runtime`].

pub mod analysis;
pub mod cli;
pub mod collective;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod lint;
pub mod metrics;
pub mod obs;
pub mod policy;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod sweep;
pub mod topology;
pub mod train;
pub mod transport;
pub mod util;
