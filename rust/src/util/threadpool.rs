//! A minimal fixed-size thread pool (no tokio in the sandbox registry).
//!
//! The coordinator runs each simulated data-parallel worker's compute on
//! this pool; `scope`-style joins give the synchronous step barrier of
//! Eq. 1 for free.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool executing boxed jobs.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("dc-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool thread")
            })
            .collect();
        Self { sender: Some(sender), handles }
    }

    /// Pool sized to the machine (cores, capped).
    pub fn default_size() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("pool send");
    }

    /// Run `f(i)` for `i in 0..n` across the pool and collect results in
    /// order. Blocks until all complete (the synchronous-training barrier).
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        self.map_indexed_with(n, f, |_| {})
    }

    /// [`Self::map_indexed`] invoking `on_done(completed_count)` on the
    /// submitting thread as each job lands, in completion order —
    /// the hook the sweep engine's progress/ETA reporting rides on.
    pub fn map_indexed_with<T, F>(
        &self,
        n: usize,
        f: F,
        mut on_done: impl FnMut(usize),
    ) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let out = f(i);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut done = 0usize;
        for (i, out) in rx {
            slots[i] = Some(out);
            done += 1;
            on_done(done);
        }
        slots.into_iter().map(|s| s.expect("job completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_ordered() {
        let pool = ThreadPool::new(3);
        let out = pool.map_indexed(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_with_reports_completion_counts() {
        let pool = ThreadPool::new(3);
        let mut seen = Vec::new();
        let out = pool.map_indexed_with(10, |i| i, |done| seen.push(done));
        assert_eq!(out, (0..10).collect::<Vec<_>>());
        // on_done runs on the submitting thread with a monotone count
        assert_eq!(seen, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map_indexed(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
