//! Small shared utilities: errors, logging, a scoped thread pool.

mod threadpool;

pub use threadpool::ThreadPool;

use std::time::Instant;

/// Crate-wide error type (hand-rolled Display/From — the sandbox
/// registry has no thiserror).
#[derive(Debug)]
pub enum Error {
    Config(String),
    Cli(String),
    Runtime(String),
    Data(String),
    Io(std::io::Error),
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Cli(m) => write!(f, "cli error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Legacy numeric verbosity shim over [`crate::obs::log`]
/// (0 = quiet, 1 = info, 2 = debug). New code should use
/// [`crate::obs::log::set_level`] / the leveled macros directly.
pub fn set_verbosity(v: u8) {
    use crate::obs::log::Level;
    crate::obs::log::set_level(match v {
        0 => Level::Error,
        1 => Level::Info,
        _ => Level::Debug,
    });
}

/// Legacy numeric verbosity readout (see [`set_verbosity`]).
pub fn verbosity() -> u8 {
    use crate::obs::log::Level;
    match crate::obs::log::level() {
        Level::Error | Level::Warn => 0,
        Level::Info => 1,
        Level::Debug => 2,
    }
}

/// Print an info-level line (routed through [`crate::obs::log`]).
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::obs::log::log(
            $crate::obs::log::Level::Info,
            format_args!($($arg)*),
        )
    };
}

/// Print a warning line (shown unless `--quiet` drops to errors-only).
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::obs::log::log(
            $crate::obs::log::Level::Warn,
            format_args!($($arg)*),
        )
    };
}

/// Print a debug-level line (needs `-v`/`--verbose`).
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::obs::log::log(
            $crate::obs::log::Level::Debug,
            format_args!($($arg)*),
        )
    };
}

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> f64 {
        let s = self.seconds();
        self.start = Instant::now();
        s
    }
}

/// Format seconds human-readably (`1.23s`, `4m05s`).
pub fn fmt_secs(s: f64) -> String {
    if s < 60.0 {
        format!("{s:.2}s")
    } else {
        format!("{}m{:04.1}s", (s / 60.0) as u64, s % 60.0)
    }
}

/// Format a count with SI suffix (`1.2K`, `3.4M`).
pub fn fmt_count(n: f64) -> String {
    let a = n.abs();
    if a >= 1e9 {
        format!("{:.2}B", n / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", n / 1e6)
    } else if a >= 1e3 {
        format!("{:.1}K", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(1.234), "1.23s");
        assert_eq!(fmt_secs(65.0), "1m05.0s");
        assert_eq!(fmt_count(1_500.0), "1.5K");
        assert_eq!(fmt_count(2_500_000.0), "2.50M");
        assert_eq!(fmt_count(12.0), "12");
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.seconds() >= 0.004);
    }
}
