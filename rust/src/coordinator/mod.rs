//! The DropCompute coordinator: decentralized calibration + scale runs.
//!
//! [`decentralized_calibration`] demonstrates the paper's key systems
//! property (§2 "Redundancy methods"): unlike parameter-server designs,
//! no central entity decides who participates. Each worker thread
//! measures its own latencies, the empirical distributions are exchanged
//! with an AllGather over the real ring collective, and every worker
//! independently runs the same argmax (Algorithm 2) — consensus on
//! `tau*` follows from determinism, which the tests assert bitwise.
//!
//! [`ScaleRun`] drives the throughput-vs-N sweeps behind Figs 1/13/14
//! and, with a [`crate::topology::TopologyKind`] + DropComm deadline in
//! its base config, the `benches/topology_ablation.rs` four-way sweep
//! (no-drop / DropCompute / DropComm / both).

use std::thread;

use crate::analysis::{choose_threshold, ThresholdChoice};
use crate::collective::{all_gather_varlen, Communicator};
use crate::config::ClusterConfig;
use crate::sim::{ClusterSim, Trace};

/// One worker's calibration measurements: its own micro-batch latencies
/// for `I` iterations (what it would measure with real clocks).
#[derive(Debug, Clone)]
pub struct WorkerSamples {
    pub worker: usize,
    /// `[iter][accum]` latencies flattened row-major.
    pub latencies: Vec<f64>,
    pub iters: usize,
    pub accums: usize,
    pub comm: Vec<f64>,
}

impl WorkerSamples {
    /// Extract worker `n`'s view from a recorded trace.
    pub fn from_trace(trace: &Trace, n: usize) -> Self {
        let mut latencies = Vec::with_capacity(trace.iters * trace.accums);
        for i in 0..trace.iters {
            for m in 0..trace.accums {
                latencies.push(trace.get(i, n, m));
            }
        }
        Self {
            worker: n,
            latencies,
            iters: trace.iters,
            accums: trace.accums,
            comm: trace.comm.clone(),
        }
    }
}

/// Rebuild the full trace from all workers' gathered samples.
fn assemble_trace(all: &[Vec<f64>], iters: usize, accums: usize, comm: &[f64])
    -> Trace
{
    let workers = all.len();
    let mut trace = Trace::new(iters, workers, accums);
    for (n, lat) in all.iter().enumerate() {
        assert_eq!(lat.len(), iters * accums, "worker {n} sample count");
        for i in 0..iters {
            for m in 0..accums {
                trace.set(i, n, m, lat[i * accums + m]);
            }
        }
    }
    trace.comm.copy_from_slice(&comm[..iters]);
    trace
}

/// Run Algorithm 2 decentralized: spawn one thread per worker, gather
/// the latency distributions over the ring collective, and let every
/// worker compute `tau*` independently. Returns each worker's choice
/// (the caller can assert consensus; the tests do).
pub fn decentralized_calibration(
    trace: &Trace,
    grid: usize,
) -> Vec<ThresholdChoice> {
    let n = trace.workers;
    let comms = Communicator::ring(n);
    let samples: Vec<WorkerSamples> =
        (0..n).map(|w| WorkerSamples::from_trace(trace, w)).collect();
    let iters = trace.iters;
    let accums = trace.accums;
    let comm_times = trace.comm.clone();

    let handles: Vec<_> = comms
        .into_iter()
        .zip(samples)
        .map(|(comm, mine)| {
            let comm_times = comm_times.clone();
            thread::spawn(move || {
                // 1. synchronize empirical distributions (AllGather)
                let all = all_gather_varlen(&comm, mine.latencies);
                // 2. every worker rebuilds the same global view...
                let trace = assemble_trace(&all, iters, accums, &comm_times);
                // 3. ...and runs the same deterministic argmax.
                choose_threshold(&trace, grid)
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("worker thread")).collect()
}

/// A throughput measurement at one cluster size (a Fig 1 data point).
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub workers: usize,
    /// Micro-batches per second, baseline synchronous.
    pub baseline_throughput: f64,
    /// Micro-batches per second with DropCompute at its auto threshold
    /// (dropped work excluded — this is *useful* throughput).
    pub dropcompute_throughput: f64,
    /// The auto-chosen threshold.
    pub tau: f64,
    /// Observed drop rate at that threshold.
    pub drop_rate: f64,
    /// Ideal linear scaling reference.
    pub linear_throughput: f64,
}

/// Sweep cluster sizes and measure baseline vs DropCompute throughput —
/// the engine behind Fig 1 (left), Fig 13, Fig 14 and the topology
/// ablation. The collective model (topology + DropComm deadline) rides
/// in `base` ([`ClusterConfig::topology`] /
/// [`ClusterConfig::comm_drop_deadline`]); `comm_drop_deadline` here
/// overrides the latter per run, so one base config can be swept with
/// and without bounded-wait communication.
#[derive(Debug, Clone)]
pub struct ScaleRun {
    pub base: ClusterConfig,
    pub calibration_iters: usize,
    pub measure_iters: usize,
    pub grid: usize,
    pub seed: u64,
    /// `Some(d)` forces the DropComm deadline for every measured sim
    /// (including the baseline arm); `None` keeps `base`'s setting.
    pub comm_drop_deadline: Option<f64>,
    /// Threads for [`Self::sweep`] (0 = all cores, 1 = serial). Each
    /// point derives every sim seed from `seed` alone, so the parallel
    /// sweep is bitwise identical to the serial one.
    pub jobs: usize,
}

impl Default for ScaleRun {
    fn default() -> Self {
        Self {
            base: ClusterConfig::default(),
            calibration_iters: 15,
            measure_iters: 60,
            grid: 128,
            seed: 0xF16_1,
            comm_drop_deadline: None,
            jobs: 1,
        }
    }
}

impl ScaleRun {
    /// Single-worker iteration time (the linear-scaling anchor).
    fn single_worker_iter_time(&self) -> f64 {
        let mut cfg = self.base.clone();
        cfg.workers = 1;
        let mut sim = ClusterSim::new(&cfg, self.seed ^ 1);
        sim.mean_iter_time(self.measure_iters, None)
    }

    /// Measure one cluster size.
    pub fn point(&self, workers: usize) -> ScalePoint {
        self.point_with_anchor(workers, self.single_worker_iter_time())
    }

    /// [`Self::point`] with the single-worker anchor precomputed — the
    /// anchor depends only on `self`, so a sweep computes it once
    /// instead of once per grid point (same bits either way).
    fn point_with_anchor(&self, workers: usize, single: f64) -> ScalePoint {
        let mut cfg = self.base.clone();
        cfg.workers = workers;
        if let Some(d) = self.comm_drop_deadline {
            cfg.comm_drop_deadline = d;
        }
        let m = cfg.accumulations as f64;

        // baseline — counted from completed micro-batches so that a
        // DropComm deadline's excluded workers aren't credited as
        // useful work (without drops this equals workers * m / E[t]).
        let mut out = crate::sim::StepOutcome::default();
        let mut sim = ClusterSim::new(&cfg, self.seed);
        let mut base_t_sum = 0.0;
        let mut base_completed = 0usize;
        for _ in 0..self.measure_iters {
            sim.step_into(None, &mut out);
            base_t_sum += out.iter_time;
            base_completed += out.total_completed();
        }
        let baseline_throughput = base_completed as f64 / base_t_sum;

        // DropCompute: calibrate (Algorithm 2) then measure
        let mut cal_sim = ClusterSim::new(&cfg, self.seed ^ 2);
        let trace = cal_sim.record_trace(self.calibration_iters);
        let choice = choose_threshold(&trace, self.grid);
        let mut dc_sim = ClusterSim::new(&cfg, self.seed ^ 3);
        let mut t_sum = 0.0;
        let mut completed = 0usize;
        for _ in 0..self.measure_iters {
            dc_sim.step_into(Some(choice.tau), &mut out);
            t_sum += out.iter_time;
            completed += out.total_completed();
        }
        let dropcompute_throughput = completed as f64 / t_sum;
        let drop_rate =
            1.0 - completed as f64 / (self.measure_iters * workers) as f64 / m;

        ScalePoint {
            workers,
            baseline_throughput,
            dropcompute_throughput,
            tau: choice.tau,
            drop_rate,
            linear_throughput: workers as f64 * m / single,
        }
    }

    /// Sweep a worker grid, fanning the points over the sweep engine's
    /// thread pool (`self.jobs`; 0 = all cores). [`Self::point`] is a
    /// pure function of `(self, n)`, so the output is bitwise identical
    /// to the serial order regardless of scheduling. The single-worker
    /// linear-scaling anchor is measured once for the whole sweep.
    pub fn sweep(&self, ns: &[usize]) -> Vec<ScalePoint> {
        let ns: Vec<usize> = ns.to_vec();
        let single = self.single_worker_iter_time();
        let run = std::sync::Arc::new(self.clone());
        crate::sweep::run_indexed(ns.len(), self.jobs, None, move |i| {
            run.point_with_anchor(ns[i], single)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NoiseKind;

    fn noisy_cfg() -> ClusterConfig {
        ClusterConfig {
            workers: 12,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.5,
            noise: NoiseKind::PaperLogNormal {
                mu: 4.0,
                sigma: 1.0,
                alpha: 2.0 * (4.5f64).exp(),
                beta: 5.5,
            },
            ..Default::default()
        }
    }

    #[test]
    fn decentralized_consensus_on_tau() {
        let mut sim = ClusterSim::new(&noisy_cfg(), 77);
        let trace = sim.record_trace(8);
        let choices = decentralized_calibration(&trace, 64);
        assert_eq!(choices.len(), 12);
        let tau0 = choices[0].tau;
        for c in &choices {
            assert_eq!(
                c.tau.to_bits(),
                tau0.to_bits(),
                "workers disagree on tau*"
            );
        }
        // and the consensus equals the centralized computation
        let central = choose_threshold(&trace, 64);
        assert_eq!(central.tau.to_bits(), tau0.to_bits());
    }

    #[test]
    fn parallel_sweep_bitwise_matches_serial() {
        let mut run = ScaleRun {
            base: noisy_cfg(),
            calibration_iters: 5,
            measure_iters: 10,
            grid: 32,
            seed: 9,
            ..ScaleRun::default()
        };
        let ns = [2usize, 4, 6];
        let serial = run.sweep(&ns);
        run.jobs = 3;
        let parallel = run.sweep(&ns);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.workers, b.workers);
            assert_eq!(
                a.baseline_throughput.to_bits(),
                b.baseline_throughput.to_bits()
            );
            assert_eq!(
                a.dropcompute_throughput.to_bits(),
                b.dropcompute_throughput.to_bits()
            );
            assert_eq!(a.tau.to_bits(), b.tau.to_bits());
            assert_eq!(a.drop_rate.to_bits(), b.drop_rate.to_bits());
            assert_eq!(
                a.linear_throughput.to_bits(),
                b.linear_throughput.to_bits()
            );
        }
    }

    #[test]
    fn scale_run_shapes_match_paper() {
        // Fig 1's qualitative content: under heavy-tailed noise the
        // baseline falls away from linear scaling as N grows and
        // DropCompute recovers a chunk of it.
        let run = ScaleRun {
            base: noisy_cfg(),
            calibration_iters: 10,
            measure_iters: 30,
            grid: 64,
            seed: 5,
            ..ScaleRun::default()
        };
        let pts = run.sweep(&[4, 32, 96]);
        for p in &pts {
            assert!(p.baseline_throughput <= p.linear_throughput * 1.02);
            assert!(
                p.dropcompute_throughput >= p.baseline_throughput * 0.98,
                "N={}: dc {} vs base {}",
                p.workers,
                p.dropcompute_throughput,
                p.baseline_throughput
            );
            assert!(p.drop_rate >= 0.0 && p.drop_rate < 0.5);
        }
        // scaling efficiency of the baseline degrades with N
        let eff =
            |p: &ScalePoint| p.baseline_throughput / p.linear_throughput;
        assert!(
            eff(&pts[2]) < eff(&pts[0]),
            "baseline efficiency should degrade: {:?}",
            pts.iter().map(eff).collect::<Vec<_>>()
        );
        // DropCompute's advantage grows with N
        let adv = |p: &ScalePoint| {
            p.dropcompute_throughput / p.baseline_throughput
        };
        assert!(adv(&pts[2]) > adv(&pts[0]) * 0.98);
    }
}
