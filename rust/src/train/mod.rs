//! Training: parameters, optimizers, schedules, gradient aggregation,
//! the synchronous DropCompute trainer and the Local-SGD variant.

pub mod grad;
pub mod local_sgd;
pub mod lr;
pub mod optimizer;
pub mod params;
pub mod checkpoint;
pub mod classifier;
pub mod trainer;

pub use grad::{GradAccumulator, GradNorm};
pub use local_sgd::LocalSgdTrainer;
pub use lr::lr_at;
pub use optimizer::{clip_global_norm, Optimizer, OptimizerConfig};
pub use params::ParamStore;
pub use checkpoint::Checkpoint;
pub use classifier::{train_classifier, ClassifierConfig, ClassifierRun, LrCorrection};
pub use trainer::Trainer;
