//! Learning-rate schedules (the You et al. 2019 BERT regime and friends).

use crate::config::LrSchedule;

/// Learning rate at `step` of `total` steps for base rate `lr`.
pub fn lr_at(schedule: LrSchedule, lr: f64, step: usize, total: usize) -> f64 {
    let total = total.max(1);
    let t = (step as f64 / total as f64).min(1.0);
    match schedule {
        LrSchedule::Constant => lr,
        LrSchedule::WarmupLinear { warmup_ratio } => {
            warmup_then(lr, t, warmup_ratio, |p| 1.0 - p)
        }
        LrSchedule::WarmupCosine { warmup_ratio } => warmup_then(
            lr,
            t,
            warmup_ratio,
            |p| 0.5 * (1.0 + (std::f64::consts::PI * p).cos()),
        ),
        LrSchedule::WarmupPoly { warmup_ratio, power } => {
            warmup_then(lr, t, warmup_ratio, |p| (1.0 - p).powf(power))
        }
    }
}

fn warmup_then(lr: f64, t: f64, warmup: f64, decay: impl Fn(f64) -> f64) -> f64 {
    if warmup > 0.0 && t < warmup {
        lr * t / warmup
    } else {
        let p = if warmup < 1.0 { (t - warmup) / (1.0 - warmup) } else { 1.0 };
        lr * decay(p.clamp(0.0, 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        for s in [0, 50, 100] {
            assert_eq!(lr_at(LrSchedule::Constant, 0.1, s, 100), 0.1);
        }
    }

    #[test]
    fn warmup_linear_shape() {
        let sch = LrSchedule::WarmupLinear { warmup_ratio: 0.1 };
        assert_eq!(lr_at(sch, 1.0, 0, 100), 0.0);
        assert!((lr_at(sch, 1.0, 5, 100) - 0.5).abs() < 1e-12);
        assert!((lr_at(sch, 1.0, 10, 100) - 1.0).abs() < 1e-12);
        assert!((lr_at(sch, 1.0, 55, 100) - 0.5).abs() < 1e-12);
        assert!(lr_at(sch, 1.0, 100, 100) < 1e-12);
    }

    #[test]
    fn cosine_endpoints() {
        let sch = LrSchedule::WarmupCosine { warmup_ratio: 0.0 };
        assert!((lr_at(sch, 1.0, 0, 100) - 1.0).abs() < 1e-12);
        assert!(lr_at(sch, 1.0, 100, 100) < 1e-12);
        // midpoint = 0.5
        assert!((lr_at(sch, 1.0, 50, 100) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn poly_power_two_decays_faster_than_linear() {
        let lin = LrSchedule::WarmupPoly { warmup_ratio: 0.0, power: 1.0 };
        let sq = LrSchedule::WarmupPoly { warmup_ratio: 0.0, power: 2.0 };
        let l = lr_at(lin, 1.0, 50, 100);
        let s = lr_at(sq, 1.0, 50, 100);
        assert!(s < l);
        assert!((l - 0.5).abs() < 1e-12);
    }

    #[test]
    fn never_negative_never_exceeds_base() {
        for sch in [
            LrSchedule::WarmupLinear { warmup_ratio: 0.2843 },
            LrSchedule::WarmupCosine { warmup_ratio: 0.128 },
            LrSchedule::WarmupPoly { warmup_ratio: 0.1, power: 1.0 },
        ] {
            for s in 0..=200 {
                let v = lr_at(sch, 0.006, s, 200);
                assert!((0.0..=0.006 + 1e-12).contains(&v), "{sch:?} {s} {v}");
            }
        }
    }
}
