//! Gradient aggregation under stochastic batch size.
//!
//! With DropCompute the per-step sample count is random; Theorem 4.1's
//! importance weighting (`alpha_i = b_i`) corresponds to normalizing the
//! summed gradient by the *computed* number of micro-batches. The paper
//! also evaluates normalizing by the *scheduled* count (App. B.2.2's
//! "no correction", which implicitly scales the step down by the drop
//! rate) — both are provided.

/// Normalization mode for the aggregated gradient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradNorm {
    /// Divide by micro-batches actually computed (the stochastic
    /// correction; unbiased w.r.t. Eq. 1).
    Computed,
    /// Divide by `N * M` regardless of drops (paper's "None" row).
    Scheduled,
}

/// Accumulates micro-batch gradient sums and produces the step gradient.
#[derive(Debug)]
pub struct GradAccumulator {
    sum: Vec<Vec<f32>>,
    computed: usize,
    scheduled: usize,
    pub norm: GradNorm,
    loss_sum: f64,
}

impl GradAccumulator {
    pub fn new(shapes: &[Vec<f32>], norm: GradNorm) -> Self {
        Self {
            sum: shapes.iter().map(|t| vec![0.0; t.len()]).collect(),
            computed: 0,
            scheduled: 0,
            norm,
            loss_sum: 0.0,
        }
    }

    /// Add one computed micro-batch gradient.
    pub fn add(&mut self, grads: &[Vec<f32>], loss: f64) {
        debug_assert_eq!(grads.len(), self.sum.len());
        for (s, g) in self.sum.iter_mut().zip(grads) {
            for (a, &b) in s.iter_mut().zip(g) {
                *a += b;
            }
        }
        self.computed += 1;
        self.scheduled += 1;
        self.loss_sum += loss;
    }

    /// Record a dropped micro-batch (affects `Scheduled` normalization).
    pub fn add_dropped(&mut self) {
        self.scheduled += 1;
    }

    pub fn computed(&self) -> usize {
        self.computed
    }

    pub fn scheduled(&self) -> usize {
        self.scheduled
    }

    /// Mean loss over computed micro-batches.
    pub fn mean_loss(&self) -> f64 {
        if self.computed == 0 {
            f64::NAN
        } else {
            self.loss_sum / self.computed as f64
        }
    }

    /// Finalize into the step gradient; `None` if nothing was computed
    /// (the step must then be skipped — consensus preserved since every
    /// worker sees the same all-reduced count).
    pub fn finalize(mut self) -> Option<(Vec<Vec<f32>>, f64)> {
        if self.computed == 0 {
            return None;
        }
        let denom = match self.norm {
            GradNorm::Computed => self.computed,
            GradNorm::Scheduled => self.scheduled,
        } as f32;
        for s in self.sum.iter_mut() {
            for x in s.iter_mut() {
                *x /= denom;
            }
        }
        let loss = self.loss_sum / self.computed as f64;
        Some((self.sum, loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<Vec<f32>> {
        vec![vec![0.0; 3], vec![0.0; 2]]
    }

    #[test]
    fn computed_normalization_is_mean() {
        let mut acc = GradAccumulator::new(&shapes(), GradNorm::Computed);
        acc.add(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0]], 1.0);
        acc.add(&[vec![3.0, 2.0, 1.0], vec![0.0, 1.0]], 3.0);
        acc.add_dropped();
        let (g, loss) = acc.finalize().unwrap();
        assert_eq!(g[0], vec![2.0, 2.0, 2.0]);
        assert_eq!(g[1], vec![2.0, 3.0]);
        assert_eq!(loss, 2.0);
    }

    #[test]
    fn scheduled_normalization_shrinks_with_drops() {
        let mut acc = GradAccumulator::new(&shapes(), GradNorm::Scheduled);
        acc.add(&[vec![2.0, 2.0, 2.0], vec![2.0, 2.0]], 1.0);
        acc.add_dropped(); // scheduled 2, computed 1
        let (g, _) = acc.finalize().unwrap();
        assert_eq!(g[0], vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn all_dropped_yields_none() {
        let mut acc = GradAccumulator::new(&shapes(), GradNorm::Computed);
        acc.add_dropped();
        acc.add_dropped();
        assert!(acc.finalize().is_none());
    }

    #[test]
    fn counts_tracked() {
        let mut acc = GradAccumulator::new(&shapes(), GradNorm::Computed);
        acc.add(&[vec![0.0; 3], vec![0.0; 2]], 0.5);
        acc.add_dropped();
        assert_eq!(acc.computed(), 1);
        assert_eq!(acc.scheduled(), 2);
        assert_eq!(acc.mean_loss(), 0.5);
    }
}
