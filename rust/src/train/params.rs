//! Flat parameter store: init, vector algebra, (de)flattening.
//!
//! Initialization reproduces `model.py::param_specs` hints so a Rust-side
//! init gives the same statistics as the JAX reference (python never runs
//! at training time).

use crate::rng::Xoshiro256pp;
use crate::runtime::{InitKind, Manifest};

/// All model parameters as per-tensor flat `Vec<f32>`s.
#[derive(Debug, Clone)]
pub struct ParamStore {
    tensors: Vec<Vec<f32>>,
    /// Total element count.
    numel: usize,
}

impl ParamStore {
    /// Initialize from manifest init hints with a seeded RNG.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x9E37_79B9);
        let tensors: Vec<Vec<f32>> = manifest
            .params
            .iter()
            .map(|spec| {
                let n = spec.numel();
                match spec.init {
                    InitKind::Zeros => vec![0.0; n],
                    InitKind::Ones => vec![1.0; n],
                    InitKind::Normal => (0..n)
                        .map(|_| {
                            (rng.next_standard_normal() * spec.scale) as f32
                        })
                        .collect(),
                }
            })
            .collect();
        let numel = tensors.iter().map(Vec::len).sum();
        Self { tensors, numel }
    }

    /// Zeros with the same shapes (gradient accumulators etc).
    pub fn zeros_like(&self) -> Self {
        Self {
            tensors: self.tensors.iter().map(|t| vec![0.0; t.len()]).collect(),
            numel: self.numel,
        }
    }

    pub fn tensors(&self) -> &[Vec<f32>] {
        &self.tensors
    }

    pub fn tensors_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.tensors
    }

    pub fn numel(&self) -> usize {
        self.numel
    }

    /// `self += alpha * grads` (per-tensor).
    pub fn axpy(&mut self, alpha: f32, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), self.tensors.len());
        for (t, g) in self.tensors.iter_mut().zip(grads) {
            debug_assert_eq!(t.len(), g.len());
            for (x, &d) in t.iter_mut().zip(g) {
                *x += alpha * d;
            }
        }
    }

    /// Global L2 norm across all tensors.
    pub fn global_norm(tensors: &[Vec<f32>]) -> f64 {
        tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Flatten all tensors into one contiguous vector (AllReduce layout).
    pub fn flatten(tensors: &[Vec<f32>]) -> Vec<f32> {
        let mut out = Vec::with_capacity(tensors.iter().map(Vec::len).sum());
        for t in tensors {
            out.extend_from_slice(t);
        }
        out
    }

    /// Inverse of [`flatten`]: scatter a flat buffer back into tensors.
    pub fn unflatten(flat: &[f32], like: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(like.len());
        let mut off = 0;
        for t in like {
            out.push(flat[off..off + t.len()].to_vec());
            off += t.len();
        }
        assert_eq!(off, flat.len(), "flatten length mismatch");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn store() -> (ParamStore, Manifest) {
        let m = Manifest::load(&PathBuf::from("artifacts"), "test").unwrap();
        (ParamStore::init(&m, 42), m)
    }

    #[test]
    fn init_respects_specs() {
        let (s, m) = store();
        assert_eq!(s.numel(), m.param_count);
        for (t, spec) in s.tensors().iter().zip(&m.params) {
            assert_eq!(t.len(), spec.numel());
            match spec.init {
                InitKind::Zeros => assert!(t.iter().all(|&x| x == 0.0)),
                InitKind::Ones => assert!(t.iter().all(|&x| x == 1.0)),
                InitKind::Normal => {
                    let mean: f32 = t.iter().sum::<f32>() / t.len() as f32;
                    let var: f32 = t.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                        / t.len() as f32;
                    assert!(mean.abs() < 0.02, "{}: mean {mean}", spec.name);
                    let want = (spec.scale * spec.scale) as f32;
                    assert!(
                        (var - want).abs() < 0.3 * want.max(1e-8),
                        "{}: var {var} vs {want}",
                        spec.name
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_across_seeds() {
        let m = Manifest::load(&PathBuf::from("artifacts"), "test").unwrap();
        let a = ParamStore::init(&m, 7);
        let b = ParamStore::init(&m, 7);
        let c = ParamStore::init(&m, 8);
        assert_eq!(a.tensors(), b.tensors());
        assert_ne!(a.tensors()[0], c.tensors()[0]);
    }

    #[test]
    fn flatten_roundtrip() {
        let (s, _) = store();
        let flat = ParamStore::flatten(s.tensors());
        assert_eq!(flat.len(), s.numel());
        let back = ParamStore::unflatten(&flat, s.tensors());
        assert_eq!(back, s.tensors());
    }

    #[test]
    fn axpy_and_norm() {
        let (mut s, _) = store();
        let before = ParamStore::global_norm(s.tensors());
        let grads: Vec<Vec<f32>> =
            s.tensors().iter().map(|t| vec![1.0; t.len()]).collect();
        s.axpy(0.0, &grads);
        assert_eq!(ParamStore::global_norm(s.tensors()), before);
        let mut z = s.zeros_like();
        z.axpy(2.0, &grads);
        let n = ParamStore::global_norm(z.tensors());
        assert!((n - 2.0 * (s.numel() as f64).sqrt()).abs() < 1e-6);
    }
}
