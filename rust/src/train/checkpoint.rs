//! Checkpointing: save/restore parameters + training progress.
//!
//! Format: a small self-describing binary (`DCKP` magic, version,
//! step/seed metadata, then per-tensor f32 payloads with names and
//! lengths). Written atomically (temp file + rename) so a straggling or
//! killed leader never leaves a torn checkpoint — the same failure mode
//! DropCompute is about at the step level.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::runtime::Manifest;
use crate::util::{Error, Result};

use super::params::ParamStore;

const MAGIC: &[u8; 4] = b"DCKP";
const VERSION: u32 = 1;

/// Checkpoint payload: the model plus loop state to resume.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: usize,
    pub seed: u64,
    pub virtual_time: f64,
    pub tensors: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn from_params(
        manifest: &Manifest,
        params: &ParamStore,
        step: usize,
        seed: u64,
        virtual_time: f64,
    ) -> Self {
        let tensors = manifest
            .params
            .iter()
            .zip(params.tensors())
            .map(|(spec, t)| (spec.name.clone(), t.clone()))
            .collect();
        Self { step, seed, virtual_time, tensors }
    }

    /// Restore into a ParamStore, validating names and shapes against
    /// the manifest (refuses silently-wrong restores).
    pub fn into_params(self, manifest: &Manifest) -> Result<ParamStore> {
        if self.tensors.len() != manifest.params.len() {
            return Err(Error::Runtime(format!(
                "checkpoint has {} tensors, manifest {}",
                self.tensors.len(),
                manifest.params.len()
            )));
        }
        let mut store = ParamStore::init(manifest, self.seed);
        for ((spec, slot), (name, data)) in manifest
            .params
            .iter()
            .zip(store.tensors_mut())
            .zip(self.tensors)
        {
            if spec.name != name {
                return Err(Error::Runtime(format!(
                    "checkpoint tensor `{name}` where manifest expects `{}`",
                    spec.name
                )));
            }
            if spec.numel() != data.len() {
                return Err(Error::Runtime(format!(
                    "tensor `{name}`: {} elements, expected {}",
                    data.len(),
                    spec.numel()
                )));
            }
            *slot = data;
        }
        Ok(store)
    }

    /// Atomic save: write to `<path>.tmp`, fsync, rename.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let file = std::fs::File::create(&tmp)?;
            let mut w = BufWriter::new(file);
            w.write_all(MAGIC)?;
            w.write_all(&VERSION.to_le_bytes())?;
            w.write_all(&(self.step as u64).to_le_bytes())?;
            w.write_all(&self.seed.to_le_bytes())?;
            w.write_all(&self.virtual_time.to_le_bytes())?;
            w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
            for (name, data) in &self.tensors {
                let nb = name.as_bytes();
                w.write_all(&(nb.len() as u32).to_le_bytes())?;
                w.write_all(nb)?;
                w.write_all(&(data.len() as u64).to_le_bytes())?;
                // little-endian f32 payload
                for &x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            w.flush()?;
            w.get_ref().sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Runtime("not a DropCompute checkpoint".into()));
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            return Err(Error::Runtime(format!(
                "checkpoint version {version}, expected {VERSION}"
            )));
        }
        let step = read_u64(&mut r)? as usize;
        let seed = read_u64(&mut r)?;
        let virtual_time = f64::from_le_bytes(read_bytes::<8>(&mut r)?);
        let count = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            let mut nb = vec![0u8; name_len];
            r.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)
                .map_err(|_| Error::Runtime("bad tensor name".into()))?;
            let len = read_u64(&mut r)? as usize;
            let mut bytes = vec![0u8; len * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push((name, data));
        }
        Ok(Self { step, seed, virtual_time, tensors })
    }
}

fn read_bytes<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_bytes::<4>(r)?))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    Ok(u64::from_le_bytes(read_bytes::<8>(r)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        Manifest::load(&PathBuf::from("artifacts"), "test").unwrap()
    }

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dc_ckpt_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_exact() {
        let m = manifest();
        let params = ParamStore::init(&m, 3);
        let ckpt = Checkpoint::from_params(&m, &params, 42, 3, 123.5);
        let path = tmpdir("roundtrip").join("c.dckp");
        ckpt.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ckpt);
        let restored = loaded.into_params(&m).unwrap();
        assert_eq!(restored.tensors(), params.tensors());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let dir = tmpdir("garbage");
        let bad = dir.join("bad.dckp");
        std::fs::write(&bad, b"NOPE").unwrap();
        assert!(Checkpoint::load(&bad).is_err());
        // truncated real checkpoint
        let m = manifest();
        let ckpt =
            Checkpoint::from_params(&m, &ParamStore::init(&m, 0), 1, 0, 0.0);
        let good = dir.join("good.dckp");
        ckpt.save(&good).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        let trunc = dir.join("trunc.dckp");
        std::fs::write(&trunc, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&trunc).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn refuses_mismatched_manifest() {
        let m = manifest();
        let mut ckpt =
            Checkpoint::from_params(&m, &ParamStore::init(&m, 0), 1, 0, 0.0);
        ckpt.tensors[0].0 = "wrong_name".into();
        assert!(ckpt.into_params(&m).is_err());
        let mut ckpt2 =
            Checkpoint::from_params(&m, &ParamStore::init(&m, 0), 1, 0, 0.0);
        ckpt2.tensors[0].1.pop();
        assert!(ckpt2.into_params(&m).is_err());
    }

    #[test]
    fn resume_training_continues_descent() {
        // Save mid-run, restore into a fresh trainer, keep training: the
        // loss must continue from (not reset to) the checkpointed level.
        crate::util::set_verbosity(0);
        let mut cfg = crate::config::Config::default();
        cfg.train.model_size = "test".into();
        cfg.train.steps = 6;
        cfg.train.lr = 3e-3;
        cfg.train.log_every = 1000;
        cfg.cluster.workers = 3;
        cfg.cluster.accumulations = 2;
        let mut t1 = crate::train::Trainer::new(&cfg).unwrap();
        let log1 = t1.train().unwrap();
        let m = manifest();
        let path = tmpdir("resume").join("mid.dckp");
        Checkpoint::from_params(&m, &t1.params, 6, cfg.train.seed, 0.0)
            .save(&path)
            .unwrap();

        let mut t2 = crate::train::Trainer::new(&cfg).unwrap();
        t2.params =
            Checkpoint::load(&path).unwrap().into_params(&m).unwrap();
        let rec = t2.train_step(6).unwrap();
        assert!(
            rec.loss < log1.steps[0].loss * 0.98,
            "resumed loss {} should continue below the fresh-start {}",
            rec.loss,
            log1.steps[0].loss
        );
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
