//! Update rules: SGD / Momentum / Adam / AdamW / LAMB / LARS / LANS.
//!
//! The paper's experiments use LAMB (BERT-Large pretraining, You et al.
//! 2019), LANS (BERT-1.5B, Zheng et al. 2020), SGD+momentum (ResNet-50,
//! Goyal et al. 2017) and LARS (MLPerf regime) — all are implemented so
//! every generalization experiment runs with its original optimizer
//! family. All state lives Rust-side over the flat parameter tensors.

use crate::config::OptimizerKind;
use crate::runtime::Manifest;

use super::params::ParamStore;

/// Hyper-parameters common across rules.
#[derive(Debug, Clone, Copy)]
pub struct OptimizerConfig {
    pub kind: OptimizerKind,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub momentum: f64,
    /// LARS/LAMB trust-ratio clamp.
    pub trust_clip: f64,
}

impl OptimizerConfig {
    pub fn new(kind: OptimizerKind, weight_decay: f64) -> Self {
        Self {
            kind,
            weight_decay,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            momentum: 0.9,
            trust_clip: 10.0,
        }
    }
}

/// Optimizer with per-tensor state.
pub struct Optimizer {
    cfg: OptimizerConfig,
    /// First moment / momentum buffers.
    m: Vec<Vec<f32>>,
    /// Second moment buffers (adaptive rules only).
    v: Vec<Vec<f32>>,
    /// Which tensors receive weight decay.
    decayed: Vec<bool>,
    step: u64,
}

impl Optimizer {
    pub fn new(cfg: OptimizerConfig, manifest: &Manifest, params: &ParamStore)
        -> Self
    {
        let need_v = matches!(
            cfg.kind,
            OptimizerKind::Adam
                | OptimizerKind::AdamW
                | OptimizerKind::Lamb
                | OptimizerKind::Lans
        );
        Self {
            cfg,
            m: params.zeros_like().tensors().to_vec(),
            v: if need_v {
                params.zeros_like().tensors().to_vec()
            } else {
                Vec::new()
            },
            decayed: manifest.params.iter().map(|p| p.decayed()).collect(),
            step: 0,
        }
    }

    pub fn kind(&self) -> OptimizerKind {
        self.cfg.kind
    }

    /// Apply one update with learning rate `lr` and gradients `grads`.
    pub fn step(&mut self, params: &mut ParamStore, grads: &[Vec<f32>], lr: f64) {
        self.step += 1;
        match self.cfg.kind {
            OptimizerKind::Sgd => self.sgd(params, grads, lr),
            OptimizerKind::Momentum => self.momentum(params, grads, lr),
            OptimizerKind::Adam => self.adam(params, grads, lr, false, false),
            OptimizerKind::AdamW => self.adam(params, grads, lr, true, false),
            OptimizerKind::Lamb => self.adam(params, grads, lr, true, true),
            OptimizerKind::Lars => self.lars(params, grads, lr),
            OptimizerKind::Lans => self.lans(params, grads, lr),
        }
    }

    fn sgd(&mut self, params: &mut ParamStore, grads: &[Vec<f32>], lr: f64) {
        let wd = self.cfg.weight_decay as f32;
        for (i, (t, g)) in
            params.tensors_mut().iter_mut().zip(grads).enumerate()
        {
            let decay = if self.decayed[i] { wd } else { 0.0 };
            for (x, &gx) in t.iter_mut().zip(g) {
                *x -= (lr as f32) * (gx + decay * *x);
            }
        }
    }

    fn momentum(&mut self, params: &mut ParamStore, grads: &[Vec<f32>], lr: f64) {
        let mu = self.cfg.momentum as f32;
        let wd = self.cfg.weight_decay as f32;
        for (i, (t, g)) in
            params.tensors_mut().iter_mut().zip(grads).enumerate()
        {
            let decay = if self.decayed[i] { wd } else { 0.0 };
            for ((x, &gx), m) in t.iter_mut().zip(g).zip(self.m[i].iter_mut()) {
                *m = mu * *m + gx + decay * *x;
                *x -= (lr as f32) * *m;
            }
        }
    }

    /// Adam family. `decoupled_wd` = AdamW-style decay;
    /// `trust_ratio` = LAMB layer-wise adaptation.
    fn adam(
        &mut self,
        params: &mut ParamStore,
        grads: &[Vec<f32>],
        lr: f64,
        decoupled_wd: bool,
        trust_ratio: bool,
    ) {
        let (b1, b2) = (self.cfg.beta1 as f32, self.cfg.beta2 as f32);
        let eps = self.cfg.eps as f32;
        let wd = self.cfg.weight_decay as f32;
        let bc1 = 1.0 - (self.cfg.beta1).powi(self.step as i32) as f32;
        let bc2 = 1.0 - (self.cfg.beta2).powi(self.step as i32) as f32;
        for (i, (t, g)) in
            params.tensors_mut().iter_mut().zip(grads).enumerate()
        {
            let decay = if self.decayed[i] { wd } else { 0.0 };
            // update moments + build raw update direction
            let mut upd = vec![0.0f32; t.len()];
            for (j, (&gx, x)) in g.iter().zip(t.iter()).enumerate() {
                let gx = if decoupled_wd { gx } else { gx + decay * *x };
                let m = &mut self.m[i][j];
                let v = &mut self.v[i][j];
                *m = b1 * *m + (1.0 - b1) * gx;
                *v = b2 * *v + (1.0 - b2) * gx * gx;
                let mhat = *m / bc1;
                let vhat = *v / bc2;
                upd[j] = mhat / (vhat.sqrt() + eps);
                if decoupled_wd {
                    upd[j] += decay * *x;
                }
            }
            let ratio = if trust_ratio {
                trust(t, &upd, self.cfg.trust_clip as f32)
            } else {
                1.0
            };
            for (x, &u) in t.iter_mut().zip(&upd) {
                *x -= (lr as f32) * ratio * u;
            }
        }
    }

    fn lars(&mut self, params: &mut ParamStore, grads: &[Vec<f32>], lr: f64) {
        let mu = self.cfg.momentum as f32;
        let wd = self.cfg.weight_decay as f32;
        for (i, (t, g)) in
            params.tensors_mut().iter_mut().zip(grads).enumerate()
        {
            let decay = if self.decayed[i] { wd } else { 0.0 };
            let upd: Vec<f32> =
                g.iter().zip(t.iter()).map(|(&gx, &x)| gx + decay * x).collect();
            let ratio = trust(t, &upd, self.cfg.trust_clip as f32);
            for ((x, &u), m) in t.iter_mut().zip(&upd).zip(self.m[i].iter_mut())
            {
                *m = mu * *m + ratio * u;
                *x -= (lr as f32) * *m;
            }
        }
    }

    /// LANS (Zheng et al. 2020): Nesterov-style LAMB — the BERT-1.5B
    /// optimizer of the paper's runtime experiments (App. B.1).
    fn lans(&mut self, params: &mut ParamStore, grads: &[Vec<f32>], lr: f64) {
        let (b1, b2) = (self.cfg.beta1 as f32, self.cfg.beta2 as f32);
        let eps = self.cfg.eps as f32;
        let wd = self.cfg.weight_decay as f32;
        let bc1 = 1.0 - (self.cfg.beta1).powi(self.step as i32) as f32;
        let bc2 = 1.0 - (self.cfg.beta2).powi(self.step as i32) as f32;
        for (i, (t, g)) in
            params.tensors_mut().iter_mut().zip(grads).enumerate()
        {
            let decay = if self.decayed[i] { wd } else { 0.0 };
            // normalize the gradient per tensor (LANS step 1)
            let gnorm = (g.iter().map(|&x| x * x).sum::<f32>()).sqrt().max(eps);
            let mut upd_m = vec![0.0f32; t.len()];
            let mut upd_g = vec![0.0f32; t.len()];
            for (j, (&graw, x)) in g.iter().zip(t.iter()).enumerate() {
                let gx = graw / gnorm;
                let m = &mut self.m[i][j];
                let v = &mut self.v[i][j];
                *m = b1 * *m + (1.0 - b1) * gx;
                *v = b2 * *v + (1.0 - b2) * gx * gx;
                let denom = (*v / bc2).sqrt() + eps;
                upd_m[j] = (*m / bc1) / denom + decay * *x;
                upd_g[j] = gx / denom + decay * *x;
            }
            let r_m = trust(t, &upd_m, self.cfg.trust_clip as f32);
            let r_g = trust(t, &upd_g, self.cfg.trust_clip as f32);
            for ((x, &um), &ug) in t.iter_mut().zip(&upd_m).zip(&upd_g) {
                *x -= (lr as f32) * (b1 * r_m * um + (1.0 - b1) * r_g * ug);
            }
        }
    }
}

/// Layer-wise trust ratio `phi(||w||)/||u||` with clamping (LARS/LAMB).
fn trust(w: &[f32], upd: &[f32], clip: f32) -> f32 {
    let wn = (w.iter().map(|&x| x * x).sum::<f32>()).sqrt();
    let un = (upd.iter().map(|&x| x * x).sum::<f32>()).sqrt();
    if wn > 0.0 && un > 0.0 {
        (wn / un).min(clip)
    } else {
        1.0
    }
}

/// Clip gradients by global norm (returns pre-clip norm).
pub fn clip_global_norm(grads: &mut [Vec<f32>], max_norm: f64) -> f64 {
    let norm = ParamStore::global_norm(grads);
    if max_norm > 0.0 && norm > max_norm {
        let scale = (max_norm / norm) as f32;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn setup(kind: OptimizerKind) -> (Optimizer, ParamStore, Manifest) {
        let m = Manifest::load(&PathBuf::from("artifacts"), "test").unwrap();
        let p = ParamStore::init(&m, 1);
        let opt = Optimizer::new(OptimizerConfig::new(kind, 0.01), &m, &p);
        (opt, p, m)
    }

    /// Quadratic sanity: every optimizer must reduce ||w||^2 given
    /// grads = w (loss = ||w||^2/2).
    #[test]
    fn all_optimizers_descend_quadratic() {
        for kind in [
            OptimizerKind::Sgd,
            OptimizerKind::Momentum,
            OptimizerKind::Adam,
            OptimizerKind::AdamW,
            OptimizerKind::Lamb,
            OptimizerKind::Lars,
            OptimizerKind::Lans,
        ] {
            let (mut opt, mut p, _) = setup(kind);
            let before = ParamStore::global_norm(p.tensors());
            for _ in 0..20 {
                let grads: Vec<Vec<f32>> = p.tensors().to_vec();
                opt.step(&mut p, &grads, 1e-2);
            }
            let after = ParamStore::global_norm(p.tensors());
            assert!(after < before, "{kind:?}: {before} -> {after}");
        }
    }

    #[test]
    fn sgd_matches_manual_update() {
        let (mut opt, mut p, m) = setup(OptimizerKind::Sgd);
        // pick a decayed tensor (attn.wq), not a LayerNorm scale
        let idx = m.params.iter().position(|s| s.decayed()).unwrap();
        let w0 = p.tensors()[idx][0];
        let grads: Vec<Vec<f32>> =
            p.tensors().iter().map(|t| vec![0.5; t.len()]).collect();
        opt.step(&mut p, &grads, 0.1);
        let want = w0 - 0.1 * (0.5 + 0.01 * w0);
        assert!((p.tensors()[idx][0] - want).abs() < 1e-7);
    }

    #[test]
    fn no_decay_on_norm_tensors() {
        // With zero gradients, non-decayed tensors must not move under
        // SGD; decayed ones shrink.
        let (mut opt, mut p, m) = setup(OptimizerKind::Sgd);
        let zeros: Vec<Vec<f32>> =
            p.tensors().iter().map(|t| vec![0.0; t.len()]).collect();
        let before = p.tensors().to_vec();
        opt.step(&mut p, &zeros, 0.1);
        for ((spec, t0), t1) in
            m.params.iter().zip(&before).zip(p.tensors())
        {
            if spec.decayed() {
                // shrinks multiplicatively
                for (a, b) in t0.iter().zip(t1) {
                    assert!((b - a * (1.0 - 0.1 * 0.01)).abs() < 1e-7);
                }
            } else {
                assert_eq!(t0, t1, "{}", spec.name);
            }
        }
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // First Adam step with grad g moves by ~lr*sign(g) regardless of
        // magnitude (bias-corrected mhat/sqrt(vhat) = sign at step 1).
        let (mut opt, mut p, _) = setup(OptimizerKind::Adam);
        let w0 = p.tensors()[2][0];
        let grads: Vec<Vec<f32>> =
            p.tensors().iter().map(|t| vec![1e-3; t.len()]).collect();
        opt.step(&mut p, &grads, 0.01);
        let moved = w0 - p.tensors()[2][0];
        assert!((moved - 0.01).abs() < 2e-3, "moved {moved}");
    }

    #[test]
    fn lamb_trust_ratio_bounds_update() {
        let (mut opt, mut p, _) = setup(OptimizerKind::Lamb);
        let before = p.tensors().to_vec();
        // gigantic gradients: LAMB normalizes by trust ratio
        let grads: Vec<Vec<f32>> =
            p.tensors().iter().map(|t| vec![1e6; t.len()]).collect();
        opt.step(&mut p, &grads, 0.01);
        for (t0, t1) in before.iter().zip(p.tensors()) {
            let wn = (t0.iter().map(|&x| x * x).sum::<f32>()).sqrt();
            if wn == 0.0 {
                // zero-norm tensors (fresh biases) get ratio 1 by
                // definition; the trust bound doesn't apply.
                continue;
            }
            let dn = (t0
                .iter()
                .zip(t1)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>())
            .sqrt();
            // ||delta|| <= lr * ||w|| (trust ratio r = ||w||/||u||)
            assert!(dn <= 0.0101 * wn + 1e-6, "{dn} vs {wn}");
        }
    }

    #[test]
    fn clip_global_norm_scales() {
        let mut g = vec![vec![3.0f32, 4.0]];
        let norm = clip_global_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let after = ParamStore::global_norm(&g);
        assert!((after - 1.0).abs() < 1e-6);
        // below threshold: untouched
        let mut g2 = vec![vec![0.3f32, 0.4]];
        clip_global_norm(&mut g2, 1.0);
        assert_eq!(g2[0], vec![0.3, 0.4]);
    }
}
