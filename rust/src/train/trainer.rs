//! The synchronous data-parallel trainer with DropCompute (Algorithm 1).
//!
//! Semantics are exact data-parallelism: `N` simulated workers each own a
//! data shard and schedule `M` micro-batches per step; *which* micro-
//! batches survive is decided by the virtual-time cluster simulator
//! (drop decisions = Algorithm 1 with the configured noise model), and
//! the surviving ones are *really computed* through the PJRT artifacts.
//! Wall-clock compute is therefore proportional to surviving work while
//! iteration *time* follows the paper's timing model — the same
//! methodology the paper uses (post-analysis + simulated delay).
//!
//! Compensation (§4.5): extra steps, increased batch, resampling.

use std::path::Path;

use crate::analysis::{choose_threshold, threshold_for_drop_rate, ThresholdChoice};
use crate::config::{Compensation, Config, ThresholdPolicy};
use crate::data::ShardedLoader;
use crate::metrics::{RunLog, StepRecord};
use crate::policy::DropPolicy;
use crate::runtime::ModelRuntime;
use crate::sim::ClusterSim;
use crate::util::{Result, Stopwatch};

use super::grad::{GradAccumulator, GradNorm};
use super::lr::lr_at;
use super::optimizer::{clip_global_norm, Optimizer, OptimizerConfig};
use super::params::ParamStore;

/// Everything needed to train one model under one cluster configuration.
pub struct Trainer {
    pub cfg: Config,
    pub runtime: ModelRuntime,
    pub params: ParamStore,
    optimizer: Optimizer,
    loaders: Vec<ShardedLoader>,
    eval_loader: ShardedLoader,
    sim: ClusterSim,
    /// Chosen compute threshold (None = vanilla synchronous). Kept for
    /// reporting/back-compat; [`Self::drop_policy`] is what stepping
    /// actually consumes (`calibrate` keeps the two in sync — mutating
    /// this field directly changes nothing).
    pub threshold: Option<f64>,
    /// The full drop surface the timing sim steps under: the config's
    /// policy ([`Config::effective_policy`]) with the calibrated
    /// threshold composed in.
    pub drop_policy: DropPolicy,
    /// Calibration outcome, if Algorithm 2 ran.
    pub calibration: Option<ThresholdChoice>,
    pub norm: GradNorm,
    virtual_time: f64,
    /// Virtual time spent in Algorithm-2 calibration. Tracked separately:
    /// in the paper the calibration iterations are ordinary (drop-free)
    /// training steps, so they are not a training-time overhead; the
    /// summary still reports them for honest accounting.
    pub calibration_time: f64,
    /// Effective accumulations per step (inflated by IncreasedBatch).
    accums: usize,
    /// Effective total steps (inflated by ExtraSteps).
    total_steps: usize,
    /// Attached step observer ([`Self::observe`]); `None` (default)
    /// routes every step through the zero-cost
    /// [`crate::obs::NoopObserver`] path.
    obs: Option<Box<crate::obs::ObsRecorder>>,
}

impl Trainer {
    pub fn new(cfg: &Config) -> Result<Self> {
        let runtime =
            ModelRuntime::load(Path::new(&cfg.artifacts_dir), &cfg.train.model_size)?;
        let params = ParamStore::init(&runtime.manifest, cfg.train.seed);
        let optimizer = Optimizer::new(
            OptimizerConfig::new(cfg.train.optimizer, cfg.train.weight_decay),
            &runtime.manifest,
            &params,
        );
        let dims = &runtime.manifest.dims;
        let loaders = (0..cfg.cluster.workers)
            .map(|n| {
                ShardedLoader::new(
                    dims.vocab,
                    dims.micro_batch,
                    dims.seq_len,
                    &cfg.data,
                    n,
                )
            })
            .collect();
        let eval_loader = ShardedLoader::new(
            dims.vocab,
            dims.micro_batch,
            dims.seq_len,
            &cfg.data,
            usize::MAX / 2, // shard far away from any training worker
        );
        let base_policy = cfg.effective_policy();
        if base_policy.local_sgd_h().is_some() {
            return Err(crate::util::Error::Config(
                "a local-sgd policy clause requires the local-sgd trainer \
                 (`local-sgd` subcommand)"
                    .into(),
            ));
        }
        let sim = ClusterSim::new(&cfg.cluster, cfg.train.seed ^ 0x5EED)
            .with_policy(base_policy.clone());
        Ok(Self {
            cfg: cfg.clone(),
            runtime,
            params,
            optimizer,
            loaders,
            eval_loader,
            sim,
            threshold: None,
            drop_policy: base_policy,
            calibration: None,
            norm: GradNorm::Computed,
            virtual_time: 0.0,
            calibration_time: 0.0,
            accums: cfg.cluster.accumulations,
            total_steps: cfg.train.steps,
            obs: None,
        })
    }

    /// Attach an [`crate::obs::ObsRecorder`] to every subsequent
    /// training step's timing simulation. Observation only reads — the
    /// step outcomes are bitwise identical with or without it.
    pub fn observe(&mut self) {
        self.obs = Some(Box::new(crate::obs::ObsRecorder::new(
            self.cfg.cluster.workers,
        )));
    }

    /// The attached recorder, if [`Self::observe`] was called.
    pub fn observer(&self) -> Option<&crate::obs::ObsRecorder> {
        self.obs.as_deref()
    }

    /// Detach and return the recorder.
    pub fn take_observer(&mut self) -> Option<Box<crate::obs::ObsRecorder>> {
        self.obs.take()
    }

    /// Phase 0 — choose the threshold per policy (Algorithm 2 for Auto),
    /// then apply the configured compensation to the schedule.
    pub fn calibrate(&mut self) {
        let policy = self.cfg.dropcompute.policy.clone();
        let (threshold, choice) = match policy {
            ThresholdPolicy::Off => (None, None),
            ThresholdPolicy::Fixed(tau) => (Some(tau), None),
            ThresholdPolicy::Auto => {
                let trace = self
                    .sim
                    .record_trace(self.cfg.dropcompute.calibration_iters);
                let choice =
                    choose_threshold(&trace, self.cfg.dropcompute.search_points);
                self.calibration_time = (0..trace.iters)
                    .map(|i| trace.step_time(i) + trace.comm[i])
                    .sum::<f64>();
                (Some(choice.tau), Some(choice))
            }
            ThresholdPolicy::TargetDropRate(rate) => {
                let trace = self
                    .sim
                    .record_trace(self.cfg.dropcompute.calibration_iters);
                let tau = threshold_for_drop_rate(&trace, rate);
                self.calibration_time = (0..trace.iters)
                    .map(|i| trace.step_time(i) + trace.comm[i])
                    .sum::<f64>();
                (Some(tau), None)
            }
        };
        self.threshold = threshold;
        // fold the chosen threshold into the unified drop surface
        self.drop_policy = {
            let mut p = self.cfg.effective_policy();
            if let Some(tau) = threshold {
                p = p.and(DropPolicy::compute_tau(tau));
            }
            p
        };

        // Compensation planning (§4.5): R = M/M~ - 1 from the predicted
        // completion rate.
        let completion = choice
            .as_ref()
            .map(|c| c.completion_rate)
            .unwrap_or_else(|| match self.cfg.dropcompute.policy {
                ThresholdPolicy::TargetDropRate(r) => 1.0 - r,
                _ => 1.0,
            });
        if completion < 1.0 {
            let r = 1.0 / completion - 1.0;
            match self.cfg.dropcompute.compensation {
                Compensation::ExtraSteps => {
                    self.total_steps = ((self.cfg.train.steps as f64)
                        * (1.0 + r))
                        .round() as usize;
                }
                Compensation::IncreasedBatch => {
                    self.accums = ((self.cfg.cluster.accumulations as f64)
                        * (1.0 + r))
                        .ceil() as usize;
                }
                Compensation::None | Compensation::Resample => {}
            }
        }
        self.calibration = choice;
    }

    pub fn total_steps(&self) -> usize {
        self.total_steps
    }

    pub fn accumulations(&self) -> usize {
        self.accums
    }

    /// One synchronous training step. Returns the step record.
    pub fn train_step(&mut self, step: usize) -> Result<StepRecord> {
        let sw = Stopwatch::start();
        // Timing + drop decisions from the cluster simulator. If the
        // batch was inflated (IncreasedBatch) rebuild the sim dimension.
        let outcome = if self.accums == self.sim.accums {
            let mut out = Default::default();
            match self.obs.as_deref_mut() {
                Some(rec) => {
                    self.sim.step_with_observed(&self.drop_policy, &mut out, rec)
                }
                None => self.sim.step_with_into(&self.drop_policy, &mut out),
            }
            out
        } else {
            // temporary sim with adjusted accumulation count
            let mut cfg = self.cfg.cluster.clone();
            cfg.accumulations = self.accums;
            let mut sim =
                ClusterSim::new(&cfg, self.cfg.train.seed ^ step as u64);
            let mut out = Default::default();
            match self.obs.as_deref_mut() {
                Some(rec) => {
                    sim.step_with_observed(&self.drop_policy, &mut out, rec)
                }
                None => sim.step_with_into(&self.drop_policy, &mut out),
            }
            out
        };

        self.runtime.upload_params(self.params.tensors())?;
        let mut acc =
            GradAccumulator::new(self.params.tensors(), self.norm);
        for (n, &done) in outcome.completed.iter().enumerate() {
            for _ in 0..done {
                let mb = self.loaders[n].next();
                let out = self.runtime.grad(&mb.tokens)?;
                acc.add(&out.grads, out.loss as f64);
            }
            for _ in done..self.accums {
                // dropped micro-batch: requeue under Resample
                if self.cfg.dropcompute.compensation == Compensation::Resample {
                    let mb = self.loaders[n].next();
                    self.loaders[n].push_dropped(mb);
                }
                acc.add_dropped();
            }
        }

        let completed = acc.computed();
        let scheduled = acc.scheduled();
        let lr = lr_at(
            self.cfg.train.schedule,
            self.cfg.train.lr,
            step,
            self.total_steps,
        );
        let (loss, grad_norm) = match acc.finalize() {
            Some((mut grads, loss)) => {
                let gn = clip_global_norm(&mut grads, self.cfg.train.grad_clip);
                self.optimizer.step(&mut self.params, &grads, lr);
                (loss, gn)
            }
            None => (f64::NAN, 0.0), // every worker dropped everything
        };

        self.virtual_time += outcome.iter_time;
        Ok(StepRecord {
            step,
            virtual_time: self.virtual_time,
            wall_time: sw.seconds(),
            iter_time: outcome.iter_time,
            completed_microbatches: completed,
            scheduled_microbatches: scheduled,
            loss,
            lr,
            grad_norm,
        })
    }

    /// Mean eval loss over held-out micro-batches (the Table 1 quality
    /// metric — see DESIGN.md on the SQuAD-F1 -> perplexity substitution).
    pub fn eval_loss(&mut self, batches: usize) -> Result<f64> {
        self.runtime.upload_params(self.params.tensors())?;
        let mut sum = 0.0;
        for _ in 0..batches {
            let mb = self.eval_loader.next();
            sum += self.runtime.loss(&mb.tokens)? as f64;
        }
        Ok(sum / batches as f64)
    }

    /// Full training run.
    pub fn train(&mut self) -> Result<RunLog> {
        self.calibrate();
        let label = format!(
            "{}-{}",
            self.cfg.train.model_size,
            match self.threshold {
                Some(_) => "dropcompute",
                None => "baseline",
            }
        );
        let mut log = RunLog::new(label);
        for step in 0..self.total_steps {
            let rec = self.train_step(step)?;
            if step % self.cfg.train.log_every == 0 {
                crate::info!(
                    "step {step:4} loss {:.4} drop {:5.1}% iter {:.2}s vt {:.1}s",
                    rec.loss,
                    rec.drop_rate() * 100.0,
                    rec.iter_time,
                    rec.virtual_time
                );
            }
            if self.cfg.train.eval_every > 0
                && step > 0
                && step % self.cfg.train.eval_every == 0
            {
                let ev = self.eval_loss(self.cfg.train.eval_batches)?;
                log.set_summary(&format!("eval_loss_{step}"), ev);
            }
            log.push(rec);
        }
        if let Some(tau) = self.threshold {
            log.set_summary("threshold", tau);
            log.set_summary("calibration_virtual_time", self.calibration_time);
        }
        if let Some(choice) = &self.calibration {
            log.set_summary("predicted_speedup", choice.speedup);
            log.set_summary("predicted_completion", choice.completion_rate);
        }
        let final_eval = self.eval_loss(self.cfg.train.eval_batches)?;
        log.set_summary("final_eval_loss", final_eval);
        log.set_summary("mean_drop_rate", log.mean_drop_rate());
        log.set_summary("total_virtual_time", log.total_virtual_time());
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NoiseKind, OptimizerKind};

    fn test_config() -> Config {
        let mut cfg = Config::default();
        cfg.train.model_size = "test".into();
        cfg.train.steps = 12;
        cfg.train.lr = 3e-3;
        cfg.train.optimizer = OptimizerKind::Adam;
        cfg.train.log_every = 1000; // quiet
        cfg.cluster.workers = 4;
        cfg.cluster.accumulations = 3;
        cfg
    }

    #[test]
    fn baseline_training_reduces_loss() {
        crate::util::set_verbosity(0);
        let mut t = Trainer::new(&test_config()).unwrap();
        let log = t.train().unwrap();
        assert_eq!(log.steps.len(), 12);
        let first = log.steps[0].loss;
        let last = log.steps.last().unwrap().loss;
        assert!(last < first, "loss {first} -> {last}");
        assert_eq!(log.mean_drop_rate(), 0.0);
        // every step computed N*M micro-batches
        assert!(log
            .steps
            .iter()
            .all(|s| s.completed_microbatches == 12));
    }

    #[test]
    fn dropcompute_auto_calibrates_and_drops() {
        crate::util::set_verbosity(0);
        let mut cfg = test_config();
        cfg.cluster.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        cfg.dropcompute.policy = ThresholdPolicy::Auto;
        cfg.dropcompute.calibration_iters = 10;
        let mut t = Trainer::new(&cfg).unwrap();
        let log = t.train().unwrap();
        assert!(t.threshold.is_some());
        let choice = t.calibration.as_ref().unwrap();
        assert!(choice.speedup > 1.0);
        assert!(log.mean_drop_rate() > 0.0, "should drop something");
        assert!(log.mean_drop_rate() < 0.6);
        // training still converges
        assert!(log.final_loss() < log.steps[0].loss);
    }

    #[test]
    fn fixed_threshold_respected() {
        crate::util::set_verbosity(0);
        let mut cfg = test_config();
        cfg.cluster.noise = NoiseKind::Exponential { mean: 0.4 };
        cfg.dropcompute.policy = ThresholdPolicy::Fixed(1.8);
        let mut t = Trainer::new(&cfg).unwrap();
        let log = t.train().unwrap();
        assert_eq!(t.threshold, Some(1.8));
        for s in &log.steps {
            // iter time = compute (<= tau) + comm
            assert!(s.iter_time <= 1.8 + cfg.cluster.comm_latency + 1e-9);
        }
    }

    #[test]
    fn extra_steps_compensation_extends_run() {
        crate::util::set_verbosity(0);
        let mut cfg = test_config();
        cfg.cluster.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        cfg.dropcompute.policy = ThresholdPolicy::TargetDropRate(0.10);
        cfg.dropcompute.compensation = Compensation::ExtraSteps;
        let mut t = Trainer::new(&cfg).unwrap();
        t.calibrate();
        assert!(
            t.total_steps() > cfg.train.steps,
            "{} should exceed {}",
            t.total_steps(),
            cfg.train.steps
        );
        // ~11% extra at 10% drop (paper §4.5)
        assert!(t.total_steps() <= (cfg.train.steps as f64 * 1.25) as usize);
    }

    #[test]
    fn increased_batch_compensation_inflates_accums() {
        crate::util::set_verbosity(0);
        let mut cfg = test_config();
        cfg.dropcompute.policy = ThresholdPolicy::TargetDropRate(0.25);
        cfg.dropcompute.compensation = Compensation::IncreasedBatch;
        cfg.cluster.noise = NoiseKind::Exponential { mean: 0.4 };
        let mut t = Trainer::new(&cfg).unwrap();
        t.calibrate();
        assert!(t.accumulations() == 4, "3 * 4/3 = 4, got {}", t.accumulations());
    }

    #[test]
    fn resample_pool_grows_under_drops() {
        crate::util::set_verbosity(0);
        let mut cfg = test_config();
        cfg.cluster.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        cfg.dropcompute.policy = ThresholdPolicy::TargetDropRate(0.3);
        cfg.dropcompute.compensation = Compensation::Resample;
        let mut t = Trainer::new(&cfg).unwrap();
        let log = t.train().unwrap();
        assert!(log.mean_drop_rate() > 0.05);
        let total_resampled: usize =
            t.loaders.iter().map(|l| l.resampled + l.pool_len()).sum();
        assert!(total_resampled > 0, "dropped batches should be requeued");
    }

    #[test]
    fn eval_loss_finite_and_near_train() {
        crate::util::set_verbosity(0);
        let mut t = Trainer::new(&test_config()).unwrap();
        let log = t.train().unwrap();
        let ev = log.summary["final_eval_loss"];
        assert!(ev.is_finite());
        assert!((ev - log.final_loss()).abs() < 1.5, "{ev} vs {}", log.final_loss());
    }

    #[test]
    fn deterministic_given_seed() {
        crate::util::set_verbosity(0);
        let cfg = test_config();
        let la = Trainer::new(&cfg).unwrap().train().unwrap();
        let lb = Trainer::new(&cfg).unwrap().train().unwrap();
        assert_eq!(la.final_loss().to_bits(), lb.final_loss().to_bits());
    }
}
