//! Data-parallel linear-softmax classifier — the Fig 10/11 analogue.
//!
//! The paper's image-classification experiment simulates DropCompute by
//! zeroing each worker's whole gradient contribution with probability
//! `p_drop` per step (§5.1 "Image classification", App. B.2.2). The model
//! there is ResNet-50; the *claim* is about stochastic batch size vs.
//! accuracy, so a linear-softmax classifier on a Gaussian-cluster task
//! exercises the identical mechanism (see DESIGN.md §Substitutions),
//! including the two learning-rate corrections of App. B.2.2.

use crate::config::OptimizerKind;
use crate::data::ClassificationTask;
use crate::rng::Xoshiro256pp;

/// Learning-rate correction under stochastic batch size (App. B.2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrCorrection {
    /// No correction (divide by the scheduled batch size).
    None,
    /// Constant: multiply lr by `(1 - p_drop)`.
    Constant,
    /// Stochastic: divide by the *computed* batch size each step.
    Stochastic,
}

/// Training configuration for the classifier experiment.
#[derive(Debug, Clone)]
pub struct ClassifierConfig {
    pub workers: usize,
    pub local_batch: usize,
    pub steps: usize,
    pub lr: f64,
    pub p_drop: f64,
    pub correction: LrCorrection,
    pub optimizer: OptimizerKind,
    pub momentum: f64,
    pub seed: u64,
}

impl Default for ClassifierConfig {
    fn default() -> Self {
        Self {
            workers: 8,
            local_batch: 32,
            steps: 300,
            lr: 0.5,
            p_drop: 0.0,
            correction: LrCorrection::None,
            optimizer: OptimizerKind::Momentum,
            momentum: 0.9,
            seed: 0,
        }
    }
}

/// Result of one training run.
#[derive(Debug, Clone)]
pub struct ClassifierRun {
    pub test_accuracy: f64,
    pub final_loss: f64,
    pub observed_drop_rate: f64,
}

/// Train a linear softmax classifier data-parallel with whole-worker
/// gradient drops; returns held-out accuracy.
pub fn train_classifier(task: &ClassificationTask, cfg: &ClassifierConfig)
    -> ClassifierRun
{
    let (c, d) = (task.classes, task.dim);
    let mut w = vec![0.0f32; c * d];
    let mut b = vec![0.0f32; c];
    let mut mw = vec![0.0f32; c * d];
    let mut mb = vec![0.0f32; c];
    let root = Xoshiro256pp::seed_from_u64(cfg.seed);
    let mut drop_rng = root.split(999_999);
    let mut streams: Vec<Xoshiro256pp> =
        (0..cfg.workers).map(|n| root.split(n as u64)).collect();

    let mut dropped = 0usize;
    let mut last_loss = 0.0f64;
    for _step in 0..cfg.steps {
        let mut gw = vec![0.0f32; c * d];
        let mut gb = vec![0.0f32; c];
        let mut computed_workers = 0usize;
        let mut loss_acc = 0.0f64;
        for n in 0..cfg.workers {
            // whole-worker drop (the paper's simulated mechanism)
            if drop_rng.next_f64() < cfg.p_drop {
                dropped += 1;
                continue;
            }
            computed_workers += 1;
            let (xs, ys) = task.sample(cfg.local_batch, &mut streams[n]);
            loss_acc += accumulate_grads(
                &xs, &ys, &w, &b, c, d, cfg.local_batch, &mut gw, &mut gb,
            );
        }
        if computed_workers == 0 {
            continue;
        }
        last_loss = loss_acc / computed_workers as f64;
        // normalization + lr correction (App. B.2.2)
        let (denom, lr) = match cfg.correction {
            LrCorrection::None => (cfg.workers as f32, cfg.lr),
            LrCorrection::Constant => {
                (cfg.workers as f32, cfg.lr * (1.0 - cfg.p_drop))
            }
            LrCorrection::Stochastic => (computed_workers as f32, cfg.lr),
        };
        let lr = lr as f32;
        let mu = cfg.momentum as f32;
        // LARS (You et al. 2017): layer-wise trust ratio ||w||/||g||
        // scaling the update, as in the MLPerf ResNet-50 regime the
        // paper's Fig 10 (right) uses. Anything else = plain momentum.
        let ratio_w = if cfg.optimizer == OptimizerKind::Lars {
            let wn = (w.iter().map(|&x| x * x).sum::<f32>()).sqrt();
            let gn = (gw.iter().map(|&x| (x / denom) * (x / denom)).sum::<f32>())
                .sqrt();
            if wn > 0.0 && gn > 0.0 {
                (wn / gn).min(10.0)
            } else {
                1.0
            }
        } else {
            1.0
        };
        for (wi, (g, m)) in gw.iter().zip(mw.iter_mut()).enumerate() {
            *m = mu * *m + ratio_w * g / denom;
            w[wi] -= lr * *m;
        }
        for (bi, (g, m)) in gb.iter().zip(mb.iter_mut()).enumerate() {
            *m = mu * *m + g / denom;
            b[bi] -= lr * *m;
        }
    }

    // held-out evaluation
    let mut eval_rng = root.split(123_456_789);
    let (xs, ys) = task.sample(2000, &mut eval_rng);
    let mut correct = 0usize;
    for i in 0..ys.len() {
        let x = &xs[i * d..(i + 1) * d];
        let (mut best_v, mut best_c) = (f32::NEG_INFINITY, 0usize);
        for cc in 0..c {
            let logit = b[cc]
                + w[cc * d..(cc + 1) * d]
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum::<f32>();
            if logit > best_v {
                best_v = logit;
                best_c = cc;
            }
        }
        if best_c == ys[i] as usize {
            correct += 1;
        }
    }
    ClassifierRun {
        test_accuracy: correct as f64 / ys.len() as f64,
        final_loss: last_loss,
        observed_drop_rate: dropped as f64 / (cfg.steps * cfg.workers) as f64,
    }
}

/// Accumulate softmax-CE gradients for one worker's local batch; returns
/// the summed-over-batch mean loss contribution.
#[allow(clippy::too_many_arguments)]
fn accumulate_grads(
    xs: &[f32],
    ys: &[u32],
    w: &[f32],
    b: &[f32],
    c: usize,
    d: usize,
    batch: usize,
    gw: &mut [f32],
    gb: &mut [f32],
) -> f64 {
    let mut loss = 0.0f64;
    let scale = 1.0 / batch as f32;
    let mut logits = vec![0.0f32; c];
    for i in 0..batch {
        let x = &xs[i * d..(i + 1) * d];
        for cc in 0..c {
            logits[cc] = b[cc]
                + w[cc * d..(cc + 1) * d]
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum::<f32>();
        }
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for l in logits.iter_mut() {
            *l = (*l - max).exp();
            z += *l;
        }
        let y = ys[i] as usize;
        loss += -((logits[y] / z).ln() as f64);
        for cc in 0..c {
            let p = logits[cc] / z - if cc == y { 1.0 } else { 0.0 };
            let p = p * scale;
            gb[cc] += p;
            for (g, &xv) in gw[cc * d..(cc + 1) * d].iter_mut().zip(x) {
                *g += p * xv;
            }
        }
    }
    loss / batch as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> ClassificationTask {
        ClassificationTask::new(8, 16, 0.6, 3)
    }

    fn cfg(p_drop: f64) -> ClassifierConfig {
        ClassifierConfig { p_drop, steps: 150, ..Default::default() }
    }

    #[test]
    fn learns_without_drops() {
        let run = train_classifier(&task(), &cfg(0.0));
        assert!(run.test_accuracy > 0.9, "{}", run.test_accuracy);
        assert_eq!(run.observed_drop_rate, 0.0);
    }

    #[test]
    fn ten_percent_drop_barely_hurts() {
        // Fig 10's claim: up to 10% drop rate, negligible deterioration.
        let base = train_classifier(&task(), &cfg(0.0));
        let drop = train_classifier(&task(), &cfg(0.10));
        assert!(drop.observed_drop_rate > 0.05);
        assert!(
            drop.test_accuracy > base.test_accuracy - 0.03,
            "base {} vs 10% drop {}",
            base.test_accuracy,
            drop.test_accuracy
        );
    }

    #[test]
    fn extreme_drop_hurts() {
        let base = train_classifier(&task(), &cfg(0.0));
        let mut c = cfg(0.9);
        c.steps = 60; // fewer effective updates
        let drop = train_classifier(&task(), &c);
        assert!(drop.test_accuracy < base.test_accuracy + 1e-9);
    }

    #[test]
    fn corrections_comparable_at_low_drop() {
        // App. B.2.2: no correction method is clearly superior at <=10%.
        let mut accs = Vec::new();
        for corr in [
            LrCorrection::None,
            LrCorrection::Constant,
            LrCorrection::Stochastic,
        ] {
            let mut c = cfg(0.1);
            c.correction = corr;
            accs.push(train_classifier(&task(), &c).test_accuracy);
        }
        let max = accs.iter().cloned().fold(f64::MIN, f64::max);
        let min = accs.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min < 0.05, "{accs:?}");
    }

    #[test]
    fn lars_regime_also_learns() {
        let mut c = cfg(0.05);
        c.optimizer = OptimizerKind::Lars;
        c.lr = 0.3;
        let run = train_classifier(&task(), &c);
        assert!(run.test_accuracy > 0.85, "{}", run.test_accuracy);
    }
}
