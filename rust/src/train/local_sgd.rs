//! Local-SGD (Lin et al. 2020) with optional DropCompute (App. B.3).
//!
//! Workers keep private parameter replicas, take `H` local SGD steps
//! (one micro-batch each), then average parameters. DropCompute
//! integrates per *local step*: a worker whose compute exceeds the
//! threshold skips that local update (its replica simply doesn't move),
//! bounding the straggler's effect on the period time.

use std::path::Path;

use crate::config::Config;
use crate::data::ShardedLoader;
use crate::metrics::{RunLog, StepRecord};
use crate::policy::DropPolicy;
use crate::runtime::ModelRuntime;
use crate::sim::{ClusterSim, StepOutcome};
use crate::util::{Result, Stopwatch};

use super::params::ParamStore;

/// Local-SGD trainer: private replicas + periodic averaging.
pub struct LocalSgdTrainer {
    pub cfg: Config,
    runtime: ModelRuntime,
    replicas: Vec<ParamStore>,
    loaders: Vec<ShardedLoader>,
    sim: ClusterSim,
    pub threshold: Option<f64>,
    /// The period's full drop surface: `local-sgd=H` composed with the
    /// per-local-step threshold and the config's comm-side policy.
    pub drop_policy: DropPolicy,
    virtual_time: f64,
    /// Reusable period-timing outcome
    /// ([`ClusterSim::local_sgd_period_into`] recycles its vectors).
    outcome: StepOutcome,
    /// Optional observability recorder ([`Self::observe`]); boxed so
    /// the unobserved path pays one pointer, nothing more.
    obs: Option<Box<crate::obs::ObsRecorder>>,
}

impl LocalSgdTrainer {
    pub fn new(cfg: &Config, threshold: Option<f64>) -> Result<Self> {
        let runtime =
            ModelRuntime::load(Path::new(&cfg.artifacts_dir), &cfg.train.model_size)?;
        let params = ParamStore::init(&runtime.manifest, cfg.train.seed);
        let dims = &runtime.manifest.dims;
        let loaders = (0..cfg.cluster.workers)
            .map(|n| {
                ShardedLoader::new(
                    dims.vocab,
                    dims.micro_batch,
                    dims.seq_len,
                    &cfg.data,
                    n,
                )
            })
            .collect();
        // one micro-batch per local step
        let mut sim_cfg = cfg.cluster.clone();
        sim_cfg.accumulations = 1;
        // the unified drop surface: the config's policy, a local-sgd
        // clause (from the policy itself or the train config) and the
        // per-local-step threshold
        let mut policy = cfg.effective_policy();
        if policy.local_sgd_h().is_none() {
            policy = policy
                .and(DropPolicy::local_sgd(cfg.train.local_sgd_period));
        }
        if let Some(tau) = threshold {
            policy = policy.and(DropPolicy::compute_tau(tau));
        }
        let sim = ClusterSim::new(&sim_cfg, cfg.train.seed ^ 0x10CA1)
            .with_policy(policy.clone());
        Ok(Self {
            cfg: cfg.clone(),
            replicas: vec![params; cfg.cluster.workers],
            runtime,
            loaders,
            sim,
            threshold,
            drop_policy: policy,
            virtual_time: 0.0,
            outcome: StepOutcome::default(),
            obs: None,
        })
    }

    /// Attach an [`crate::obs::ObsRecorder`]; subsequent periods route
    /// through [`ClusterSim::step_installed_observed`].
    pub fn observe(&mut self) {
        self.obs = Some(Box::new(crate::obs::ObsRecorder::new(
            self.cfg.cluster.workers,
        )));
    }

    /// The attached recorder, if any.
    pub fn observer(&self) -> Option<&crate::obs::ObsRecorder> {
        self.obs.as_deref()
    }

    /// Detach and return the recorder.
    pub fn take_observer(&mut self) -> Option<Box<crate::obs::ObsRecorder>> {
        self.obs.take()
    }

    /// The synchronization period H the policy measures.
    pub fn period_len(&self) -> usize {
        self.drop_policy
            .local_sgd_h()
            .unwrap_or(self.cfg.train.local_sgd_period)
    }

    /// One synchronization period: `H` local steps then averaging.
    /// Returns (record, local updates performed).
    pub fn period(&mut self, period_idx: usize) -> Result<StepRecord> {
        let sw = Stopwatch::start();
        let h = self.period_len();
        match self.obs.as_deref_mut() {
            Some(rec) => {
                self.sim.step_installed_observed(&mut self.outcome, rec)
            }
            None => self.sim.step_installed_into(&mut self.outcome),
        }
        let outcome = &self.outcome;

        let lr = self.cfg.train.lr;
        let mut loss_sum = 0.0;
        let mut loss_count = 0usize;
        for (n, &done) in outcome.completed.iter().enumerate() {
            // `done` of the H local steps survived for worker n.
            for _ in 0..done {
                let mb = self.loaders[n].next();
                self.runtime.upload_params(self.replicas[n].tensors())?;
                let out = self.runtime.grad(&mb.tokens)?;
                self.replicas[n].axpy(-(lr as f32), &out.grads);
                loss_sum += out.loss as f64;
                loss_count += 1;
            }
        }

        // Parameter averaging (the periodic synchronization).
        let n_workers = self.replicas.len();
        let mut avg = self.replicas[0].clone();
        for t in avg.tensors_mut() {
            for x in t.iter_mut() {
                *x /= n_workers as f32;
            }
        }
        for rep in &self.replicas[1..] {
            let scaled: Vec<Vec<f32>> = rep
                .tensors()
                .iter()
                .map(|t| t.iter().map(|&x| x / n_workers as f32).collect())
                .collect();
            avg.axpy(1.0, &scaled);
        }
        for rep in self.replicas.iter_mut() {
            *rep = avg.clone();
        }

        self.virtual_time += outcome.iter_time;
        Ok(StepRecord {
            step: period_idx,
            virtual_time: self.virtual_time,
            wall_time: sw.seconds(),
            iter_time: outcome.iter_time,
            completed_microbatches: outcome.total_completed(),
            scheduled_microbatches: n_workers * h,
            loss: if loss_count > 0 {
                loss_sum / loss_count as f64
            } else {
                f64::NAN
            },
            lr,
            grad_norm: 0.0,
        })
    }

    pub fn train(&mut self, periods: usize) -> Result<RunLog> {
        let mut log = RunLog::new(format!(
            "local_sgd_h{}_{}",
            self.cfg.train.local_sgd_period,
            if self.threshold.is_some() { "dropcompute" } else { "plain" }
        ));
        for p in 0..periods {
            log.push(self.period(p)?);
        }
        log.set_summary("total_virtual_time", log.total_virtual_time());
        Ok(log)
    }

    /// Consensus check helper: max parameter divergence across replicas.
    pub fn replica_divergence(&self) -> f32 {
        let first = &self.replicas[0];
        let mut max_d = 0.0f32;
        for rep in &self.replicas[1..] {
            for (a, b) in first.tensors().iter().zip(rep.tensors()) {
                for (x, y) in a.iter().zip(b) {
                    max_d = max_d.max((x - y).abs());
                }
            }
        }
        max_d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StragglerKind;

    fn cfg() -> Config {
        let mut cfg = Config::default();
        cfg.train.model_size = "test".into();
        cfg.train.lr = 3e-3;
        cfg.train.local_sgd_period = 4;
        cfg.cluster.workers = 3;
        cfg.cluster.accumulations = 1;
        cfg
    }

    #[test]
    fn consensus_after_each_period() {
        crate::util::set_verbosity(0);
        let mut t = LocalSgdTrainer::new(&cfg(), None).unwrap();
        t.period(0).unwrap();
        assert_eq!(t.replica_divergence(), 0.0);
    }

    #[test]
    fn loss_decreases_over_periods() {
        crate::util::set_verbosity(0);
        let mut t = LocalSgdTrainer::new(&cfg(), None).unwrap();
        let log = t.train(8).unwrap();
        let first = log.steps[0].loss;
        let last = log.steps.last().unwrap().loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn dropcompute_bounds_period_time_under_stragglers() {
        crate::util::set_verbosity(0);
        let mut c = cfg();
        c.cluster.stragglers = StragglerKind::Uniform { p: 0.3, delay: 1.0 };
        let mut plain = LocalSgdTrainer::new(&c, None).unwrap();
        let mut dc = LocalSgdTrainer::new(&c, Some(0.9)).unwrap();
        let lp = plain.train(5).unwrap();
        let ld = dc.train(5).unwrap();
        assert!(
            ld.total_virtual_time() < lp.total_virtual_time(),
            "dc {} vs plain {}",
            ld.total_virtual_time(),
            lp.total_virtual_time()
        );
        assert!(ld.mean_drop_rate() > 0.0);
    }
}
