//! Paper-style table/figure rendering: aligned text rows shared by the
//! benches, so every experiment prints in a uniform, diffable format.

/// A simple aligned-column table printer.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout — unless `--quiet` dropped the
    /// [`crate::obs::log`] level below info, keeping stdout clean for
    /// machine-readable output.
    pub fn print(&self) {
        if crate::obs::log::enabled(crate::obs::log::Level::Info) {
            println!("{}", self.render());
        }
    }
}

/// Format helper: fixed-precision float cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Format helper: percentage cell.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

/// Render a simple ASCII series plot (x label, y values as bars) for
/// terminal-friendly "figures".
pub fn ascii_series(title: &str, points: &[(String, f64)], width: usize) -> String {
    let max = points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
    let min = points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min).min(0.0);
    let span = (max - min).max(1e-12);
    let label_w = points.iter().map(|p| p.0.len()).max().unwrap_or(0);
    let mut out = format!("-- {title} --\n");
    for (label, v) in points {
        let bars = (((v - min) / span) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:>w$} | {}{} {v:.4}\n",
            label,
            "#".repeat(bars),
            " ".repeat(width.saturating_sub(bars)),
            w = label_w
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["n", "speedup"]);
        t.row(vec!["8".into(), f(1.05, 2)]);
        t.row(vec!["128".into(), f(1.31, 2)]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("1.05"));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn pct_and_f() {
        assert_eq!(pct(0.105), "10.5%");
        assert_eq!(f(1.23456, 2), "1.23");
    }

    #[test]
    fn ascii_series_shape() {
        let s = ascii_series(
            "t",
            &[("a".into(), 1.0), ("bb".into(), 2.0)],
            10,
        );
        assert!(s.contains("-- t --"));
        assert!(s.lines().count() == 3);
    }
}
