//! Artifact manifests: what `python/compile/aot.py` emitted.

use std::path::{Path, PathBuf};

use crate::util::{Error, Result};

use super::json::Json;

/// Initialization kind for one parameter tensor (mirrors model.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    Normal,
    Zeros,
    Ones,
}

/// One parameter tensor's spec.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
    pub scale: f64,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether this tensor gets weight decay (LN/bias tensors do not —
    /// the standard transformer recipe, also what LAMB/BERT uses).
    pub fn decayed(&self) -> bool {
        !(self.name.ends_with(".bias")
            || self.name.ends_with(".scale")
            || self.name.contains("ln"))
    }
}

/// Model hyper-parameters recorded in the manifest.
#[derive(Debug, Clone, Default)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub micro_batch: usize,
    pub d_ff: usize,
}

/// A size directory under `artifacts/`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub param_count: usize,
    pub flops_per_microbatch: f64,
    pub params: Vec<ParamSpec>,
    pub grad_file: PathBuf,
    pub loss_file: PathBuf,
}

impl Manifest {
    /// Load `artifacts/<size>/manifest.json`.
    pub fn load(artifacts_dir: &Path, size: &str) -> Result<Self> {
        let dir = artifacts_dir.join(size);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let req_str = |keys: &[&str]| -> Result<String> {
            j.path(keys)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| {
                    Error::Runtime(format!("manifest missing {}", keys.join(".")))
                })
        };
        let cfg = j
            .get("config")
            .ok_or_else(|| Error::Runtime("manifest missing config".into()))?;
        let dim = |k: &str| -> usize {
            cfg.get(k).and_then(Json::as_usize).unwrap_or(0)
        };
        let mut params = Vec::new();
        for p in j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Runtime("manifest missing params".into()))?
        {
            let name = p
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Runtime("param missing name".into()))?
                .to_string();
            let shape: Vec<usize> = p
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Runtime("param missing shape".into()))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let init = match p.get("init").and_then(Json::as_str) {
                Some("normal") => InitKind::Normal,
                Some("zeros") => InitKind::Zeros,
                Some("ones") => InitKind::Ones,
                other => {
                    return Err(Error::Runtime(format!(
                        "param {name}: unknown init {other:?}"
                    )))
                }
            };
            let scale = p.get("scale").and_then(Json::as_f64).unwrap_or(0.0);
            params.push(ParamSpec { name, shape, init, scale });
        }
        let manifest = Manifest {
            name: req_str(&["name"])?,
            grad_file: dir.join(req_str(&["entrypoints", "grad", "file"])?),
            loss_file: dir.join(req_str(&["entrypoints", "loss", "file"])?),
            dir,
            dims: ModelDims {
                vocab: dim("vocab"),
                d_model: dim("d_model"),
                n_layers: dim("n_layers"),
                n_heads: dim("n_heads"),
                seq_len: dim("seq_len"),
                micro_batch: dim("micro_batch"),
                d_ff: dim("d_ff"),
            },
            param_count: j
                .get("param_count")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            flops_per_microbatch: j
                .get("flops_per_microbatch")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            params,
        };
        // sanity: spec'd elements must sum to param_count
        let total: usize = manifest.params.iter().map(ParamSpec::numel).sum();
        if manifest.param_count != 0 && total != manifest.param_count {
            return Err(Error::Runtime(format!(
                "manifest param_count {} != sum of shapes {total}",
                manifest.param_count
            )));
        }
        Ok(manifest)
    }

    /// Tokens-per-micro-batch (batch * seq).
    pub fn tokens_per_microbatch(&self) -> usize {
        self.dims.micro_batch * self.dims.seq_len
    }

    /// Gradient bytes exchanged per AllReduce (f32).
    pub fn grad_bytes(&self) -> f64 {
        4.0 * self.param_count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from("artifacts")
    }

    #[test]
    fn loads_test_manifest() {
        let m = Manifest::load(&artifacts_dir(), "test").unwrap();
        assert_eq!(m.name, "test");
        assert_eq!(m.dims.vocab, 64);
        assert_eq!(m.dims.seq_len, 16);
        assert!(m.param_count > 0);
        assert!(m.grad_file.exists(), "{:?}", m.grad_file);
        assert!(m.loss_file.exists());
        assert_eq!(m.params[0].name, "tok_embed");
        assert_eq!(m.params[0].shape, vec![64, 32]);
        assert_eq!(m.params[0].init, InitKind::Normal);
    }

    #[test]
    fn decay_mask_excludes_norm_and_bias() {
        let m = Manifest::load(&artifacts_dir(), "test").unwrap();
        let decayed: Vec<&str> = m
            .params
            .iter()
            .filter(|p| p.decayed())
            .map(|p| p.name.as_str())
            .collect();
        assert!(decayed.contains(&"tok_embed"));
        for p in &m.params {
            if p.name.contains("ln") || p.name.ends_with(".bias") {
                assert!(!p.decayed(), "{}", p.name);
            }
        }
    }

    #[test]
    fn missing_size_errors_helpfully() {
        let e = Manifest::load(&artifacts_dir(), "nonexistent").unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
    }
}
