//! Minimal JSON parser for artifact manifests (no serde_json in the
//! sandbox registry). Full JSON grammar: objects, arrays, strings with
//! escapes, numbers, booleans, null.

use std::collections::BTreeMap;

use crate::util::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `m.path(&["entrypoints", "grad", "file"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Data(format!("json at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .unwrap_or(char::REPLACEMENT_CHARACTER),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number `{s}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let j = Json::parse(
            r#"{
              "name": "test",
              "param_count": 27776,
              "params": [
                {"name": "tok_embed", "shape": [64, 32], "init": "normal",
                 "scale": 0.02}
              ],
              "entrypoints": {"grad": {"file": "grad.hlo.txt",
                              "outputs": ["loss", "tok_embed"]}},
              "flag": true, "nothing": null
            }"#,
        )
        .unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("test"));
        assert_eq!(j.get("param_count").unwrap().as_usize(), Some(27776));
        let p0 = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("scale").unwrap().as_f64(), Some(0.02));
        assert_eq!(
            j.path(&["entrypoints", "grad", "file"]).unwrap().as_str(),
            Some("grad.hlo.txt")
        );
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"b\"A"));
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1.5", -1.5), ("2e3", 2000.0), ("1.25e-2", 0.0125)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
