//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! * [`json`] — manifest parsing substrate;
//! * [`artifacts`] — manifest schema (`artifacts/<size>/manifest.json`);
//! * [`pjrt`] — compile-once/run-many executor with device-resident
//!   parameter buffers.

pub mod artifacts;
pub mod json;
pub mod pjrt;

pub use artifacts::{InitKind, Manifest, ModelDims, ParamSpec};
pub use pjrt::{GradOutput, ModelRuntime};
