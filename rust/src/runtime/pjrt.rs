//! PJRT execution: load HLO-text artifacts, compile once, run many.
//!
//! The interchange is HLO *text* (see `python/compile/aot.py`); the
//! executor keeps parameters resident as device buffers between
//! micro-batches of the same step (`execute_b`), so the per-micro-batch
//! upload is just the token batch.

use std::path::Path;

use crate::util::{Error, Result};

use super::artifacts::Manifest;

/// Shared PJRT CPU client + compiled entry points for one model size.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    grad_exe: xla::PjRtLoadedExecutable,
    loss_exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    /// Device-resident parameter buffers (refreshed once per step).
    param_buffers: Option<Vec<xla::PjRtBuffer>>,
}

/// Result of one micro-batch gradient execution.
#[derive(Debug)]
pub struct GradOutput {
    pub loss: f32,
    /// Flat per-tensor gradients, same order as `manifest.params`.
    pub grads: Vec<Vec<f32>>,
}

fn compile(client: &xla::PjRtClient, path: &Path)
    -> Result<xla::PjRtLoadedExecutable>
{
    let path_str = path
        .to_str()
        .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

impl ModelRuntime {
    /// Load and compile the artifacts for `size` under `artifacts_dir`.
    pub fn load(artifacts_dir: &Path, size: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir, size)?;
        let client = xla::PjRtClient::cpu()?;
        let grad_exe = compile(&client, &manifest.grad_file)?;
        let loss_exe = compile(&client, &manifest.loss_file)?;
        Ok(Self { client, grad_exe, loss_exe, manifest, param_buffers: None })
    }

    /// Upload parameters once; subsequent `grad`/`loss` calls reuse the
    /// device buffers until the next `upload_params`.
    pub fn upload_params(&mut self, params: &[Vec<f32>]) -> Result<()> {
        assert_eq!(params.len(), self.manifest.params.len(), "param arity");
        let device = &self.client.devices()[0];
        let mut bufs = Vec::with_capacity(params.len());
        for (spec, data) in self.manifest.params.iter().zip(params) {
            assert_eq!(spec.numel(), data.len(), "param {} size", spec.name);
            let dims: Vec<usize> = spec.shape.clone();
            bufs.push(self.client.buffer_from_host_buffer(
                data,
                &dims,
                Some(device),
            )?);
        }
        self.param_buffers = Some(bufs);
        Ok(())
    }

    fn token_buffer(&self, tokens: &[i32]) -> Result<xla::PjRtBuffer> {
        let dims =
            [self.manifest.dims.micro_batch, self.manifest.dims.seq_len];
        assert_eq!(tokens.len(), dims[0] * dims[1], "token batch size");
        let device = &self.client.devices()[0];
        Ok(self.client.buffer_from_host_buffer(tokens, &dims, Some(device))?)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        tokens: &[i32],
    ) -> Result<Vec<xla::Literal>> {
        let params = self.param_buffers.as_ref().ok_or_else(|| {
            Error::Runtime("upload_params before execution".into())
        })?;
        let tok = self.token_buffer(tokens)?;
        let mut args: Vec<&xla::PjRtBuffer> = params.iter().collect();
        args.push(&tok);
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// One micro-batch forward+backward: `(loss, grads...)`.
    pub fn grad(&self, tokens: &[i32]) -> Result<GradOutput> {
        let outs = self.run(&self.grad_exe, tokens)?;
        if outs.len() != 1 + self.manifest.params.len() {
            return Err(Error::Runtime(format!(
                "grad arity {} != 1+{}",
                outs.len(),
                self.manifest.params.len()
            )));
        }
        let mut it = outs.into_iter();
        let loss = it.next().unwrap().to_vec::<f32>()?[0];
        let mut grads = Vec::with_capacity(self.manifest.params.len());
        for lit in it {
            grads.push(lit.to_vec::<f32>()?);
        }
        Ok(GradOutput { loss, grads })
    }

    /// Evaluation loss of one micro-batch.
    pub fn loss(&self, tokens: &[i32]) -> Result<f32> {
        let outs = self.run(&self.loss_exe, tokens)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }

    /// UNOPTIMIZED reference path: marshal parameters as host literals on
    /// *every* call (no device-resident buffers). Kept for the §Perf
    /// before/after comparison in `benches/perf_hotpaths.rs` — the
    /// buffered path amortizes the upload across a step's micro-batches.
    pub fn grad_unbuffered(
        &self,
        params: &[Vec<f32>],
        tokens: &[i32],
    ) -> Result<GradOutput> {
        let mut args: Vec<xla::Literal> = Vec::with_capacity(params.len() + 1);
        for (spec, data) in self.manifest.params.iter().zip(params) {
            let dims: Vec<i64> =
                spec.shape.iter().map(|&d| d as i64).collect();
            args.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let dims =
            [self.manifest.dims.micro_batch as i64, self.manifest.dims.seq_len as i64];
        args.push(xla::Literal::vec1(tokens).reshape(&dims)?);
        let result = self.grad_exe.execute::<xla::Literal>(&args)?;
        let outs = result[0][0].to_literal_sync()?.to_tuple()?;
        let mut it = outs.into_iter();
        let loss = it.next().unwrap().to_vec::<f32>()?[0];
        let grads: Result<Vec<Vec<f32>>> =
            it.map(|l| l.to_vec::<f32>().map_err(Into::into)).collect();
        Ok(GradOutput { loss, grads: grads? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::train::params::ParamStore;
    use std::path::PathBuf;

    fn runtime() -> ModelRuntime {
        ModelRuntime::load(&PathBuf::from("artifacts"), "test").unwrap()
    }

    fn tokens(rt: &ModelRuntime, seed: u64) -> Vec<i32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..rt.manifest.tokens_per_microbatch())
            .map(|_| rng.next_below(rt.manifest.dims.vocab as u64) as i32)
            .collect()
    }

    #[test]
    fn grad_shapes_and_initial_loss() {
        let mut rt = runtime();
        let store = ParamStore::init(&rt.manifest, 0);
        rt.upload_params(store.tensors()).unwrap();
        let out = rt.grad(&tokens(&rt, 1)).unwrap();
        // random init -> loss ~ ln(vocab) = ln 64 ~ 4.16
        assert!(
            (out.loss - (64f32).ln()).abs() < 0.5,
            "initial loss {}",
            out.loss
        );
        assert_eq!(out.grads.len(), rt.manifest.params.len());
        for (g, spec) in out.grads.iter().zip(&rt.manifest.params) {
            assert_eq!(g.len(), spec.numel(), "{}", spec.name);
        }
        // gradients must be finite and not all zero
        let norm: f32 = out
            .grads
            .iter()
            .flat_map(|g| g.iter())
            .map(|x| x * x)
            .sum::<f32>()
            .sqrt();
        assert!(norm.is_finite() && norm > 1e-3, "grad norm {norm}");
    }

    #[test]
    fn loss_entry_matches_grad_entry() {
        let mut rt = runtime();
        let store = ParamStore::init(&rt.manifest, 0);
        rt.upload_params(store.tensors()).unwrap();
        let t = tokens(&rt, 2);
        let g = rt.grad(&t).unwrap();
        let l = rt.loss(&t).unwrap();
        assert!((g.loss - l).abs() < 1e-5, "{} vs {l}", g.loss);
    }

    #[test]
    fn sgd_on_constant_batch_reduces_loss() {
        // End-to-end L3<->L2<->L1 sanity: a few SGD steps on a repeated
        // batch must reduce the loss through the real HLO artifacts.
        let mut rt = runtime();
        let mut store = ParamStore::init(&rt.manifest, 0);
        let t = tokens(&rt, 3);
        rt.upload_params(store.tensors()).unwrap();
        let l0 = rt.grad(&t).unwrap().loss;
        for _ in 0..10 {
            let out = rt.grad(&t).unwrap();
            store.axpy(-0.5, &out.grads);
            rt.upload_params(store.tensors()).unwrap();
        }
        let l1 = rt.grad(&t).unwrap().loss;
        assert!(l1 < l0 * 0.8, "loss {l0} -> {l1}");
    }

    #[test]
    fn requires_upload_before_run() {
        let rt = runtime();
        let t = tokens(&rt, 4);
        assert!(rt.grad(&t).is_err());
    }
}
