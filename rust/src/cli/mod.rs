//! Command-line parsing (no clap in the sandbox registry).
//!
//! Supports `program <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]` with typed accessors, `--set a.b=c` config overrides
//! (repeatable) and generated usage text.

use crate::util::{Error, Result};
use std::collections::BTreeMap;

/// Parsed arguments: subcommand, options, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Declares which option keys take values (everything else is a flag).
#[derive(Debug, Clone, Default)]
pub struct Spec {
    value_keys: Vec<&'static str>,
    subcommands: Vec<&'static str>,
    shorts: Vec<(char, &'static str)>,
}

impl Spec {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn value_keys(mut self, keys: &[&'static str]) -> Self {
        self.value_keys.extend_from_slice(keys);
        self
    }

    pub fn subcommands(mut self, subs: &[&'static str]) -> Self {
        self.subcommands.extend_from_slice(subs);
        self
    }

    /// Register a single-dash alias: `-c` expands to `--long` before
    /// parsing. Unregistered single-dash arguments stay positional, so
    /// existing invocations (e.g. negative-number positionals) keep
    /// working.
    pub fn short(mut self, c: char, long: &'static str) -> Self {
        self.shorts.push((c, long));
        self
    }

    /// If `arg` is a registered short alias (`-x`), return its long
    /// flag name.
    fn expand_short(&self, arg: &str) -> Option<&'static str> {
        let mut chars = arg.strip_prefix('-')?.chars();
        let c = chars.next()?;
        if chars.next().is_some() || arg.starts_with("--") {
            return None;
        }
        self.shorts.iter().find(|(s, _)| *s == c).map(|(_, l)| *l)
    }

    /// Parse argv (without the program name).
    pub fn parse<I, S>(&self, argv: I) -> Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = argv.into_iter().map(Into::into).peekable();

        if let Some(first) = it.peek() {
            if !first.starts_with('-') && self.subcommands.contains(&first.as_str())
            {
                out.subcommand = Some(it.next().unwrap());
            }
        }

        while let Some(arg) = it.next() {
            let arg = match self.expand_short(&arg) {
                Some(long) => format!("--{long}"),
                None => arg,
            };
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if self.value_keys.contains(&key.as_str()) {
                    let value = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| {
                            Error::Cli(format!("--{key} expects a value"))
                        })?,
                    };
                    out.options.entry(key).or_default().push(value);
                } else if let Some(v) = inline {
                    // unknown --k=v still recorded as option
                    out.options.entry(key).or_default().push(v);
                } else {
                    out.flags.push(key);
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.options
            .get(key)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{key}: bad integer `{v}`"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{key}: bad integer `{v}`"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{key}: bad float `{v}`"))),
        }
    }

    /// Load a config file (if `--config`) and apply `--set` overrides.
    pub fn build_config(&self) -> Result<crate::config::Config> {
        let mut doc = match self.get("config") {
            Some(path) => crate::config::Document::load(std::path::Path::new(path))?,
            None => crate::config::Document::parse("")?,
        };
        for ov in self.get_all("set") {
            doc.set_raw(ov)?;
        }
        crate::config::Config::from_doc(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new()
            .subcommands(&["train", "simulate"])
            .value_keys(&["config", "set", "workers", "out"])
            .short('v', "verbose")
            .short('q', "quiet")
            .short('c', "config")
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = spec()
            .parse(["train", "--config", "c.toml", "--verbose", "pos1"])
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("c.toml"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax_and_repeats() {
        let a = spec()
            .parse(["--set", "a.b=1", "--set=c.d=2", "--workers=8"])
            .unwrap();
        assert_eq!(a.get_all("set"), vec!["a.b=1", "c.d=2"]);
        assert_eq!(a.usize_or("workers", 0).unwrap(), 8);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(spec().parse(["--config"]).is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let a = spec().parse(["--workers", "abc"]).unwrap();
        assert!(a.usize_or("workers", 0).is_err());
        assert_eq!(a.f64_or("missing", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn short_flags_expand_to_long() {
        let a = spec().parse(["train", "-v", "-c", "c.toml"]).unwrap();
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("config"), Some("c.toml"));
    }

    #[test]
    fn unregistered_single_dash_stays_positional() {
        let a = spec().parse(["train", "-x", "-1.5", "-vv"]).unwrap();
        assert_eq!(a.positional, vec!["-x", "-1.5", "-vv"]);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn non_subcommand_first_positional() {
        let a = spec().parse(["notasub", "x"]).unwrap();
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["notasub", "x"]);
    }

    #[test]
    fn build_config_with_overrides() {
        let a = spec()
            .parse(["--set", "cluster.workers=99", "--set", "train.lr=0.5"])
            .unwrap();
        let c = a.build_config().unwrap();
        assert_eq!(c.cluster.workers, 99);
        assert_eq!(c.train.lr, 0.5);
    }
}
