//! Run metrics: step records, counters, CSV/JSON export.
//!
//! Every trainer/simulator run produces a [`RunLog`]; the report layer
//! and EXPERIMENTS.md consume its CSV/JSON output.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::util::Result;

/// One training/simulation step record.
#[derive(Debug, Clone, Default)]
pub struct StepRecord {
    pub step: usize,
    /// Virtual (simulated) time at the *end* of this step, seconds.
    pub virtual_time: f64,
    /// Wall-clock spent on real compute this step, seconds.
    pub wall_time: f64,
    /// Iteration time (max worker compute + comm), seconds.
    pub iter_time: f64,
    /// Micro-batches completed, summed over workers.
    pub completed_microbatches: usize,
    /// Micro-batches scheduled (N*M).
    pub scheduled_microbatches: usize,
    pub loss: f64,
    pub lr: f64,
    pub grad_norm: f64,
}

impl StepRecord {
    pub fn drop_rate(&self) -> f64 {
        if self.scheduled_microbatches == 0 {
            0.0
        } else {
            1.0 - self.completed_microbatches as f64
                / self.scheduled_microbatches as f64
        }
    }
}

/// Full run log: steps + free-form scalar summary fields.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub steps: Vec<StepRecord>,
    pub summary: BTreeMap<String, f64>,
    pub label: String,
}

impl RunLog {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), ..Default::default() }
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.steps.push(rec);
    }

    pub fn set_summary(&mut self, key: &str, value: f64) {
        self.summary.insert(key.to_string(), value);
    }

    pub fn total_virtual_time(&self) -> f64 {
        self.steps.last().map(|s| s.virtual_time).unwrap_or(0.0)
    }

    pub fn mean_iter_time(&self) -> f64 {
        if self.steps.is_empty() {
            return f64::NAN;
        }
        self.steps.iter().map(|s| s.iter_time).sum::<f64>()
            / self.steps.len() as f64
    }

    pub fn mean_drop_rate(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.drop_rate()).sum::<f64>()
            / self.steps.len() as f64
    }

    pub fn final_loss(&self) -> f64 {
        self.steps.last().map(|s| s.loss).unwrap_or(f64::NAN)
    }

    /// Micro-batches per virtual second (the paper's throughput metric).
    pub fn throughput(&self) -> f64 {
        let t = self.total_virtual_time();
        if t <= 0.0 {
            return f64::NAN;
        }
        self.steps
            .iter()
            .map(|s| s.completed_microbatches as f64)
            .sum::<f64>()
            / t
    }

    /// Write steps as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "step,virtual_time,wall_time,iter_time,completed,scheduled,drop_rate,loss,lr,grad_norm"
        )?;
        for s in &self.steps {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{},{},{:.6},{:.6},{:.8},{:.6}",
                s.step,
                s.virtual_time,
                s.wall_time,
                s.iter_time,
                s.completed_microbatches,
                s.scheduled_microbatches,
                s.drop_rate(),
                s.loss,
                s.lr,
                s.grad_norm
            )?;
        }
        Ok(())
    }

    /// Minimal JSON (summary + per-step arrays) without a JSON library.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"label\":\"{}\",", escape(&self.label)));
        out.push_str("\"summary\":{");
        let items: Vec<String> = self
            .summary
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", escape(k), fmt_f64(*v)))
            .collect();
        out.push_str(&items.join(","));
        out.push_str("},");
        let col = |f: &dyn Fn(&StepRecord) -> String| -> String {
            self.steps.iter().map(|s| f(s)).collect::<Vec<_>>().join(",")
        };
        // Every CSV column rides along (wall_time, completed/scheduled,
        // lr, grad_norm used to be silently dropped here).
        out.push_str(&format!(
            "\"step\":[{}],\"virtual_time\":[{}],\"wall_time\":[{}],\
             \"iter_time\":[{}],\"completed\":[{}],\"scheduled\":[{}],\
             \"loss\":[{}],\"lr\":[{}],\"grad_norm\":[{}],\"drop_rate\":[{}]",
            col(&|s| s.step.to_string()),
            col(&|s| fmt_f64(s.virtual_time)),
            col(&|s| fmt_f64(s.wall_time)),
            col(&|s| fmt_f64(s.iter_time)),
            col(&|s| s.completed_microbatches.to_string()),
            col(&|s| s.scheduled_microbatches.to_string()),
            col(&|s| fmt_f64(s.loss)),
            col(&|s| fmt_f64(s.lr)),
            col(&|s| fmt_f64(s.grad_norm)),
            col(&|s| fmt_f64(s.drop_rate())),
        ));
        out.push('}');
        out
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// JSON string escaping: backslash, quote, and control characters
/// (a label with an embedded newline/tab used to produce invalid JSON).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> RunLog {
        let mut log = RunLog::new("test");
        for i in 0..5 {
            log.push(StepRecord {
                step: i,
                virtual_time: (i + 1) as f64,
                iter_time: 1.0,
                completed_microbatches: 9,
                scheduled_microbatches: 10,
                loss: 5.0 - i as f64 * 0.5,
                ..Default::default()
            });
        }
        log.set_summary("speedup", 1.25);
        log
    }

    #[test]
    fn drop_rate_and_throughput() {
        let log = sample_log();
        assert!((log.mean_drop_rate() - 0.1).abs() < 1e-12);
        assert!((log.throughput() - 9.0).abs() < 1e-12);
        assert_eq!(log.final_loss(), 3.0);
        assert_eq!(log.mean_iter_time(), 1.0);
    }

    #[test]
    fn csv_roundtrip() {
        let log = sample_log();
        let dir = std::env::temp_dir().join("dc_metrics_test");
        let path = dir.join("run.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,"));
        assert_eq!(text.lines().count(), 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_shape() {
        let j = sample_log().to_json();
        assert!(j.contains("\"label\":\"test\""));
        assert!(j.contains("\"speedup\":1.25"));
        assert!(j.contains("\"loss\":[5,4.5,4,3.5,3]"));
        // The once-dropped CSV columns are present with full length.
        assert!(j.contains("\"wall_time\":[0,0,0,0,0]"));
        assert!(j.contains("\"completed\":[9,9,9,9,9]"));
        assert!(j.contains("\"scheduled\":[10,10,10,10,10]"));
        assert!(j.contains("\"lr\":[0,0,0,0,0]"));
        assert!(j.contains("\"grad_norm\":[0,0,0,0,0]"));
        // Parses with the in-tree JSON parser.
        let parsed = crate::runtime::json::Json::parse(&j).unwrap();
        assert_eq!(
            parsed.path(&["completed"]).unwrap().as_arr().unwrap().len(),
            5
        );
    }

    #[test]
    fn json_escapes_control_chars_in_labels() {
        let mut log = RunLog::new("line1\nline2\ttab\u{1}");
        log.push(StepRecord::default());
        let j = log.to_json();
        assert!(j.contains("line1\\nline2\\ttab\\u0001"));
        // Still valid JSON, and the label round-trips.
        let parsed = crate::runtime::json::Json::parse(&j).unwrap();
        assert_eq!(
            parsed.path(&["label"]).unwrap().as_str(),
            Some("line1\nline2\ttab\u{1}")
        );
    }

    #[test]
    fn empty_log_degenerate() {
        let log = RunLog::new("empty");
        assert_eq!(log.total_virtual_time(), 0.0);
        assert!(log.mean_iter_time().is_nan());
        assert!(log.throughput().is_nan());
    }
}
