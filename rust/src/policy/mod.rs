//! `DropPolicy` — the single drop-decision surface.
//!
//! DropCompute's core move is to bound each worker's step time; the
//! codebase grew four disconnected knobs for it: the compute threshold
//! `tau` (Algorithm 1), the step-level DropComm deadline (bounded-wait
//! collective membership), Local-SGD's period `H`, and — new here —
//! OptiReduce-style *per-phase* collective deadlines. [`DropPolicy`]
//! folds them into one closed, composable value (mirroring the
//! [`crate::sim::NoiseSampler`] redesign: a closed enum, no trait
//! objects, every consumer dispatches on the same type):
//!
//! * [`DropPolicy::ComputeTau`] — the paper's method: preempt compute
//!   at `tau`, drop the unfinished micro-batches;
//! * [`DropPolicy::CommDeadline`] — step-level DropComm: collective
//!   membership closes `deadline` after the first arrival;
//! * [`DropPolicy::PerPhaseDeadline`] — per-phase cutoffs evaluated
//!   inside the compiled schedule pass (and the event-queue oracle):
//!   checkpoint `p` drops workers not ready to enter phase `p` by
//!   `first_arrival + budgets[0] + ... + budgets[p]`;
//! * [`DropPolicy::LocalSgdPeriod`] — measure Local-SGD periods of `H`
//!   local steps (App. B.3) instead of synchronous steps;
//! * [`DropPolicy::Composed`] — any combination (e.g. compute `tau` +
//!   comm deadline = the topology ablation's "both" arm).
//!
//! Every variant answers the same two questions — *when does compute
//! get cut?* ([`DropPolicy::compute_cutoff`]) and *when does collective
//! phase `p` close its membership?* ([`DropPolicy::comm_cutoff`]) — and
//! flattens to an [`EffectivePolicy`] that `ClusterSim` installs once
//! (cumulative phase offsets precomputed, nothing allocated per step).
//!
//! Policies round-trip through a spec-string grammar shared by the CLI
//! (`--policy`), the `[policy]` config section and the sweep JSON:
//!
//! ```text
//! spec   := clause ('+' clause)*
//! clause := "none"
//!         | "tau=" f64 [",preempt" | ",between"]
//!         | "deadline=" f64
//!         | "phase-deadline=" f64 ('/' f64)*
//!         | "local-sgd=" int
//! ```
//!
//! e.g. `tau=9`, `deadline=3`, `tau=9,between+deadline=3`,
//! `phase-deadline=1.5/0.5/0.5`, `local-sgd=4+tau=0.9`.

use crate::config::ClusterConfig;
use crate::sim::PreemptionMode;
use crate::util::{Error, Result};

/// One drop-decision policy: the closed union of every way this crate
/// can bound a synchronous step (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum DropPolicy {
    /// No drops: vanilla synchronous training.
    None,
    /// Algorithm 1: preempt compute at `tau`; unfinished micro-batches
    /// are dropped. `preemption` picks the theory model (stop exactly
    /// at `tau`) or the reference-implementation model (finish the
    /// crossing micro-batch).
    ComputeTau { tau: f64, preemption: PreemptionMode },
    /// Step-level DropComm: collective membership closes `deadline`
    /// seconds after the first arrival; later workers are excluded and
    /// their step contribution dropped.
    CommDeadline { deadline: f64 },
    /// Per-phase DropComm (à la OptiReduce): checkpoint `p` closes at
    /// `first_arrival + budgets[0] + ... + budgets[p]`; a worker not
    /// ready to *enter* phase `p` by that instant is excluded. With a
    /// single lumped budget this is exactly [`DropPolicy::CommDeadline`]
    /// (property-tested); extra budgets add checkpoints deeper into the
    /// collective, catching workers stalled by slow dependency chains
    /// that a step-level deadline cannot see. Phases beyond
    /// `budgets.len()` are unconstrained.
    PerPhaseDeadline { budgets: Vec<f64> },
    /// Local-SGD (App. B.3): one period = `h` local steps of one
    /// micro-batch each, then a sync. Composes with `ComputeTau` (the
    /// threshold then applies per local step).
    LocalSgdPeriod { h: usize },
    /// Several policies applied together; cutoffs merge tightest-wins
    /// (min over components).
    Composed(Vec<DropPolicy>),
}

/// A [`DropPolicy`] flattened to the knobs one simulated step consumes.
/// `ClusterSim` computes this once per installed policy, so stepping
/// pays no per-step resolution cost.
#[derive(Debug, Clone, PartialEq)]
pub struct EffectivePolicy {
    /// Compute threshold (None = no compute drops).
    pub tau: Option<f64>,
    /// Preemption model for `tau` (meaningless without one).
    pub preemption: PreemptionMode,
    /// Step-level DropComm deadline (None = wait for everyone).
    pub step_deadline: Option<f64>,
    /// Cumulative per-phase cutoff offsets (`offsets[p]` = seconds
    /// after the first arrival by which phase `p`'s entry closes;
    /// empty = no per-phase policy). Already clamped non-negative.
    pub phase_offsets: Vec<f64>,
    /// Local-SGD period H (None = synchronous steps).
    pub local_sgd_h: Option<usize>,
}

impl Default for EffectivePolicy {
    fn default() -> Self {
        Self {
            tau: None,
            preemption: PreemptionMode::Preemptive,
            step_deadline: None,
            phase_offsets: Vec::new(),
            local_sgd_h: None,
        }
    }
}

impl EffectivePolicy {
    /// The per-phase cutoff offsets with a step-level deadline folded
    /// into the entry checkpoint — both express the same membership
    /// rule at phase 0, so the tighter one wins there. Empty when no
    /// per-phase policy is active (a pure step deadline stays on the
    /// step-level path).
    pub fn merged_phase_offsets(&self) -> Vec<f64> {
        let mut offsets = self.phase_offsets.clone();
        if let (Some(first), Some(d)) = (offsets.first_mut(), self.step_deadline)
        {
            let d = d.max(0.0);
            if d < *first {
                *first = d;
            }
        }
        offsets
    }
}

/// Cumulative cutoff offsets from raw per-phase budgets: entry `p` is
/// `max(b_0,0) + ... + max(b_p,0)`. The single source of the cumsum —
/// the compiled scan, the event-queue oracle and the tests all consume
/// offsets produced here, so the f64 addition order (and therefore
/// every bit) agrees everywhere.
pub fn cumulative_offsets(budgets: &[f64]) -> Vec<f64> {
    let mut cum = 0.0f64;
    budgets
        .iter()
        .map(|&b| {
            cum += b.max(0.0);
            cum
        })
        .collect()
}

/// The cutoff offsets *remaining* after checkpoint `last` triggered a
/// drop, rebased to the survivors' restart instant: entry `j` is
/// `offsets[last + 1 + j] - offsets[last]`. The recursive restart
/// semantics re-check the restarted collective against these (see
/// [`crate::sim::ClusterSim`]); the compiled path and the event-queue
/// oracle both consume offsets produced by this one expression, so the
/// f64 subtraction — and therefore every bit of the recursion — agrees
/// everywhere. Offsets are cumulative (nondecreasing), so every rebased
/// entry is `>= 0`.
pub fn rebased_offsets(offsets: &[f64], last: usize) -> Vec<f64> {
    match offsets.get(last + 1..) {
        Some(rest) => rest.iter().map(|o| o - offsets[last]).collect(),
        None => Vec::new(),
    }
}

/// [`rebased_offsets`] in place (the allocation-free form the compiled
/// drop path uses): shifts the rebased tail to the front and truncates.
/// Bitwise identical to the allocating form — same subtraction, same
/// order.
pub fn rebase_offsets_in_place(offsets: &mut Vec<f64>, last: usize) {
    if last + 1 >= offsets.len() {
        offsets.clear();
        return;
    }
    let pivot = offsets[last];
    let tail = offsets.len() - last - 1;
    for j in 0..tail {
        offsets[j] = offsets[last + 1 + j] - pivot;
    }
    offsets.truncate(tail);
}

impl DropPolicy {
    /// The no-drop policy (named constructor for symmetry).
    pub fn none() -> Self {
        DropPolicy::None
    }

    /// Algorithm 1 with the theory (preemptive) timeout model.
    pub fn compute_tau(tau: f64) -> Self {
        DropPolicy::ComputeTau { tau, preemption: PreemptionMode::Preemptive }
    }

    /// Step-level DropComm.
    pub fn comm_deadline(deadline: f64) -> Self {
        DropPolicy::CommDeadline { deadline }
    }

    /// Per-phase DropComm with the given raw budgets.
    pub fn per_phase_deadline(budgets: Vec<f64>) -> Self {
        DropPolicy::PerPhaseDeadline { budgets }
    }

    /// Local-SGD periods of `h` local steps.
    pub fn local_sgd(h: usize) -> Self {
        DropPolicy::LocalSgdPeriod { h }
    }

    /// Set the preemption model on every `ComputeTau` clause.
    pub fn with_preemption(mut self, mode: PreemptionMode) -> Self {
        self.set_preemption(mode);
        self
    }

    fn set_preemption(&mut self, mode: PreemptionMode) {
        match self {
            DropPolicy::ComputeTau { preemption, .. } => *preemption = mode,
            DropPolicy::Composed(ps) => {
                for p in ps {
                    p.set_preemption(mode);
                }
            }
            DropPolicy::None
            | DropPolicy::CommDeadline { .. }
            | DropPolicy::PerPhaseDeadline { .. }
            | DropPolicy::LocalSgdPeriod { .. } => {}
        }
    }

    /// Compose two policies (tightest cutoff wins where they overlap).
    /// `None` clauses vanish; nested `Composed`s flatten.
    pub fn and(self, other: DropPolicy) -> Self {
        let mut parts = Vec::new();
        self.flatten_into(&mut parts);
        other.flatten_into(&mut parts);
        match parts.len() {
            0 => DropPolicy::None,
            1 => parts.pop().expect("one part"),
            _ => DropPolicy::Composed(parts),
        }
    }

    fn flatten_into(self, out: &mut Vec<DropPolicy>) {
        match self {
            DropPolicy::None => {}
            DropPolicy::Composed(ps) => {
                for p in ps {
                    p.flatten_into(out);
                }
            }
            p => out.push(p),
        }
    }

    /// The legacy config surface as a policy: a positive
    /// `comm.drop_deadline` is a step-level [`DropPolicy::CommDeadline`]
    /// (0 keeps the synchronous wait-for-everyone collective, as the
    /// `[comm]` section always meant).
    pub fn from_cluster(cfg: &ClusterConfig) -> Self {
        if cfg.comm_drop_deadline > 0.0 {
            DropPolicy::CommDeadline { deadline: cfg.comm_drop_deadline }
        } else {
            DropPolicy::None
        }
    }

    /// Is this (recursively) the no-drop policy?
    pub fn is_none(&self) -> bool {
        match self {
            DropPolicy::None => true,
            DropPolicy::Composed(ps) => ps.iter().all(|p| p.is_none()),
            DropPolicy::ComputeTau { .. }
            | DropPolicy::CommDeadline { .. }
            | DropPolicy::PerPhaseDeadline { .. }
            | DropPolicy::LocalSgdPeriod { .. } => false,
        }
    }

    /// Uniform compute-side query: the threshold at which compute is
    /// cut, with its preemption model. Composed policies answer with
    /// the tightest `tau` (first clause wins ties).
    pub fn compute_cutoff(&self) -> Option<(f64, PreemptionMode)> {
        match self {
            DropPolicy::ComputeTau { tau, preemption } => {
                Some((*tau, *preemption))
            }
            DropPolicy::Composed(ps) => {
                let mut best: Option<(f64, PreemptionMode)> = None;
                for p in ps {
                    if let Some((tau, mode)) = p.compute_cutoff() {
                        if best.map_or(true, |(t, _)| tau < t) {
                            best = Some((tau, mode));
                        }
                    }
                }
                best
            }
            DropPolicy::None
            | DropPolicy::CommDeadline { .. }
            | DropPolicy::PerPhaseDeadline { .. }
            | DropPolicy::LocalSgdPeriod { .. } => None,
        }
    }

    /// Uniform comm-side query: the absolute instant at which phase
    /// `phase`'s entry membership closes, given the collective's first
    /// arrival. `None` = this policy does not constrain that phase.
    /// Step-level deadlines constrain phase 0 only; per-phase budgets
    /// constrain phases `0..budgets.len()`; Composed takes the min.
    pub fn comm_cutoff(&self, phase: usize, first: f64) -> Option<f64> {
        match self {
            DropPolicy::CommDeadline { deadline } => {
                (phase == 0).then(|| first + deadline.max(0.0))
            }
            DropPolicy::PerPhaseDeadline { budgets } => {
                if phase < budgets.len() {
                    // same cumsum as the install path — one source of
                    // truth for the offset arithmetic
                    cumulative_offsets(&budgets[..=phase])
                        .last()
                        .map(|&cum| first + cum)
                } else {
                    None
                }
            }
            DropPolicy::Composed(ps) => ps
                .iter()
                .filter_map(|p| p.comm_cutoff(phase, first))
                .fold(None, |acc, c| {
                    Some(match acc {
                        Some(a) if a <= c => a,
                        _ => c,
                    })
                }),
            DropPolicy::None
            | DropPolicy::ComputeTau { .. }
            | DropPolicy::LocalSgdPeriod { .. } => None,
        }
    }

    /// True when the policy acts purely on the comm side (membership
    /// deadlines, or nothing): no τ threshold and no local-SGD period.
    /// This is the contract the real transport enforces — its workers
    /// always compute every micro-batch, so a compute-side clause
    /// could never take effect and is rejected up front.
    pub fn comm_only(&self) -> bool {
        self.compute_cutoff().is_none() && self.local_sgd_h().is_none()
    }

    /// Local-SGD period, if this policy measures periods.
    pub fn local_sgd_h(&self) -> Option<usize> {
        match self {
            DropPolicy::LocalSgdPeriod { h } => Some(*h),
            DropPolicy::Composed(ps) => {
                ps.iter().find_map(|p| p.local_sgd_h())
            }
            DropPolicy::None
            | DropPolicy::ComputeTau { .. }
            | DropPolicy::CommDeadline { .. }
            | DropPolicy::PerPhaseDeadline { .. } => None,
        }
    }

    /// Flatten to the knobs one step consumes (see [`EffectivePolicy`]).
    pub fn effective(&self) -> EffectivePolicy {
        let mut eff = EffectivePolicy::default();
        self.fold_into(&mut eff);
        eff
    }

    fn fold_into(&self, eff: &mut EffectivePolicy) {
        match self {
            DropPolicy::None => {}
            DropPolicy::ComputeTau { tau, preemption } => {
                if eff.tau.map_or(true, |t| *tau < t) {
                    eff.tau = Some(*tau);
                    eff.preemption = *preemption;
                }
            }
            DropPolicy::CommDeadline { deadline } => {
                let d = deadline.max(0.0);
                eff.step_deadline =
                    Some(eff.step_deadline.map_or(d, |x| x.min(d)));
            }
            DropPolicy::PerPhaseDeadline { budgets } => {
                let offs = cumulative_offsets(budgets);
                if eff.phase_offsets.is_empty() {
                    eff.phase_offsets = offs;
                } else {
                    // elementwise tightest-wins; the longer tail keeps
                    // its extra checkpoints
                    for (i, o) in offs.iter().enumerate() {
                        if i < eff.phase_offsets.len() {
                            if *o < eff.phase_offsets[i] {
                                eff.phase_offsets[i] = *o;
                            }
                        } else {
                            eff.phase_offsets.push(*o);
                        }
                    }
                }
            }
            DropPolicy::LocalSgdPeriod { h } => {
                if eff.local_sgd_h.is_none() {
                    eff.local_sgd_h = Some(*h);
                }
            }
            DropPolicy::Composed(ps) => {
                for p in ps {
                    p.fold_into(eff);
                }
            }
        }
    }

    /// Structural validation (the config/CLI boundary calls this; the
    /// builders don't, so programmatic construction stays infallible).
    pub fn validate(&self) -> Result<()> {
        self.validate_inner()?;
        let mut h_count = 0usize;
        self.count_local_sgd(&mut h_count);
        if h_count > 1 {
            return Err(Error::Config(
                "policy: at most one local-sgd clause".into(),
            ));
        }
        Ok(())
    }

    fn count_local_sgd(&self, count: &mut usize) {
        match self {
            DropPolicy::LocalSgdPeriod { .. } => *count += 1,
            DropPolicy::Composed(ps) => {
                for p in ps {
                    p.count_local_sgd(count);
                }
            }
            DropPolicy::None
            | DropPolicy::ComputeTau { .. }
            | DropPolicy::CommDeadline { .. }
            | DropPolicy::PerPhaseDeadline { .. } => {}
        }
    }

    fn validate_inner(&self) -> Result<()> {
        match self {
            DropPolicy::None => Ok(()),
            DropPolicy::ComputeTau { tau, .. } => {
                if !(tau.is_finite() && *tau > 0.0) {
                    return Err(Error::Config(format!(
                        "policy: tau must be finite and > 0, got {tau}"
                    )));
                }
                Ok(())
            }
            DropPolicy::CommDeadline { deadline } => {
                if !(deadline.is_finite() && *deadline >= 0.0) {
                    return Err(Error::Config(format!(
                        "policy: deadline must be finite and >= 0, got {deadline}"
                    )));
                }
                Ok(())
            }
            DropPolicy::PerPhaseDeadline { budgets } => {
                if budgets.is_empty() {
                    return Err(Error::Config(
                        "policy: phase-deadline needs at least one budget"
                            .into(),
                    ));
                }
                for b in budgets {
                    if !(b.is_finite() && *b >= 0.0) {
                        return Err(Error::Config(format!(
                            "policy: phase budgets must be finite and >= 0, \
                             got {b}"
                        )));
                    }
                }
                Ok(())
            }
            DropPolicy::LocalSgdPeriod { h } => {
                if *h == 0 {
                    return Err(Error::Config(
                        "policy: local-sgd period must be >= 1".into(),
                    ));
                }
                Ok(())
            }
            DropPolicy::Composed(ps) => {
                if ps.is_empty() {
                    return Err(Error::Config(
                        "policy: empty composition".into(),
                    ));
                }
                for p in ps {
                    p.validate_inner()?;
                }
                Ok(())
            }
        }
    }

    /// Parse a spec string (see the module-docs grammar). Validates.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(Error::Config("policy: empty spec".into()));
        }
        let mut parts = Vec::new();
        for clause in spec.split('+') {
            let clause = clause.trim();
            let parsed = Self::parse_clause(clause)?;
            parsed.flatten_into(&mut parts);
        }
        let policy = match parts.len() {
            0 => DropPolicy::None,
            1 => parts.pop().expect("one part"),
            _ => DropPolicy::Composed(parts),
        };
        policy.validate()?;
        Ok(policy)
    }

    fn parse_clause(clause: &str) -> Result<Self> {
        if clause.eq_ignore_ascii_case("none") {
            return Ok(DropPolicy::None);
        }
        let (key, value) = clause.split_once('=').ok_or_else(|| {
            Error::Config(format!(
                "policy: bad clause `{clause}` (want none, tau=, deadline=, \
                 phase-deadline=, local-sgd=)"
            ))
        })?;
        let bad_num = |v: &str| {
            Error::Config(format!("policy: bad number `{v}` in `{clause}`"))
        };
        match key.trim() {
            "tau" => {
                let (num, mode) = match value.split_once(',') {
                    None => (value, PreemptionMode::Preemptive),
                    Some((num, m)) => {
                        let mode = match m.trim() {
                            "preempt" | "preemptive" => {
                                PreemptionMode::Preemptive
                            }
                            "between" | "between-accums" => {
                                PreemptionMode::BetweenAccumulations
                            }
                            other => {
                                return Err(Error::Config(format!(
                                    "policy: unknown preemption `{other}` \
                                     (want preempt or between)"
                                )))
                            }
                        };
                        (num, mode)
                    }
                };
                let tau: f64 =
                    num.trim().parse().map_err(|_| bad_num(num))?;
                Ok(DropPolicy::ComputeTau { tau, preemption: mode })
            }
            "deadline" => {
                let d: f64 =
                    value.trim().parse().map_err(|_| bad_num(value))?;
                Ok(DropPolicy::CommDeadline { deadline: d })
            }
            "phase-deadline" => {
                let budgets: Vec<f64> = value
                    .split('/')
                    .map(|v| v.trim().parse().map_err(|_| bad_num(v)))
                    .collect::<Result<_>>()?;
                Ok(DropPolicy::PerPhaseDeadline { budgets })
            }
            "local-sgd" => {
                let h: usize =
                    value.trim().parse().map_err(|_| bad_num(value))?;
                Ok(DropPolicy::LocalSgdPeriod { h })
            }
            other => Err(Error::Config(format!(
                "policy: unknown clause key `{other}`"
            ))),
        }
    }

    /// Render back to the spec-string grammar (round-trips through
    /// [`Self::parse`]; used by the sweep JSON and reports).
    pub fn spec(&self) -> String {
        match self {
            DropPolicy::None => "none".into(),
            DropPolicy::ComputeTau { tau, preemption } => match preemption {
                PreemptionMode::Preemptive => format!("tau={tau}"),
                PreemptionMode::BetweenAccumulations => {
                    format!("tau={tau},between")
                }
            },
            DropPolicy::CommDeadline { deadline } => {
                format!("deadline={deadline}")
            }
            DropPolicy::PerPhaseDeadline { budgets } => {
                let parts: Vec<String> =
                    budgets.iter().map(|b| format!("{b}")).collect();
                format!("phase-deadline={}", parts.join("/"))
            }
            DropPolicy::LocalSgdPeriod { h } => format!("local-sgd={h}"),
            DropPolicy::Composed(ps) => {
                let parts: Vec<String> =
                    ps.iter().map(|p| p.spec()).collect();
                if parts.is_empty() {
                    "none".into()
                } else {
                    parts.join("+")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_clause() {
        for spec in [
            "none",
            "tau=9",
            "tau=9,between",
            "deadline=3",
            "deadline=0",
            "phase-deadline=1.5",
            "phase-deadline=1.5/0.5/0.25",
            "local-sgd=4",
            "tau=9+deadline=3",
            "local-sgd=4+tau=0.9",
            "tau=9,between+phase-deadline=1/1",
        ] {
            let p = DropPolicy::parse(spec).expect(spec);
            assert_eq!(p.spec(), spec, "round trip");
            let again = DropPolicy::parse(&p.spec()).expect(spec);
            assert_eq!(p, again, "{spec}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        for spec in [
            "",
            "tau=",
            "tau=abc",
            "tau=-1",
            "tau=0",
            "deadline=-2",
            "phase-deadline=",
            "phase-deadline=1//2",
            "phase-deadline=-1",
            "local-sgd=0",
            "wat=3",
            "tau=9,sometimes",
            "local-sgd=2+local-sgd=3",
        ] {
            assert!(DropPolicy::parse(spec).is_err(), "{spec:?}");
        }
    }

    #[test]
    fn and_flattens_and_drops_none() {
        let p = DropPolicy::none()
            .and(DropPolicy::compute_tau(9.0))
            .and(DropPolicy::none())
            .and(DropPolicy::comm_deadline(3.0));
        assert_eq!(p.spec(), "tau=9+deadline=3");
        assert_eq!(DropPolicy::none().and(DropPolicy::none()), DropPolicy::None);
        // a single surviving clause is not wrapped
        assert_eq!(
            DropPolicy::none().and(DropPolicy::compute_tau(2.0)),
            DropPolicy::compute_tau(2.0)
        );
    }

    #[test]
    fn effective_merges_tightest_wins() {
        let p = DropPolicy::parse(
            "tau=9+tau=5,between+deadline=3+deadline=7+local-sgd=4",
        )
        .unwrap();
        let eff = p.effective();
        assert_eq!(eff.tau, Some(5.0));
        assert_eq!(eff.preemption, PreemptionMode::BetweenAccumulations);
        assert_eq!(eff.step_deadline, Some(3.0));
        assert_eq!(eff.local_sgd_h, Some(4));
        assert!(eff.phase_offsets.is_empty());
    }

    #[test]
    fn effective_merges_phase_offsets_elementwise() {
        let p = DropPolicy::parse(
            "phase-deadline=1/1/1+phase-deadline=0.5/2",
        )
        .unwrap();
        let eff = p.effective();
        // cumulative: [1,2,3] min [0.5,2.5] elementwise, tail kept
        assert_eq!(eff.phase_offsets, vec![0.5, 2.0, 3.0]);
    }

    #[test]
    fn merged_offsets_fold_step_deadline_into_entry() {
        let p = DropPolicy::parse("phase-deadline=2/1+deadline=0.5").unwrap();
        let eff = p.effective();
        assert_eq!(eff.phase_offsets, vec![2.0, 3.0]);
        assert_eq!(eff.merged_phase_offsets(), vec![0.5, 3.0]);
        // no per-phase clause: merged offsets stay empty (pure step
        // deadline stays on the step-level path)
        let eff2 = DropPolicy::comm_deadline(0.5).effective();
        assert!(eff2.merged_phase_offsets().is_empty());
    }

    #[test]
    fn cumulative_offsets_clamp_negatives() {
        assert_eq!(cumulative_offsets(&[1.0, -2.0, 0.5]), vec![1.0, 1.0, 1.5]);
        assert!(cumulative_offsets(&[]).is_empty());
    }

    #[test]
    fn rebased_offsets_shift_and_agree_with_in_place() {
        let offsets = cumulative_offsets(&[1.0, 0.25, 0.5, 0.0]);
        assert_eq!(offsets, vec![1.0, 1.25, 1.75, 1.75]);
        let rem = rebased_offsets(&offsets, 0);
        assert_eq!(rem, vec![0.25, 0.75, 0.75]);
        // nondecreasing offsets rebase to nonnegative entries
        assert!(rem.iter().all(|&o| o >= 0.0));
        // triggering at (or past) the last checkpoint leaves nothing
        assert!(rebased_offsets(&offsets, 3).is_empty());
        assert!(rebased_offsets(&offsets, 9).is_empty());
        assert!(rebased_offsets(&[], 0).is_empty());
        // the in-place form is the same map, bit for bit
        for last in 0..4 {
            let want = rebased_offsets(&offsets, last);
            let mut buf = offsets.clone();
            rebase_offsets_in_place(&mut buf, last);
            assert_eq!(want.len(), buf.len(), "last={last}");
            for (a, b) in want.iter().zip(&buf) {
                assert_eq!(a.to_bits(), b.to_bits(), "last={last}");
            }
        }
    }

    #[test]
    fn comm_cutoff_uniform_interface() {
        let d = DropPolicy::comm_deadline(3.0);
        assert_eq!(d.comm_cutoff(0, 1.0), Some(4.0));
        assert_eq!(d.comm_cutoff(1, 1.0), None);
        let pp = DropPolicy::per_phase_deadline(vec![1.0, 0.5]);
        assert_eq!(pp.comm_cutoff(0, 1.0), Some(2.0));
        assert_eq!(pp.comm_cutoff(1, 1.0), Some(2.5));
        assert_eq!(pp.comm_cutoff(2, 1.0), None);
        // composed: tightest wins per phase
        let both = d.clone().and(pp.clone());
        assert_eq!(both.comm_cutoff(0, 1.0), Some(2.0));
        assert_eq!(both.comm_cutoff(1, 1.0), Some(2.5));
        // compute-side policies never constrain comm phases
        assert_eq!(DropPolicy::compute_tau(9.0).comm_cutoff(0, 1.0), None);
        // negative deadline clamps like the membership rule
        assert_eq!(
            DropPolicy::comm_deadline(-5.0).comm_cutoff(0, 1.0),
            Some(1.0)
        );
    }

    #[test]
    fn compute_cutoff_and_local_sgd_queries() {
        let p = DropPolicy::parse("local-sgd=4+tau=0.9").unwrap();
        assert_eq!(
            p.compute_cutoff(),
            Some((0.9, PreemptionMode::Preemptive))
        );
        assert_eq!(p.local_sgd_h(), Some(4));
        assert_eq!(DropPolicy::None.compute_cutoff(), None);
        assert_eq!(DropPolicy::None.local_sgd_h(), None);
        assert!(DropPolicy::None.is_none());
        assert!(!p.is_none());
    }

    #[test]
    fn from_cluster_mirrors_legacy_deadline_sniffing() {
        let mut cfg = ClusterConfig::default();
        assert!(DropPolicy::from_cluster(&cfg).is_none());
        cfg.comm_drop_deadline = 2.5;
        assert_eq!(
            DropPolicy::from_cluster(&cfg),
            DropPolicy::CommDeadline { deadline: 2.5 }
        );
    }

    #[test]
    fn with_preemption_reaches_nested_taus() {
        let p = DropPolicy::parse("tau=9+deadline=3")
            .unwrap()
            .with_preemption(PreemptionMode::BetweenAccumulations);
        assert_eq!(
            p.compute_cutoff(),
            Some((9.0, PreemptionMode::BetweenAccumulations))
        );
    }
}
