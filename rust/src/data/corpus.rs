//! Synthetic Zipf–Markov corpus: learnable structure for real loss curves.
//!
//! Token `t+1` is drawn from a blend of (a) a Zipfian unigram marginal
//! and (b) a deterministic-ish per-token successor table. The blend
//! weight controls how much next-token signal a model can learn: the
//! loss of a perfect model is strictly below the unigram entropy, so a
//! decreasing training loss is meaningful evidence of learning.
//!
//! Documents have log-normal lengths (Sobkowicz et al. 2013 — the same
//! motivation the paper uses for its delay model): variable-length data
//! is exactly the workload that makes per-worker compute heterogeneous.

use crate::config::DataConfig;
use crate::rng::Xoshiro256pp;

/// Streaming corpus generator.
#[derive(Debug, Clone)]
pub struct MarkovCorpus {
    pub vocab: usize,
    /// Cumulative Zipf distribution for O(log V) sampling.
    zipf_cdf: Vec<f64>,
    /// Successor seed table: succ[t] gives the preferred next token.
    succ: Vec<u32>,
    markov_weight: f64,
    doclen_mu: f64,
    doclen_sigma: f64,
    /// End-of-document separator token (reserved id 0).
    pub eod: u32,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, cfg: &DataConfig) -> Self {
        assert!(vocab >= 4, "vocab too small");
        let mut weights: Vec<f64> = (1..=vocab)
            .map(|r| 1.0 / (r as f64).powf(cfg.zipf_s))
            .collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        // successor table from a deterministic mix of the seed
        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed ^ 0xC0FFEE);
        let succ = (0..vocab)
            .map(|_| 1 + rng.next_below(vocab as u64 - 1) as u32)
            .collect();
        Self {
            vocab,
            zipf_cdf: weights,
            succ,
            markov_weight: cfg.markov_weight,
            doclen_mu: cfg.doclen_mu,
            doclen_sigma: cfg.doclen_sigma,
            eod: 0,
        }
    }

    fn sample_zipf(&self, rng: &mut Xoshiro256pp) -> u32 {
        let u = rng.next_f64();
        self.zipf_cdf.partition_point(|&c| c < u) as u32 % self.vocab as u32
    }

    /// Next token given the previous one.
    pub fn next_token(&self, prev: u32, rng: &mut Xoshiro256pp) -> u32 {
        if rng.next_f64() < self.markov_weight {
            // mostly-deterministic successor with slight jitter
            let base = self.succ[prev as usize % self.vocab];
            if rng.next_f64() < 0.9 {
                base
            } else {
                (base + 1 + rng.next_below(3) as u32) % self.vocab as u32
            }
        } else {
            self.sample_zipf(rng)
        }
    }

    /// Sample a document length (log-normal, >= 4 tokens).
    pub fn sample_doc_len(&self, rng: &mut Xoshiro256pp) -> usize {
        let z = rng.next_standard_normal();
        ((self.doclen_mu + self.doclen_sigma * z).exp() as usize).max(4)
    }

    /// Generate one document (terminated by `eod`).
    pub fn document(&self, rng: &mut Xoshiro256pp) -> Vec<u32> {
        let len = self.sample_doc_len(rng);
        let mut doc = Vec::with_capacity(len + 1);
        let mut prev = self.sample_zipf(rng);
        doc.push(prev);
        for _ in 1..len {
            prev = self.next_token(prev, rng);
            doc.push(prev);
        }
        doc.push(self.eod);
        doc
    }

    /// Fill a fixed-length token sequence from the document stream
    /// (packed — documents concatenated with separators).
    pub fn fill_sequence(&self, out: &mut [i32], rng: &mut Xoshiro256pp) {
        let mut i = 0;
        while i < out.len() {
            for tok in self.document(rng) {
                if i >= out.len() {
                    return;
                }
                out[i] = tok as i32;
                i += 1;
            }
        }
    }

    /// Per-token entropy upper bound: the unigram (Zipf) entropy in nats.
    /// A model exploiting the Markov structure must go well below this.
    pub fn unigram_entropy(&self) -> f64 {
        let mut prev = 0.0;
        let mut h = 0.0;
        for &c in &self.zipf_cdf {
            let p = c - prev;
            prev = c;
            if p > 0.0 {
                h -= p * p.ln();
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;

    fn corpus() -> MarkovCorpus {
        MarkovCorpus::new(64, &DataConfig::default())
    }

    #[test]
    fn tokens_in_range() {
        let c = corpus();
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let mut buf = vec![0i32; 4096];
        c.fill_sequence(&mut buf, &mut rng);
        for &t in &buf {
            assert!((0..64).contains(&t));
        }
    }

    #[test]
    fn doc_lengths_lognormal_spread() {
        let c = corpus();
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let lens: Vec<usize> = (0..5000).map(|_| c.sample_doc_len(&mut rng)).collect();
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        // LogNormal(4,1) mean = exp(4.5) ~ 90
        assert!((60.0..130.0).contains(&mean), "{mean}");
        let max = *lens.iter().max().unwrap();
        let min = *lens.iter().min().unwrap();
        assert!(max > 10 * min, "heavy tail expected: {min}..{max}");
    }

    #[test]
    fn deterministic_given_seed() {
        let c = corpus();
        let mut r1 = Xoshiro256pp::seed_from_u64(9);
        let mut r2 = Xoshiro256pp::seed_from_u64(9);
        let mut a = vec![0i32; 256];
        let mut b = vec![0i32; 256];
        c.fill_sequence(&mut a, &mut r1);
        c.fill_sequence(&mut b, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn markov_structure_is_predictable() {
        // With high markov weight, the empirical conditional entropy of
        // (prev -> next) must be far below the unigram entropy.
        let c = corpus();
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut buf = vec![0i32; 200_000];
        c.fill_sequence(&mut buf, &mut rng);
        let v = c.vocab;
        let mut joint = vec![0u32; v * v];
        let mut marginal = vec![0u32; v];
        for w in buf.windows(2) {
            joint[w[0] as usize * v + w[1] as usize] += 1;
            marginal[w[0] as usize] += 1;
        }
        let mut h_cond = 0.0;
        let total = (buf.len() - 1) as f64;
        for p in 0..v {
            if marginal[p] == 0 {
                continue;
            }
            for nx in 0..v {
                let cnt = joint[p * v + nx];
                if cnt == 0 {
                    continue;
                }
                let p_joint = cnt as f64 / total;
                let p_cond = cnt as f64 / marginal[p] as f64;
                h_cond -= p_joint * p_cond.ln();
            }
        }
        let h_uni = c.unigram_entropy();
        assert!(
            h_cond < 0.7 * h_uni,
            "conditional {h_cond} vs unigram {h_uni}"
        );
    }

    #[test]
    fn entropy_positive_and_bounded() {
        let c = corpus();
        let h = c.unigram_entropy();
        assert!(h > 0.0 && h <= (64f64).ln() + 1e-9);
    }
}
