//! Synthetic data substrates.
//!
//! * [`corpus`] — a Zipf–Markov language corpus with log-normal document
//!   lengths (the heterogeneity that motivates compute variance, App. A);
//! * [`loader`] — per-worker sharded micro-batch loader with a resample
//!   pool for dropped samples (§4.5's third compensation method);
//! * [`classification`] — synthetic classification task for the
//!   ResNet-50 generalization analogue (Fig 10/11).

pub mod classification;
pub mod corpus;
pub mod loader;

pub use classification::ClassificationTask;
pub use corpus::MarkovCorpus;
pub use loader::{MicroBatch, ShardedLoader};
