//! Synthetic classification task — the Fig 10/11 ResNet-50/ImageNet
//! analogue (see DESIGN.md §Substitutions).
//!
//! Inputs are `dim`-d Gaussian clusters (one per class, fixed random
//! centroids, within-class noise); a linear-softmax model trained with
//! SGD/LARS on this task shows the same accuracy-vs-drop-rate behaviour
//! the paper probes: whole-worker gradient drops with probability
//! `p_drop` leave accuracy unchanged up to ~10%.

use crate::rng::Xoshiro256pp;

/// Generator of a fixed synthetic classification problem.
#[derive(Debug, Clone)]
pub struct ClassificationTask {
    pub classes: usize,
    pub dim: usize,
    pub noise: f64,
    centroids: Vec<f32>,
}

impl ClassificationTask {
    pub fn new(classes: usize, dim: usize, noise: f64, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let centroids = (0..classes * dim)
            .map(|_| rng.next_standard_normal() as f32)
            .collect();
        Self { classes, dim, noise, centroids }
    }

    /// Sample `n` (x, label) pairs into flat buffers.
    pub fn sample(&self, n: usize, rng: &mut Xoshiro256pp) -> (Vec<f32>, Vec<u32>) {
        let mut xs = Vec::with_capacity(n * self.dim);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.next_below(self.classes as u64) as usize;
            ys.push(c as u32);
            for d in 0..self.dim {
                let base = self.centroids[c * self.dim + d];
                xs.push(base + self.noise as f32 * rng.next_standard_normal() as f32);
            }
        }
        (xs, ys)
    }

    /// Bayes-ish reference accuracy: nearest-centroid classification.
    pub fn centroid_accuracy(&self, xs: &[f32], ys: &[u32]) -> f64 {
        let n = ys.len();
        let mut correct = 0usize;
        for i in 0..n {
            let x = &xs[i * self.dim..(i + 1) * self.dim];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..self.classes {
                let cen = &self.centroids[c * self.dim..(c + 1) * self.dim];
                let d2: f32 =
                    x.iter().zip(cen).map(|(a, b)| (a - b) * (a - b)).sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == ys[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_in_range_and_shapes() {
        let task = ClassificationTask::new(10, 16, 0.3, 0);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let (xs, ys) = task.sample(100, &mut rng);
        assert_eq!(xs.len(), 1600);
        assert_eq!(ys.len(), 100);
        assert!(ys.iter().all(|&y| y < 10));
    }

    #[test]
    fn separable_at_low_noise() {
        let task = ClassificationTask::new(8, 32, 0.2, 2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let (xs, ys) = task.sample(1000, &mut rng);
        let acc = task.centroid_accuracy(&xs, &ys);
        assert!(acc > 0.97, "low-noise task should be separable: {acc}");
    }

    #[test]
    fn harder_at_high_noise() {
        let task = ClassificationTask::new(8, 8, 3.0, 2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let (xs, ys) = task.sample(2000, &mut rng);
        let acc = task.centroid_accuracy(&xs, &ys);
        assert!(acc < 0.9, "high noise must hurt: {acc}");
        assert!(acc > 1.0 / 8.0, "but above chance");
    }
}
