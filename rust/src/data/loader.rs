//! Per-worker sharded micro-batch loader.
//!
//! Data-parallel semantics: worker `n` of `N` sees an independent stream
//! (split RNG), giving disjoint shards without coordination. Dropped
//! micro-batches can be pushed back into a resample pool so they are
//! revisited "before starting a new epoch" (§4.5, third compensation).

use crate::config::DataConfig;
use crate::rng::Xoshiro256pp;

use super::corpus::MarkovCorpus;

/// One micro-batch of packed token sequences, shape `[batch, seq]` i32.
#[derive(Debug, Clone, PartialEq)]
pub struct MicroBatch {
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl MicroBatch {
    pub fn numel(&self) -> usize {
        self.batch * self.seq
    }
}

/// Sharded loader for one worker.
pub struct ShardedLoader {
    corpus: MarkovCorpus,
    rng: Xoshiro256pp,
    batch: usize,
    seq: usize,
    /// Dropped micro-batches awaiting resampling.
    resample_pool: Vec<MicroBatch>,
    pub produced: usize,
    pub resampled: usize,
}

impl ShardedLoader {
    /// `worker` selects the shard (split RNG stream).
    pub fn new(
        vocab: usize,
        batch: usize,
        seq: usize,
        cfg: &DataConfig,
        worker: usize,
    ) -> Self {
        let root = Xoshiro256pp::seed_from_u64(cfg.seed);
        Self {
            corpus: MarkovCorpus::new(vocab, cfg),
            rng: root.split(worker as u64 + 1),
            batch,
            seq,
            resample_pool: Vec::new(),
            produced: 0,
            resampled: 0,
        }
    }

    /// Next micro-batch: resample pool first, then fresh data.
    pub fn next(&mut self) -> MicroBatch {
        if let Some(mb) = self.resample_pool.pop() {
            self.resampled += 1;
            return mb;
        }
        let mut tokens = vec![0i32; self.batch * self.seq];
        for row in tokens.chunks_mut(self.seq) {
            self.corpus.fill_sequence(row, &mut self.rng);
        }
        self.produced += 1;
        MicroBatch { tokens, batch: self.batch, seq: self.seq }
    }

    /// Return a dropped micro-batch to the pool (§4.5 re-computation).
    pub fn push_dropped(&mut self, mb: MicroBatch) {
        self.resample_pool.push(mb);
    }

    pub fn pool_len(&self) -> usize {
        self.resample_pool.len()
    }

    pub fn corpus(&self) -> &MarkovCorpus {
        &self.corpus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loader(worker: usize) -> ShardedLoader {
        ShardedLoader::new(64, 2, 16, &DataConfig::default(), worker)
    }

    #[test]
    fn shapes_and_ranges() {
        let mut l = loader(0);
        let mb = l.next();
        assert_eq!(mb.tokens.len(), 32);
        assert_eq!(mb.numel(), 32);
        assert!(mb.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn workers_get_disjoint_streams() {
        let a = loader(0).next();
        let b = loader(1).next();
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn deterministic_per_worker() {
        let a = loader(3).next();
        let b = loader(3).next();
        assert_eq!(a, b);
    }

    #[test]
    fn resample_pool_fifo_behavior() {
        let mut l = loader(0);
        let m1 = l.next();
        let m2 = l.next();
        assert_ne!(m1, m2);
        l.push_dropped(m1.clone());
        assert_eq!(l.pool_len(), 1);
        let got = l.next();
        assert_eq!(got, m1);
        assert_eq!(l.resampled, 1);
        assert_eq!(l.pool_len(), 0);
    }
}
