//! Phase-structured communication schedules.
//!
//! A [`Schedule`] is the *lingua franca* between the topology builders
//! ([`super::kinds`]), the event-driven timing model
//! ([`crate::sim::comm::schedule_completion`]) and the real in-process
//! executor ([`crate::collective::engine`]): an ordered list of phases,
//! each a set of point-to-point [`Transfer`]s. Both consumers interpret
//! the same object, which is what lets the tests assert that virtual
//! time and real threads agree on every topology.
//!
//! Invariant (checked by [`Schedule::validate`]): within one phase every
//! worker sends at most one message and receives at most one message.
//! All four built-in topologies satisfy it by construction; it is what
//! makes the per-phase timing recurrence exact (one hop per worker per
//! phase, no intra-phase link contention to model).

/// Which slice of the flat gradient buffer a transfer carries: part
/// `part` of `of` equal divisions. Resolved against the live buffer
/// length with [`chunk_bounds`], so the same schedule serves any
/// gradient size (uneven remainders go to the leading parts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub part: usize,
    pub of: usize,
}

impl Chunk {
    /// The whole buffer in one message.
    pub const FULL: Chunk = Chunk { part: 0, of: 1 };

    /// Fraction of the buffer's bytes this chunk occupies (timing model).
    pub fn fraction(&self) -> f64 {
        1.0 / self.of as f64
    }

    /// Concrete `[start, end)` element range for a buffer of `len`.
    pub fn bounds(&self, len: usize) -> (usize, usize) {
        chunk_bounds(len, self.of, self.part)
    }
}

/// Chunk boundaries for splitting `len` into `size` contiguous chunks
/// (chunk `idx` of `size`; the first `len % size` chunks get one extra
/// element). Shared with the ring collective in `collective`.
pub fn chunk_bounds(len: usize, size: usize, idx: usize) -> (usize, usize) {
    let base = len / size;
    let rem = len % size;
    let start = idx * base + idx.min(rem);
    let extra = if idx < rem { 1 } else { 0 };
    (start, start + base + extra)
}

/// What the receiver does with an incoming chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferOp {
    /// `local += incoming` elementwise (reduce-scatter / reduce phases).
    /// The executor always accumulates *into* the local buffer in
    /// schedule order, which fixes the reduction association — the
    /// bitwise-determinism requirement of synchronous training.
    Reduce,
    /// `local = incoming` (all-gather / broadcast phases).
    Copy,
}

/// One point-to-point message within a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    pub src: usize,
    pub dst: usize,
    pub chunk: Chunk,
    pub op: TransferOp,
}

/// One phase: a set of transfers whose sends all depend only on the
/// previous phases' receives.
#[derive(Debug, Clone, Default)]
pub struct Phase {
    pub transfers: Vec<Transfer>,
}

/// A complete all-reduce schedule for `workers` participants.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub workers: usize,
    pub phases: Vec<Phase>,
}

impl Schedule {
    /// An empty (no-communication) schedule, correct for `n <= 1`.
    pub fn empty(workers: usize) -> Self {
        Self { workers, phases: Vec::new() }
    }

    pub fn phase_count(&self) -> usize {
        self.phases.len()
    }

    /// Total messages across all phases.
    pub fn transfer_count(&self) -> usize {
        self.phases.iter().map(|p| p.transfers.len()).sum()
    }

    /// Check the structural invariants: indices in range, no self-sends,
    /// and per phase at most one send and one receive per worker.
    pub fn validate(&self) -> Result<(), String> {
        for (pi, phase) in self.phases.iter().enumerate() {
            let mut sends = vec![false; self.workers];
            let mut recvs = vec![false; self.workers];
            for t in &phase.transfers {
                if t.src >= self.workers || t.dst >= self.workers {
                    return Err(format!(
                        "phase {pi}: transfer {}->{} out of range (n={})",
                        t.src, t.dst, self.workers
                    ));
                }
                if t.src == t.dst {
                    return Err(format!("phase {pi}: self-send at {}", t.src));
                }
                if t.chunk.of == 0 || t.chunk.part >= t.chunk.of {
                    return Err(format!(
                        "phase {pi}: bad chunk {}/{}",
                        t.chunk.part, t.chunk.of
                    ));
                }
                if std::mem::replace(&mut sends[t.src], true) {
                    return Err(format!(
                        "phase {pi}: worker {} sends twice",
                        t.src
                    ));
                }
                if std::mem::replace(&mut recvs[t.dst], true) {
                    return Err(format!(
                        "phase {pi}: worker {} receives twice",
                        t.dst
                    ));
                }
            }
        }
        Ok(())
    }

    /// Closed-form completion time for simultaneous arrivals at t=0:
    /// the same per-phase readiness recurrence the event simulation
    /// runs, collapsed (uniform arrivals make the dependency DAG
    /// layered, so no queue is needed). Each transfer costs
    /// `latency + fraction * bytes / bandwidth`.
    pub fn uniform_cost(&self, latency: f64, bandwidth: f64, bytes: f64) -> f64 {
        let mut ready = vec![0.0f64; self.workers];
        for phase in &self.phases {
            let mut next = ready.clone();
            for t in &phase.transfers {
                let hop = latency + t.chunk.fraction() * bytes / bandwidth;
                let done = ready[t.src] + hop;
                if done > next[t.dst] {
                    next[t.dst] = done;
                }
                if done > next[t.src] {
                    next[t.src] = done;
                }
            }
            ready = next;
        }
        ready.iter().cloned().fold(0.0, f64::max)
    }

    /// Per-worker completion times under the [`Self::uniform_cost`]
    /// readiness recurrence, but seeded with heterogeneous `arrivals`
    /// (one per worker, same time base). This is the prediction the
    /// real-transport conformance gate scores against measured wall
    /// clocks: given when each worker *actually* finished computing,
    /// when does the model say each finishes the collective?
    pub fn worker_completion_from(
        &self,
        arrivals: &[f64],
        latency: f64,
        bandwidth: f64,
        bytes: f64,
    ) -> Vec<f64> {
        debug_assert_eq!(arrivals.len(), self.workers, "arrival count");
        let mut ready = arrivals.to_vec();
        for phase in &self.phases {
            let mut next = ready.clone();
            for t in &phase.transfers {
                let hop = latency + t.chunk.fraction() * bytes / bandwidth;
                let done = ready[t.src] + hop;
                if done > next[t.dst] {
                    next[t.dst] = done;
                }
                if done > next[t.src] {
                    next[t.src] = done;
                }
            }
            ready = next;
        }
        ready
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_bounds_partition_everything() {
        for (len, size) in [(10, 3), (7, 7), (5, 8), (16, 4), (1, 1)] {
            let mut covered = 0;
            for i in 0..size {
                let (a, b) = chunk_bounds(len, size, i);
                assert_eq!(a, covered, "len={len} size={size} i={i}");
                covered = b;
            }
            assert_eq!(covered, len);
        }
    }

    #[test]
    fn chunk_fraction_and_full() {
        assert_eq!(Chunk::FULL.fraction(), 1.0);
        assert_eq!(Chunk { part: 2, of: 4 }.fraction(), 0.25);
        assert_eq!(Chunk::FULL.bounds(17), (0, 17));
    }

    #[test]
    fn validate_catches_double_send() {
        let bad = Schedule {
            workers: 3,
            phases: vec![Phase {
                transfers: vec![
                    Transfer {
                        src: 0,
                        dst: 1,
                        chunk: Chunk::FULL,
                        op: TransferOp::Reduce,
                    },
                    Transfer {
                        src: 0,
                        dst: 2,
                        chunk: Chunk::FULL,
                        op: TransferOp::Reduce,
                    },
                ],
            }],
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validate_catches_self_send_and_range() {
        let self_send = Schedule {
            workers: 2,
            phases: vec![Phase {
                transfers: vec![Transfer {
                    src: 1,
                    dst: 1,
                    chunk: Chunk::FULL,
                    op: TransferOp::Copy,
                }],
            }],
        };
        assert!(self_send.validate().is_err());
        let oob = Schedule {
            workers: 2,
            phases: vec![Phase {
                transfers: vec![Transfer {
                    src: 0,
                    dst: 5,
                    chunk: Chunk::FULL,
                    op: TransferOp::Copy,
                }],
            }],
        };
        assert!(oob.validate().is_err());
    }

    #[test]
    fn empty_schedule_costs_nothing() {
        let s = Schedule::empty(1);
        assert_eq!(s.uniform_cost(1e-4, 1e9, 4e6), 0.0);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn worker_completion_from_generalizes_uniform_cost() {
        let mut s = Schedule::empty(3);
        s.phases.push(Phase {
            transfers: vec![
                Transfer {
                    src: 0,
                    dst: 1,
                    chunk: Chunk::FULL,
                    op: TransferOp::Reduce,
                },
                Transfer {
                    src: 2,
                    dst: 0,
                    chunk: Chunk::FULL,
                    op: TransferOp::Reduce,
                },
            ],
        });
        // zero arrivals reproduce uniform_cost at the max
        let z = s.worker_completion_from(&[0.0; 3], 1e-3, 1e9, 4e6);
        let max = z.iter().cloned().fold(0.0, f64::max);
        assert_eq!(max.to_bits(), s.uniform_cost(1e-3, 1e9, 4e6).to_bits());
        // a straggling sender delays its receiver past the straggle
        let hop = 1e-3 + 4e6 / 1e9;
        let f = s.worker_completion_from(&[0.0, 0.0, 0.5], 1e-3, 1e9, 4e6);
        assert_eq!(f[0].to_bits(), (0.5 + hop).to_bits());
        assert_eq!(f[1].to_bits(), hop.to_bits());
        assert_eq!(f[2].to_bits(), (0.5 + hop).to_bits());
    }
}
