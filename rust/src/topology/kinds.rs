//! The built-in collective topologies: ring, binomial tree, two-level
//! hierarchical ring-of-rings, and 2D torus.
//!
//! Each builder emits a [`Schedule`] whose executed result is the sum
//! over all workers (all-reduce). Reduction association differs between
//! topologies (that is the point of the ablation), but *within* one
//! topology it is fixed by the schedule, so repeated runs are bitwise
//! identical — and the ring schedule reproduces
//! [`crate::collective::ring_all_reduce`]'s association exactly.

use crate::util::{Error, Result};

use super::schedule::{Chunk, Phase, Schedule, Transfer, TransferOp};

/// A collective topology: a named factory of all-reduce schedules.
pub trait Topology {
    fn name(&self) -> &'static str;

    /// Build the all-reduce schedule for `n` workers. Must return a
    /// schedule that passes [`Schedule::validate`] and whose execution
    /// leaves every worker holding the global sum.
    fn schedule(&self, n: usize) -> Schedule;
}

/// Ring all-reduce: reduce-scatter + all-gather, 2(N-1) phases of `1/N`
/// chunks (bandwidth-optimal; Patarasuk & Yuan 2009).
#[derive(Debug, Clone, Copy, Default)]
pub struct Ring;

/// Binomial-tree all-reduce: reduce to rank 0, then broadcast —
/// 2·ceil(log2 N) phases of the full buffer (latency-optimal).
#[derive(Debug, Clone, Copy, Default)]
pub struct BinaryTree;

/// Two-level ring-of-rings: ring all-reduce inside each group of
/// `group` consecutive ranks, ring all-reduce across the group leaders,
/// then a pipeline broadcast of the global sum inside each group.
/// `group == 0` picks ceil(sqrt(N)) (balances the two levels).
#[derive(Debug, Clone, Copy, Default)]
pub struct HierarchicalRing {
    pub group: usize,
}

/// 2D torus: ring all-reduce along every row, then along every column.
/// `rows == 0` picks the largest divisor of N that is <= sqrt(N)
/// (degenerates to a single ring when N is prime).
#[derive(Debug, Clone, Copy, Default)]
pub struct Torus2d {
    pub rows: usize,
}

/// Ring all-reduce phases over an arbitrary member list: 2(k-1) phases,
/// chunks of `1/k` of the full buffer. Mirrors `ring_all_reduce`'s
/// send/recv indexing so the ring schedule is association-identical to
/// the hand-written collective.
fn ring_allreduce_phases(members: &[usize]) -> Vec<Phase> {
    let k = members.len();
    if k <= 1 {
        return Vec::new();
    }
    let mut phases = Vec::with_capacity(2 * (k - 1));
    // reduce-scatter: step s, member i sends chunk (i - s) mod k.
    for s in 0..k - 1 {
        let mut ph = Phase::default();
        for (i, &w) in members.iter().enumerate() {
            ph.transfers.push(Transfer {
                src: w,
                dst: members[(i + 1) % k],
                chunk: Chunk { part: (i + k - s) % k, of: k },
                op: TransferOp::Reduce,
            });
        }
        phases.push(ph);
    }
    // all-gather: step s, member i sends chunk (i + 1 - s) mod k.
    for s in 0..k - 1 {
        let mut ph = Phase::default();
        for (i, &w) in members.iter().enumerate() {
            ph.transfers.push(Transfer {
                src: w,
                dst: members[(i + 1) % k],
                chunk: Chunk { part: (i + 1 + k - s) % k, of: k },
                op: TransferOp::Copy,
            });
        }
        phases.push(ph);
    }
    phases
}

/// Merge several phase lists so they run concurrently: phase `p` of the
/// result is the union of phase `p` of every input (shorter lists simply
/// idle in the tail phases). Disjoint member sets keep the one-send/
/// one-recv invariant.
fn merge_concurrent(lists: Vec<Vec<Phase>>) -> Vec<Phase> {
    let depth = lists.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out: Vec<Phase> = (0..depth).map(|_| Phase::default()).collect();
    for list in lists {
        for (p, phase) in list.into_iter().enumerate() {
            out[p].transfers.extend(phase.transfers);
        }
    }
    out
}

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn schedule(&self, n: usize) -> Schedule {
        let members: Vec<usize> = (0..n).collect();
        Schedule { workers: n, phases: ring_allreduce_phases(&members) }
    }
}

impl Topology for BinaryTree {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn schedule(&self, n: usize) -> Schedule {
        let mut phases = Vec::new();
        if n <= 1 {
            return Schedule::empty(n);
        }
        // Reduce phase r (stride s = 2^r): rank w with w mod 2s == s
        // ships its partial sum to w - s, which accumulates. Mirrors
        // `tree_all_reduce`'s association exactly.
        let mut s = 1;
        while s < n {
            let mut ph = Phase::default();
            let mut w = s;
            while w < n {
                ph.transfers.push(Transfer {
                    src: w,
                    dst: w - s,
                    chunk: Chunk::FULL,
                    op: TransferOp::Reduce,
                });
                w += 2 * s;
            }
            phases.push(ph);
            s <<= 1;
        }
        // Broadcast: mirror image top-down from rank 0.
        let mut s = usize::next_power_of_two(n) >> 1;
        while s >= 1 {
            let mut ph = Phase::default();
            let mut w = 0;
            while w + s < n {
                ph.transfers.push(Transfer {
                    src: w,
                    dst: w + s,
                    chunk: Chunk::FULL,
                    op: TransferOp::Copy,
                });
                w += 2 * s;
            }
            phases.push(ph);
            s >>= 1;
        }
        Schedule { workers: n, phases }
    }
}

impl HierarchicalRing {
    /// Resolve the group size for `n` workers (0 = auto ceil(sqrt(n))).
    pub fn group_for(&self, n: usize) -> usize {
        if self.group > 0 {
            return self.group.min(n.max(1));
        }
        let mut g = 1usize;
        while g * g < n {
            g += 1;
        }
        g.max(1)
    }
}

impl Topology for HierarchicalRing {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn schedule(&self, n: usize) -> Schedule {
        if n <= 1 {
            return Schedule::empty(n);
        }
        let g = self.group_for(n);
        let groups: Vec<Vec<usize>> = (0..n)
            .step_by(g)
            .map(|start| (start..(start + g).min(n)).collect())
            .collect();
        if groups.len() == 1 {
            // one group covers everyone: its all-reduce is already
            // global, so the leader ring and broadcast would be waste.
            return Schedule {
                workers: n,
                phases: ring_allreduce_phases(&groups[0]),
            };
        }

        // Level 1: concurrent ring all-reduce inside every group — each
        // member ends with its group's sum.
        let intra = merge_concurrent(
            groups.iter().map(|m| ring_allreduce_phases(m)).collect(),
        );
        // Level 2: ring all-reduce across the group leaders.
        let leaders: Vec<usize> = groups.iter().map(|m| m[0]).collect();
        let inter = ring_allreduce_phases(&leaders);
        // Level 3: pipeline broadcast of the global sum down each group
        // (leader -> member1 -> member2 -> ...), full buffer per hop.
        let bcast = merge_concurrent(
            groups
                .iter()
                .map(|m| {
                    m.windows(2)
                        .map(|w| Phase {
                            transfers: vec![Transfer {
                                src: w[0],
                                dst: w[1],
                                chunk: Chunk::FULL,
                                op: TransferOp::Copy,
                            }],
                        })
                        .collect()
                })
                .collect(),
        );

        let mut phases = intra;
        phases.extend(inter);
        phases.extend(bcast);
        Schedule { workers: n, phases }
    }
}

impl Torus2d {
    /// Resolve the row count for `n` workers (0 = auto: the largest
    /// divisor of n not exceeding sqrt(n); 1 for prime n).
    pub fn rows_for(&self, n: usize) -> usize {
        if self.rows > 0 && n % self.rows == 0 {
            return self.rows;
        }
        // rows == 0 or the requested rows don't divide n: auto-pick.
        let mut best = 1usize;
        let mut d = 1usize;
        while d * d <= n {
            if n % d == 0 {
                best = d;
            }
            d += 1;
        }
        best
    }
}

impl Topology for Torus2d {
    fn name(&self) -> &'static str {
        "torus"
    }

    fn schedule(&self, n: usize) -> Schedule {
        if n <= 1 {
            return Schedule::empty(n);
        }
        let r = self.rows_for(n);
        let c = n / r;
        // Step 1: ring all-reduce along every row (c members each) —
        // each node ends with its row's sum.
        let row_phases = merge_concurrent(
            (0..r)
                .map(|i| {
                    let members: Vec<usize> = (i * c..(i + 1) * c).collect();
                    ring_allreduce_phases(&members)
                })
                .collect(),
        );
        // Step 2: ring all-reduce along every column (r members each) —
        // row sums combine into the global sum everywhere.
        let col_phases = merge_concurrent(
            (0..c)
                .map(|j| {
                    let members: Vec<usize> =
                        (0..r).map(|i| i * c + j).collect();
                    ring_allreduce_phases(&members)
                })
                .collect(),
        );
        let mut phases = row_phases;
        phases.extend(col_phases);
        Schedule { workers: n, phases }
    }
}

/// Config/CLI-level topology selector (the trait objects above carry no
/// state beyond these parameters, so a `Copy` enum travels through
/// `ClusterConfig` cheaply).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TopologyKind {
    Ring,
    Tree,
    /// Two-level ring-of-rings; `group == 0` = auto ceil(sqrt(N)).
    Hierarchical { group: usize },
    /// 2D torus; `rows == 0` = auto largest divisor <= sqrt(N).
    Torus { rows: usize },
}

impl TopologyKind {
    /// Every kind with auto parameters — the ablation sweep set.
    pub const ALL: [TopologyKind; 4] = [
        TopologyKind::Ring,
        TopologyKind::Tree,
        TopologyKind::Hierarchical { group: 0 },
        TopologyKind::Torus { rows: 0 },
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Tree => "tree",
            TopologyKind::Hierarchical { .. } => "hierarchical",
            TopologyKind::Torus { .. } => "torus",
        }
    }

    /// Build the schedule for `n` workers.
    pub fn build(&self, n: usize) -> Schedule {
        match *self {
            TopologyKind::Ring => Ring.schedule(n),
            TopologyKind::Tree => BinaryTree.schedule(n),
            TopologyKind::Hierarchical { group } => {
                HierarchicalRing { group }.schedule(n)
            }
            TopologyKind::Torus { rows } => Torus2d { rows }.schedule(n),
        }
    }

    /// Parse `ring | tree | hierarchical[:group] | torus[:rows]`
    /// (the `--topology` CLI flag and `comm.topology` config key).
    pub fn parse(s: &str) -> Result<Self> {
        let (head, param) = match s.split_once(':') {
            Some((h, p)) => {
                let v: usize = p.parse().map_err(|_| {
                    Error::Config(format!("topology `{s}`: bad parameter `{p}`"))
                })?;
                (h, v)
            }
            None => (s, 0),
        };
        Ok(match head {
            "ring" => TopologyKind::Ring,
            "tree" => TopologyKind::Tree,
            "hierarchical" | "hring" => {
                TopologyKind::Hierarchical { group: param }
            }
            "torus" => TopologyKind::Torus { rows: param },
            other => {
                return Err(Error::Config(format!(
                    "unknown topology `{other}` \
                     (ring | tree | hierarchical[:group] | torus[:rows])"
                )))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_sizes() -> Vec<usize> {
        vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 12, 16]
    }

    #[test]
    fn every_topology_validates_at_every_size() {
        for kind in TopologyKind::ALL {
            for n in all_sizes() {
                let s = kind.build(n);
                assert_eq!(s.workers, n);
                s.validate().unwrap_or_else(|e| {
                    panic!("{} n={n}: {e}", kind.name())
                });
            }
        }
    }

    #[test]
    fn ring_phase_count_is_2n_minus_2() {
        for n in [2usize, 5, 8] {
            assert_eq!(TopologyKind::Ring.build(n).phase_count(), 2 * (n - 1));
        }
        assert_eq!(TopologyKind::Ring.build(1).phase_count(), 0);
    }

    #[test]
    fn tree_phase_count_is_2_log2() {
        for (n, want) in [(2usize, 2usize), (4, 4), (5, 6), (8, 6), (9, 8)] {
            let got = TopologyKind::Tree.build(n).phase_count();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn tree_reduces_everything_to_rank0_then_broadcasts() {
        // every rank != 0 sends exactly one Reduce transfer; rank 0 none.
        for n in [3usize, 8, 13] {
            let s = TopologyKind::Tree.build(n);
            let mut reduce_sends = vec![0usize; n];
            let mut copy_recvs = vec![0usize; n];
            for ph in &s.phases {
                for t in &ph.transfers {
                    match t.op {
                        TransferOp::Reduce => reduce_sends[t.src] += 1,
                        TransferOp::Copy => copy_recvs[t.dst] += 1,
                    }
                }
            }
            assert_eq!(reduce_sends[0], 0, "n={n}");
            for w in 1..n {
                assert_eq!(reduce_sends[w], 1, "n={n} w={w}");
                assert_eq!(copy_recvs[w], 1, "n={n} w={w}");
            }
        }
    }

    #[test]
    fn hierarchical_auto_group_is_near_sqrt() {
        let h = HierarchicalRing { group: 0 };
        assert_eq!(h.group_for(16), 4);
        assert_eq!(h.group_for(9), 3);
        assert_eq!(h.group_for(10), 4);
        assert_eq!(h.group_for(1), 1);
    }

    #[test]
    fn torus_auto_rows_divides_n() {
        let t = Torus2d { rows: 0 };
        assert_eq!(t.rows_for(16), 4);
        assert_eq!(t.rows_for(12), 3);
        assert_eq!(t.rows_for(7), 1); // prime -> single ring
        let forced = Torus2d { rows: 5 };
        assert_eq!(forced.rows_for(10), 5);
        assert_eq!(forced.rows_for(12), 3); // 5 doesn't divide 12 -> auto
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        assert_eq!(TopologyKind::parse("ring").unwrap(), TopologyKind::Ring);
        assert_eq!(TopologyKind::parse("tree").unwrap(), TopologyKind::Tree);
        assert_eq!(
            TopologyKind::parse("hierarchical:4").unwrap(),
            TopologyKind::Hierarchical { group: 4 }
        );
        assert_eq!(
            TopologyKind::parse("torus:8").unwrap(),
            TopologyKind::Torus { rows: 8 }
        );
        assert!(TopologyKind::parse("mesh").is_err());
        assert!(TopologyKind::parse("torus:x").is_err());
    }

    #[test]
    fn uniform_cost_ring_matches_bandwidth_optimal_closed_form() {
        let (lat, bw, bytes) = (1e-4, 1e9, 4e6);
        for n in [2usize, 4, 8, 16] {
            let s = TopologyKind::Ring.build(n);
            let got = s.uniform_cost(lat, bw, bytes);
            let want =
                (2 * (n - 1)) as f64 * (lat + bytes / n as f64 / bw);
            assert!(
                (got - want).abs() < 1e-9,
                "n={n}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn tree_beats_ring_on_latency_bound_payloads() {
        // tiny payload, high latency: 2 log N phases < 2(N-1) phases.
        let (lat, bw, bytes) = (1e-3, 1e9, 1e3);
        let n = 32;
        let ring = TopologyKind::Ring.build(n).uniform_cost(lat, bw, bytes);
        let tree = TopologyKind::Tree.build(n).uniform_cost(lat, bw, bytes);
        assert!(tree < ring, "tree {tree} vs ring {ring}");
    }

    #[test]
    fn ring_beats_tree_on_bandwidth_bound_payloads() {
        let (lat, bw, bytes) = (1e-6, 1e9, 1e8);
        let n = 16;
        let ring = TopologyKind::Ring.build(n).uniform_cost(lat, bw, bytes);
        let tree = TopologyKind::Tree.build(n).uniform_cost(lat, bw, bytes);
        assert!(ring < tree, "ring {ring} vs tree {tree}");
    }
}
