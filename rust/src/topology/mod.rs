//! Topology-aware collective engine: pluggable all-reduce schedules.
//!
//! The paper folds communication into one constant `T^c`, but at scale
//! the collective is the *other* tail-latency amplifier: in a ring, one
//! late neighbour stalls all 2(N-1) phases. This subsystem makes the
//! collective's shape a first-class, swappable object:
//!
//! * [`schedule`] — the [`Schedule`]/[`Phase`]/[`Transfer`] data model
//!   both consumers interpret;
//! * [`kinds`] — the [`Topology`] trait and the four built-ins
//!   ([`Ring`], [`BinaryTree`], [`HierarchicalRing`], [`Torus2d`]),
//!   selected by [`TopologyKind`];
//!
//! consumed by **both** sides of the codebase:
//!
//! * virtual time — [`crate::sim::comm::schedule_completion`] runs a
//!   schedule through the event queue honoring per-worker arrival
//!   times (the `--topology` flag of `simulate`/`scale`);
//! * real threads — [`crate::collective::engine::schedule_all_reduce`]
//!   executes the same schedule over the mpsc mesh with a
//!   bitwise-deterministic reduction order.
//!
//! On top sits **DropComm** (bounded-wait all-reduce,
//! [`crate::sim::comm::CommModel::bounded_wait_completion`]): workers
//! that miss the membership deadline are excluded from the reduction
//! and the sum is reweighted — the communication-side analogue of
//! DropCompute's Algorithm 1 (cf. OptiReduce, arXiv:2310.06993; and the
//! few-lost-contributions tolerance of arXiv:1702.05800).

pub mod kinds;
pub mod schedule;

pub use kinds::{
    BinaryTree, HierarchicalRing, Ring, Topology, TopologyKind, Torus2d,
};
pub use schedule::{chunk_bounds, Chunk, Phase, Schedule, Transfer, TransferOp};
