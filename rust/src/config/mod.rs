//! Typed configuration: the launcher's single source of truth.
//!
//! A run is described by a TOML file (see `configs/`) plus CLI
//! `--set path=value` overrides, parsed into the structs here. Every
//! field has a validated default so `Config::default()` is runnable.

pub mod toml;

pub use self::toml::{Document, Value};

use crate::util::{Error, Result};

/// Which latency-noise family perturbs each micro-batch (App. B.1, C.3).
#[derive(Debug, Clone, PartialEq)]
pub enum NoiseKind {
    /// No additive noise (homogeneous cluster).
    None,
    /// The paper's simulated-delay environment:
    /// `eps = min(Z/alpha, beta)`, `Z ~ LogNormal(mu, sigma)`,
    /// `t += mu_compute * eps`.
    PaperLogNormal { mu: f64, sigma: f64, alpha: f64, beta: f64 },
    /// Families of the Fig 13 ablation, parameterized by target moments.
    LogNormal { mean: f64, var: f64 },
    Normal { mean: f64, var: f64 },
    Bernoulli { p: f64, value: f64 },
    Exponential { mean: f64 },
    Gamma { mean: f64, var: f64 },
    /// Correlated straggler bursts: one seeded burst process shared by
    /// workers `0..subset`. Each `period`-step window bursts with prob
    /// `p`, adding `delay` seconds to every subset worker's step start —
    /// the whole subset straggles *together* (rack/switch contention).
    /// Step-indexed: consumes no per-worker draws.
    SharedBurst { p: f64, period: u64, delay: f64, subset: usize, seed: u64 },
    /// Time-varying per-worker mean: each worker's step-start offset
    /// random-walks with increment `U(-sigma, sigma)` per step, clamped
    /// at 0 (thermal drift / slow degradation). Step-indexed: consumes
    /// no per-worker draws.
    Drift { sigma: f64, seed: u64 },
}

/// Straggler injection scenarios (Fig 12).
#[derive(Debug, Clone, PartialEq)]
pub enum StragglerKind {
    None,
    /// Each worker independently straggles with prob `p` per step/local
    /// step, adding `delay` seconds ("uniform stragglers").
    Uniform { p: f64, delay: f64 },
    /// Only workers in one server (ids < `server_size`) can straggle
    /// ("single server stragglers").
    SingleServer { p: f64, delay: f64, server_size: usize },
    /// Compute stall: worker `worker`'s compute pipeline hangs from step
    /// `from_step` on (bad disk / preprocessing deadlock — effectively
    /// infinite compute time), while its control thread stays alive.
    /// Baseline synchronous training stalls with it; under DropCompute
    /// the wall-clock timeout fires at `tau` and the worker joins the
    /// AllReduce empty, so training degrades gracefully to the survivors
    /// (§2's robustness comparison with redundancy methods — note the
    /// paper's limitation that *network* faults during the AllReduce
    /// itself remain out of scope).
    Fatal { worker: usize, from_step: usize },
}

/// Compute-cluster shape and timing model.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of data-parallel workers `N`.
    pub workers: usize,
    /// Gradient accumulations per step `M` (micro-batches).
    pub accumulations: usize,
    /// Mean compute time of one micro-batch, seconds (`mu` in Eq. 5).
    pub microbatch_mean: f64,
    /// Std of one micro-batch's intrinsic compute time (hardware jitter).
    pub microbatch_std: f64,
    /// Serial per-iteration latency `T^c` (AllReduce + fixed overhead).
    pub comm_latency: f64,
    /// Additive noise model.
    pub noise: NoiseKind,
    /// Straggler scenario.
    pub stragglers: StragglerKind,
    /// OS threads for real execution.
    pub threads: usize,
    /// Collective topology for the event-driven comm model
    /// (`None` = the paper's fixed `T^c` via `comm_latency`).
    pub topology: Option<crate::topology::TopologyKind>,
    /// Per-hop link latency, seconds (topology model only).
    pub link_latency: f64,
    /// Link bandwidth, bytes/second (topology model only).
    pub link_bandwidth: f64,
    /// Gradient bytes reduced per step (topology model only).
    pub grad_bytes: f64,
    /// DropComm bounded-wait deadline, seconds after the first arrival
    /// (0 = wait for everyone; the synchronous baseline).
    pub comm_drop_deadline: f64,
    /// Restore the legacy *single-restart* per-phase semantics: a
    /// restarted survivor collective is timed unchecked. The default
    /// (false) re-checks restarts against the remaining phase budgets
    /// recursively — see [`crate::sim::ClusterSim::with_single_restart`].
    pub single_restart: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            workers: 16,
            accumulations: 12,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.5,
            noise: NoiseKind::None,
            stragglers: StragglerKind::None,
            threads: 0, // 0 = auto
            topology: None,
            link_latency: 25e-6,
            link_bandwidth: 12.5e9,
            // `large` model: 33.7M f32 params
            grad_bytes: 4.0 * 33.7e6,
            comm_drop_deadline: 0.0,
            single_restart: false,
        }
    }
}

/// How dropped samples are compensated (§4.5, Table 1b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compensation {
    None,
    /// Train `R * I_base` extra steps, `R = M/M~ - 1`.
    ExtraSteps,
    /// Increase the per-step batch by `R` so the average batch matches.
    IncreasedBatch,
    /// Re-queue dropped micro-batches before the next epoch.
    Resample,
}

/// Threshold policy for Algorithm 1.
#[derive(Debug, Clone, PartialEq)]
pub enum ThresholdPolicy {
    /// DropCompute disabled (vanilla synchronous training).
    Off,
    /// Fixed compute threshold in seconds.
    Fixed(f64),
    /// Algorithm 2: measure `calibration_iters` iterations, synchronize
    /// the empirical latency distribution, pick `tau* = argmax S_eff`.
    Auto,
    /// Pick tau to hit a target drop rate (used by the post-analysis
    /// benches that sweep drop rate like Fig 4).
    TargetDropRate(f64),
}

/// DropCompute method configuration (§3.2, §4.4, §4.5).
#[derive(Debug, Clone)]
pub struct DropComputeConfig {
    pub policy: ThresholdPolicy,
    /// Iterations measured before choosing tau (Algorithm 2's `I`).
    pub calibration_iters: usize,
    /// Candidate-threshold grid resolution for the argmax search.
    pub search_points: usize,
    pub compensation: Compensation,
}

impl Default for DropComputeConfig {
    fn default() -> Self {
        Self {
            policy: ThresholdPolicy::Off,
            calibration_iters: 20,
            search_points: 256,
            compensation: Compensation::None,
        }
    }
}

/// Optimizer selection (rust-side update rules in `train::optimizer`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Momentum,
    Adam,
    AdamW,
    Lamb,
    Lars,
    Lans,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sgd" => Self::Sgd,
            "momentum" => Self::Momentum,
            "adam" => Self::Adam,
            "adamw" => Self::AdamW,
            "lamb" => Self::Lamb,
            "lars" => Self::Lars,
            "lans" => Self::Lans,
            other => {
                return Err(Error::Config(format!("unknown optimizer `{other}`")))
            }
        })
    }
}

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Constant,
    /// Linear warmup over `warmup` fraction then linear decay to 0
    /// (the BERT/LAMB regime of You et al. 2019).
    WarmupLinear { warmup_ratio: f64 },
    WarmupCosine { warmup_ratio: f64 },
    /// Polynomial decay with warmup (power 1 == linear).
    WarmupPoly { warmup_ratio: f64, power: f64 },
}

/// Training-loop configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact size name (`test`/`tiny`/`small`/`base`/`large`/`xl`).
    pub model_size: String,
    /// Total optimizer steps `I_base`.
    pub steps: usize,
    pub optimizer: OptimizerKind,
    pub lr: f64,
    pub schedule: LrSchedule,
    pub weight_decay: f64,
    pub seed: u64,
    /// Local-SGD synchronization period H (1 = fully synchronous).
    pub local_sgd_period: usize,
    pub log_every: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// Gradient clipping by global norm (0 = off).
    pub grad_clip: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model_size: "tiny".to_string(),
            steps: 100,
            optimizer: OptimizerKind::Adam,
            lr: 1e-3,
            schedule: LrSchedule::WarmupLinear { warmup_ratio: 0.1 },
            weight_decay: 0.01,
            seed: 0,
            local_sgd_period: 1,
            log_every: 10,
            eval_every: 0,
            eval_batches: 4,
            grad_clip: 1.0,
        }
    }
}

/// Synthetic-corpus configuration (`data::corpus`).
#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Zipf exponent of the unigram backbone.
    pub zipf_s: f64,
    /// Markov-blend coefficient (0 = iid unigrams, 1 = deterministic).
    pub markov_weight: f64,
    /// Log-normal document length parameters (motivates compute variance).
    pub doclen_mu: f64,
    pub doclen_sigma: f64,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            zipf_s: 1.1,
            markov_weight: 0.7,
            doclen_mu: 4.0,
            doclen_sigma: 1.0,
            seed: 1234,
        }
    }
}

/// Trace record/replay/fit configuration (`[trace]` section), consumed
/// by the `trace` CLI subcommands (see
/// [`crate::sim::TraceRecord`] and [`crate::analysis::budget_fit`]).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Default trace file path for `trace record` / `replay` / `fit`.
    pub path: String,
    /// Steps recorded by `trace record`.
    pub iters: usize,
    /// Compute-threshold grid resolution of `trace fit`.
    pub fit_grid: usize,
    /// Cap on the deadline candidates `trace fit` evaluates (the
    /// observed arrival offsets are subsampled down to this many).
    pub fit_deadlines: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            path: "artifacts/trace.json".to_string(),
            iters: 50,
            fit_grid: 8,
            fit_deadlines: 16,
        }
    }
}

/// Parallel scenario-grid configuration (`[sweep]` section), consumed
/// by the `sweep`/`scale` subcommands via [`crate::sweep::SweepSpec`].
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Worker threads for the grid runner (0 = all cores, 1 = serial).
    pub jobs: usize,
    /// Measured iterations per grid point.
    pub iters: usize,
    /// Cluster sizes `N` to sweep.
    pub workers: Vec<usize>,
    /// DropCompute thresholds (0.0 = off).
    pub thresholds: Vec<f64>,
    /// DropComm bounded-wait deadlines (0.0 = wait for everyone).
    pub deadlines: Vec<f64>,
    /// Policy axis (`[policy] sweep = ["none", "tau=9", ...]`): when
    /// non-empty it subsumes `thresholds`/`deadlines` — the grid runs
    /// `workers × policies × seeds` over parsed
    /// [`crate::policy::DropPolicy`] specs.
    pub policies: Vec<crate::policy::DropPolicy>,
    /// Fault-plan axis (`[scenario] sweep = ["none", "fail@100:w3", ...]`):
    /// when non-empty each grid point also runs under every parsed
    /// [`crate::sim::FaultPlan`] (the churn ablation).
    pub scenarios: Vec<crate::sim::FaultPlan>,
    /// Seed axis (same seed across arms = paired comparisons).
    pub seeds: Vec<u64>,
    /// Seed-axis lockstep batch width for the SoA multi-replica
    /// stepper ([`crate::sim::ReplicaBatch`]); 0/1 = scalar per-point
    /// stepping. Results are bitwise independent of the width.
    pub batch: usize,
    /// Progress/ETA reporting to stderr.
    pub progress: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            jobs: 0,
            iters: 50,
            workers: vec![16],
            thresholds: vec![0.0],
            deadlines: vec![0.0],
            policies: Vec::new(),
            scenarios: Vec::new(),
            seeds: vec![0],
            batch: 1,
            progress: true,
        }
    }
}

/// `[obs]` — the opt-in observability layer ([`crate::obs`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsConfig {
    /// Attach an [`crate::obs::ObsRecorder`] to sim/sweep/replay runs
    /// even without an output path (summary table to stdout).
    pub enabled: bool,
    /// Export base path: writes `<out>.prom` (Prometheus text) and
    /// `<out>.json` (snapshot). Empty = no files. Implies `enabled`.
    pub out: String,
}

impl ObsConfig {
    /// Whether any recording is requested.
    pub fn active(&self) -> bool {
        self.enabled || !self.out.is_empty()
    }
}

/// `[transport]` — the real-socket loopback harness
/// ([`crate::transport`], `transport run|bench`). Cluster shape,
/// topology, policy, and fault plan come from the usual sections; this
/// one holds only the socket/timing knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportConfig {
    /// Socket family carrying the collective (`uds` or `tcp`).
    pub kind: crate::transport::TransportKind,
    /// Steps a `transport run` executes.
    pub iters: usize,
    /// Failure-detection receive deadline, seconds (per blocking recv,
    /// not per step — generous by default so only real peer death or a
    /// policy deadline causes drops).
    pub recv_deadline: f64,
    /// Bounded connect/send retry attempts.
    pub connect_attempts: usize,
    /// Exponential backoff base between retries, seconds.
    pub backoff_base: f64,
    /// Backoff ceiling, seconds.
    pub backoff_max: f64,
    /// Backoff jitter fraction in `[0, 1)`.
    pub jitter: f64,
    /// Nominal per-micro-batch compute sleep, milliseconds.
    pub compute_ms: f64,
    /// Uniform per-micro-batch jitter amplitude, milliseconds (the
    /// compute-variance knob: larger skew = more stragglers).
    pub skew_ms: f64,
    /// Conformance gate's minimum discriminable gap, seconds: ordering
    /// pairs closer than this are ties and not scored.
    pub min_gap: f64,
    /// Elements in the gradient buffer each worker reduces.
    pub grad_len: usize,
    /// Socket directory for UDS endpoints (empty = fresh temp dir).
    pub dir: String,
    /// Where `transport run` writes the recorded trace.
    pub trace_out: String,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            kind: crate::transport::TransportKind::Uds,
            iters: 8,
            recv_deadline: 30.0,
            connect_attempts: 5,
            backoff_base: 0.005,
            backoff_max: 0.25,
            jitter: 0.2,
            compute_ms: 4.0,
            skew_ms: 15.0,
            min_gap: 0.04,
            grad_len: 256,
            dir: String::new(),
            trace_out: "artifacts/transport.trace.json".to_string(),
        }
    }
}

/// Top-level run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cluster: ClusterConfig,
    pub dropcompute: DropComputeConfig,
    pub train: TrainConfig,
    pub data: DataConfig,
    pub sweep: SweepConfig,
    pub trace: TraceConfig,
    pub obs: ObsConfig,
    pub transport: TransportConfig,
    /// Explicit run-level drop policy (`[policy] spec = "..."`). `None`
    /// falls back to the legacy `[comm] drop_deadline` surface — see
    /// [`Config::effective_policy`].
    pub policy: Option<crate::policy::DropPolicy>,
    /// Run-level fault plan (`[scenario] spec = "..."`); `None` (or the
    /// literal spec `"none"`) runs fault-free.
    pub scenario: Option<crate::sim::FaultPlan>,
    /// Artifact root directory.
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cluster: ClusterConfig::default(),
            dropcompute: DropComputeConfig::default(),
            train: TrainConfig::default(),
            data: DataConfig::default(),
            sweep: SweepConfig::default(),
            trace: TraceConfig::default(),
            obs: ObsConfig::default(),
            transport: TransportConfig::default(),
            policy: None,
            scenario: None,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl Config {
    /// Build from a parsed document (all keys optional).
    pub fn from_doc(doc: &Document) -> Result<Self> {
        let mut c = Config::default();
        c.artifacts_dir = doc.str_or("artifacts_dir", "artifacts");

        // [cluster]
        c.cluster.workers = doc.int_or("cluster.workers", 16).max(1) as usize;
        c.cluster.accumulations =
            doc.int_or("cluster.accumulations", 12).max(1) as usize;
        c.cluster.microbatch_mean =
            doc.float_or("cluster.microbatch_mean", 0.45);
        c.cluster.microbatch_std = doc.float_or("cluster.microbatch_std", 0.02);
        c.cluster.comm_latency = doc.float_or("cluster.comm_latency", 0.5);
        c.cluster.threads = doc.int_or("cluster.threads", 0).max(0) as usize;
        c.cluster.noise = parse_noise(doc)?;
        c.cluster.stragglers = parse_stragglers(doc)?;

        // [comm] — topology-aware collective model (sim/comm.rs)
        c.cluster.topology = match doc.str_or("comm.topology", "fixed").as_str() {
            "fixed" => None,
            spec => Some(crate::topology::TopologyKind::parse(spec)?),
        };
        c.cluster.link_latency =
            doc.float_or("comm.link_latency", c.cluster.link_latency);
        c.cluster.link_bandwidth =
            doc.float_or("comm.link_bandwidth", c.cluster.link_bandwidth);
        c.cluster.grad_bytes =
            doc.float_or("comm.grad_bytes", c.cluster.grad_bytes);
        c.cluster.comm_drop_deadline =
            doc.float_or("comm.drop_deadline", 0.0);

        // [dropcompute]
        c.dropcompute.policy = match doc.str_or("dropcompute.policy", "off").as_str() {
            "off" => ThresholdPolicy::Off,
            "auto" => ThresholdPolicy::Auto,
            "fixed" => {
                ThresholdPolicy::Fixed(doc.float_or("dropcompute.threshold", 1.0))
            }
            "drop_rate" => ThresholdPolicy::TargetDropRate(
                doc.float_or("dropcompute.drop_rate", 0.05),
            ),
            other => {
                return Err(Error::Config(format!(
                    "dropcompute.policy `{other}` not in off/auto/fixed/drop_rate"
                )))
            }
        };
        c.dropcompute.calibration_iters =
            doc.int_or("dropcompute.calibration_iters", 20).max(1) as usize;
        c.dropcompute.search_points =
            doc.int_or("dropcompute.search_points", 256).max(8) as usize;
        c.dropcompute.compensation =
            match doc.str_or("dropcompute.compensation", "none").as_str() {
                "none" => Compensation::None,
                "extra_steps" => Compensation::ExtraSteps,
                "increased_batch" => Compensation::IncreasedBatch,
                "resample" => Compensation::Resample,
                other => {
                    return Err(Error::Config(format!(
                        "unknown compensation `{other}`"
                    )))
                }
            };

        // [train]
        c.train.model_size = doc.str_or("train.model_size", "tiny");
        c.train.steps = doc.int_or("train.steps", 100).max(1) as usize;
        c.train.optimizer =
            OptimizerKind::parse(&doc.str_or("train.optimizer", "adam"))?;
        c.train.lr = doc.float_or("train.lr", 1e-3);
        c.train.weight_decay = doc.float_or("train.weight_decay", 0.01);
        c.train.seed = doc.int_or("train.seed", 0) as u64;
        c.train.local_sgd_period =
            doc.int_or("train.local_sgd_period", 1).max(1) as usize;
        c.train.log_every = doc.int_or("train.log_every", 10).max(1) as usize;
        c.train.eval_every = doc.int_or("train.eval_every", 0).max(0) as usize;
        c.train.eval_batches = doc.int_or("train.eval_batches", 4).max(1) as usize;
        c.train.grad_clip = doc.float_or("train.grad_clip", 1.0);
        let warmup = doc.float_or("train.warmup_ratio", 0.1);
        c.train.schedule = match doc.str_or("train.schedule", "warmup_linear").as_str()
        {
            "constant" => LrSchedule::Constant,
            "warmup_linear" => LrSchedule::WarmupLinear { warmup_ratio: warmup },
            "warmup_cosine" => LrSchedule::WarmupCosine { warmup_ratio: warmup },
            "warmup_poly" => LrSchedule::WarmupPoly {
                warmup_ratio: warmup,
                power: doc.float_or("train.poly_power", 1.0),
            },
            other => {
                return Err(Error::Config(format!("unknown schedule `{other}`")))
            }
        };

        // [sweep] — parallel scenario-grid runner (crate::sweep)
        let jobs = doc.int_or("sweep.jobs", 0);
        c.sweep.jobs = usize::try_from(jobs).map_err(|_| {
            Error::Config(format!("sweep.jobs must be >= 0, got {jobs}"))
        })?;
        let iters = doc.int_or("sweep.iters", 50);
        if iters < 1 {
            return Err(Error::Config(format!(
                "sweep.iters must be >= 1, got {iters}"
            )));
        }
        c.sweep.iters = iters as usize;
        let batch = doc.int_or("sweep.batch", 1);
        if batch < 1 {
            return Err(Error::Config(format!(
                "sweep.batch must be >= 1, got {batch}"
            )));
        }
        c.sweep.batch = batch as usize;
        c.sweep.progress = doc.bool_or("sweep.progress", true);
        c.sweep.workers = int_list(doc, "sweep.workers", &c.sweep.workers)?
            .into_iter()
            .map(|n: usize| n.max(1))
            .collect();
        c.sweep.thresholds =
            float_list(doc, "sweep.thresholds", &c.sweep.thresholds)?;
        c.sweep.deadlines =
            float_list(doc, "sweep.deadlines", &c.sweep.deadlines)?;
        c.sweep.seeds = int_list(doc, "sweep.seeds", &c.sweep.seeds)?;

        // [policy] — the unified drop-decision surface
        // (crate::policy::DropPolicy). `spec` drives single runs;
        // `sweep` is the grid's policy axis. The legacy [comm]
        // drop_deadline keeps working: Config::effective_policy folds
        // it in when no explicit spec is given.
        c.policy = match doc.get("policy.spec") {
            None => None,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    Error::Config("policy.spec: expected string".into())
                })?;
                Some(crate::policy::DropPolicy::parse(s)?)
            }
        };
        if let Some(specs) = str_list(doc, "policy.sweep")? {
            c.sweep.policies = specs
                .iter()
                .map(|s| crate::policy::DropPolicy::parse(s))
                .collect::<Result<_>>()?;
        }
        c.cluster.single_restart = doc.bool_or("policy.single_restart", false);

        // [scenario] — the fault-injection lab (crate::sim::FaultPlan).
        // `spec` drives single runs; `sweep` is the grid's churn axis.
        c.scenario = match doc.get("scenario.spec") {
            None => None,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    Error::Config("scenario.spec: expected string".into())
                })?;
                let plan = crate::sim::FaultPlan::parse(s)?;
                if plan.is_empty() { None } else { Some(plan) }
            }
        };
        if let Some(specs) = str_list(doc, "scenario.sweep")? {
            c.sweep.scenarios = specs
                .iter()
                .map(|s| crate::sim::FaultPlan::parse(s))
                .collect::<Result<_>>()?;
        }

        // [trace] — trace record / replay / fit (crate::sim::TraceRecord,
        // crate::analysis::budget_fit)
        c.trace.path = doc.str_or("trace.path", &c.trace.path);
        let t_iters = doc.int_or("trace.iters", c.trace.iters as i64);
        if t_iters < 1 {
            return Err(Error::Config(format!(
                "trace.iters must be >= 1, got {t_iters}"
            )));
        }
        c.trace.iters = t_iters as usize;
        let t_grid = doc.int_or("trace.fit_grid", c.trace.fit_grid as i64);
        if t_grid < 2 {
            return Err(Error::Config(format!(
                "trace.fit_grid must be >= 2, got {t_grid}"
            )));
        }
        c.trace.fit_grid = t_grid as usize;
        let t_dl =
            doc.int_or("trace.fit_deadlines", c.trace.fit_deadlines as i64);
        if t_dl < 1 {
            return Err(Error::Config(format!(
                "trace.fit_deadlines must be >= 1, got {t_dl}"
            )));
        }
        c.trace.fit_deadlines = t_dl as usize;

        // [data]
        c.data.zipf_s = doc.float_or("data.zipf_s", 1.1);
        c.data.markov_weight = doc.float_or("data.markov_weight", 0.7);
        c.data.doclen_mu = doc.float_or("data.doclen_mu", 4.0);
        c.data.doclen_sigma = doc.float_or("data.doclen_sigma", 1.0);
        c.data.seed = doc.int_or("data.seed", 1234) as u64;

        // [obs] — opt-in observability layer (crate::obs)
        c.obs.enabled = doc.bool_or("obs.enabled", false);
        c.obs.out = doc.str_or("obs.out", "");

        // [transport] — real-socket loopback harness (crate::transport)
        c.transport.kind = crate::transport::TransportKind::parse(
            &doc.str_or("transport.kind", c.transport.kind.name()),
        )?;
        let tr_iters = doc.int_or("transport.iters", c.transport.iters as i64);
        if tr_iters < 1 {
            return Err(Error::Config(format!(
                "transport.iters must be >= 1, got {tr_iters}"
            )));
        }
        c.transport.iters = tr_iters as usize;
        let tr_attempts = doc
            .int_or("transport.connect_attempts", c.transport.connect_attempts as i64);
        if tr_attempts < 1 {
            return Err(Error::Config(format!(
                "transport.connect_attempts must be >= 1, got {tr_attempts}"
            )));
        }
        c.transport.connect_attempts = tr_attempts as usize;
        c.transport.recv_deadline =
            doc.float_or("transport.recv_deadline", c.transport.recv_deadline);
        c.transport.backoff_base =
            doc.float_or("transport.backoff_base", c.transport.backoff_base);
        c.transport.backoff_max =
            doc.float_or("transport.backoff_max", c.transport.backoff_max);
        c.transport.jitter = doc.float_or("transport.jitter", c.transport.jitter);
        c.transport.compute_ms =
            doc.float_or("transport.compute_ms", c.transport.compute_ms);
        c.transport.skew_ms =
            doc.float_or("transport.skew_ms", c.transport.skew_ms);
        c.transport.min_gap =
            doc.float_or("transport.min_gap", c.transport.min_gap);
        let tr_len = doc.int_or("transport.grad_len", c.transport.grad_len as i64);
        if tr_len < 1 {
            return Err(Error::Config(format!(
                "transport.grad_len must be >= 1, got {tr_len}"
            )));
        }
        c.transport.grad_len = tr_len as usize;
        c.transport.dir = doc.str_or("transport.dir", &c.transport.dir);
        c.transport.trace_out =
            doc.str_or("transport.trace_out", &c.transport.trace_out);

        c.validate()?;
        Ok(c)
    }

    /// The run-level drop policy: the explicit `[policy] spec` when
    /// given, else the legacy `[comm] drop_deadline` surfaced as a
    /// [`crate::policy::DropPolicy::CommDeadline`] (back-compat), else
    /// no drops.
    pub fn effective_policy(&self) -> crate::policy::DropPolicy {
        match &self.policy {
            Some(p) => p.clone(),
            None => crate::policy::DropPolicy::from_cluster(&self.cluster),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.cluster.microbatch_mean <= 0.0 {
            return Err(Error::Config("microbatch_mean must be > 0".into()));
        }
        if self.cluster.comm_latency < 0.0 {
            return Err(Error::Config("comm_latency must be >= 0".into()));
        }
        if self.cluster.link_bandwidth <= 0.0 {
            return Err(Error::Config("link_bandwidth must be > 0".into()));
        }
        if self.cluster.link_latency < 0.0 || self.cluster.grad_bytes < 0.0 {
            return Err(Error::Config(
                "link_latency and grad_bytes must be >= 0".into(),
            ));
        }
        if self.cluster.comm_drop_deadline < 0.0 {
            return Err(Error::Config("comm.drop_deadline must be >= 0".into()));
        }
        if let ThresholdPolicy::Fixed(t) = self.dropcompute.policy {
            if t <= 0.0 {
                return Err(Error::Config("fixed threshold must be > 0".into()));
            }
        }
        if let ThresholdPolicy::TargetDropRate(r) = self.dropcompute.policy {
            if !(0.0..1.0).contains(&r) {
                return Err(Error::Config("drop_rate must be in [0,1)".into()));
            }
        }
        if !(0.0..=1.0).contains(&self.data.markov_weight) {
            return Err(Error::Config("markov_weight must be in [0,1]".into()));
        }
        if self.sweep.workers.is_empty()
            || self.sweep.thresholds.is_empty()
            || self.sweep.deadlines.is_empty()
            || self.sweep.seeds.is_empty()
        {
            return Err(Error::Config("sweep axes must be non-empty".into()));
        }
        if self.sweep.thresholds.iter().any(|&t| t < 0.0)
            || self.sweep.deadlines.iter().any(|&d| d < 0.0)
        {
            return Err(Error::Config(
                "sweep.thresholds and sweep.deadlines must be >= 0".into(),
            ));
        }
        if let Some(plan) = &self.scenario {
            // sweep-axis plans are validated against each point's
            // worker count when the grid materializes
            plan.validate_for(self.cluster.workers)?;
        }
        let t = &self.transport;
        if !(t.recv_deadline > 0.0) || !t.recv_deadline.is_finite() {
            return Err(Error::Config(
                "transport.recv_deadline must be finite and > 0".into(),
            ));
        }
        if !t.backoff_base.is_finite()
            || !t.backoff_max.is_finite()
            || t.backoff_base < 0.0
            || t.backoff_max < t.backoff_base
        {
            return Err(Error::Config(
                "transport backoff must satisfy 0 <= base <= max".into(),
            ));
        }
        if !(0.0..1.0).contains(&t.jitter) {
            return Err(Error::Config(
                "transport.jitter must be in [0, 1)".into(),
            ));
        }
        if t.compute_ms < 0.0 || t.skew_ms < 0.0 || !(t.min_gap > 0.0) {
            return Err(Error::Config(
                "transport compute_ms/skew_ms must be >= 0 and min_gap > 0"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// `key = [a, b, c]` (or a bare scalar, treated as a one-element list)
/// as integers `>= 0`.
fn int_values(doc: &Document, key: &str) -> Result<Option<Vec<i64>>> {
    let Some(v) = doc.get(key) else { return Ok(None) };
    let items: Vec<&Value> = match v.as_array() {
        Some(arr) => arr.iter().collect(),
        None => vec![v],
    };
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        out.push(item.as_int().ok_or_else(|| {
            Error::Config(format!("{key}: expected integer list"))
        })?);
    }
    Ok(Some(out))
}

/// `key = [...]` as any non-negative integer type (`usize`, `u64`, ...).
fn int_list<T: TryFrom<i64> + Clone>(
    doc: &Document,
    key: &str,
    default: &[T],
) -> Result<Vec<T>> {
    match int_values(doc, key)? {
        None => Ok(default.to_vec()),
        Some(v) => v
            .into_iter()
            .map(|i| {
                T::try_from(i).map_err(|_| {
                    Error::Config(format!("{key}: negative entry {i}"))
                })
            })
            .collect(),
    }
}

/// `key = ["a", "b"]` (or a bare string, treated as a one-element
/// list) as strings; `None` when the key is absent.
fn str_list(doc: &Document, key: &str) -> Result<Option<Vec<String>>> {
    let Some(v) = doc.get(key) else { return Ok(None) };
    let items: Vec<&Value> = match v.as_array() {
        Some(arr) => arr.iter().collect(),
        None => vec![v],
    };
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        out.push(
            item.as_str()
                .ok_or_else(|| {
                    Error::Config(format!("{key}: expected string list"))
                })?
                .to_string(),
        );
    }
    Ok(Some(out))
}

fn float_list(doc: &Document, key: &str, default: &[f64]) -> Result<Vec<f64>> {
    let Some(v) = doc.get(key) else { return Ok(default.to_vec()) };
    let items: Vec<&Value> = match v.as_array() {
        Some(arr) => arr.iter().collect(),
        None => vec![v],
    };
    let mut out = Vec::with_capacity(items.len());
    for item in items {
        out.push(item.as_float().ok_or_else(|| {
            Error::Config(format!("{key}: expected float list"))
        })?);
    }
    Ok(out)
}

fn parse_noise(doc: &Document) -> Result<NoiseKind> {
    Ok(match doc.str_or("noise.kind", "none").as_str() {
        "none" => NoiseKind::None,
        "paper_lognormal" => NoiseKind::PaperLogNormal {
            mu: doc.float_or("noise.mu", 4.0),
            sigma: doc.float_or("noise.sigma", 1.0),
            alpha: doc.float_or("noise.alpha", 2.0 * (4.5f64).exp()),
            beta: doc.float_or("noise.beta", 5.5),
        },
        "lognormal" => NoiseKind::LogNormal {
            mean: doc.float_or("noise.mean", 0.225),
            var: doc.float_or("noise.var", 0.05),
        },
        "normal" => NoiseKind::Normal {
            mean: doc.float_or("noise.mean", 0.225),
            var: doc.float_or("noise.var", 0.05),
        },
        "bernoulli" => NoiseKind::Bernoulli {
            p: doc.float_or("noise.p", 0.5),
            value: doc.float_or("noise.value", 0.45),
        },
        "exponential" => NoiseKind::Exponential {
            mean: doc.float_or("noise.mean", 0.225),
        },
        "gamma" => NoiseKind::Gamma {
            mean: doc.float_or("noise.mean", 0.225),
            var: doc.float_or("noise.var", 0.05),
        },
        "shared_burst" => NoiseKind::SharedBurst {
            p: doc.float_or("noise.p", 0.1),
            period: doc.int_or("noise.period", 10).max(1) as u64,
            delay: doc.float_or("noise.delay", 1.0),
            subset: doc.int_or("noise.subset", 4).max(1) as usize,
            seed: doc.int_or("noise.seed", 0) as u64,
        },
        "drift" => NoiseKind::Drift {
            sigma: doc.float_or("noise.sigma", 0.01),
            seed: doc.int_or("noise.seed", 0) as u64,
        },
        other => return Err(Error::Config(format!("unknown noise kind `{other}`"))),
    })
}

fn parse_stragglers(doc: &Document) -> Result<StragglerKind> {
    Ok(match doc.str_or("stragglers.kind", "none").as_str() {
        "none" => StragglerKind::None,
        "uniform" => StragglerKind::Uniform {
            p: doc.float_or("stragglers.p", 0.04),
            delay: doc.float_or("stragglers.delay", 1.0),
        },
        "single_server" => StragglerKind::SingleServer {
            p: doc.float_or("stragglers.p", 0.04),
            delay: doc.float_or("stragglers.delay", 1.0),
            server_size: doc.int_or("stragglers.server_size", 8).max(1) as usize,
        },
        "fatal" => StragglerKind::Fatal {
            worker: doc.int_or("stragglers.worker", 0).max(0) as usize,
            from_step: doc.int_or("stragglers.from_step", 0).max(0) as usize,
        },
        other => {
            return Err(Error::Config(format!("unknown straggler kind `{other}`")))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn full_roundtrip() {
        let doc = Document::parse(
            r#"
            artifacts_dir = "artifacts"
            [cluster]
            workers = 64
            accumulations = 12
            comm_latency = 0.35
            [noise]
            kind = "paper_lognormal"
            [stragglers]
            kind = "single_server"
            server_size = 8
            [dropcompute]
            policy = "auto"
            compensation = "extra_steps"
            [train]
            model_size = "base"
            optimizer = "lamb"
            schedule = "warmup_poly"
            warmup_ratio = 0.2843
            steps = 7038
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.cluster.workers, 64);
        assert!(matches!(c.cluster.noise, NoiseKind::PaperLogNormal { .. }));
        assert!(matches!(
            c.cluster.stragglers,
            StragglerKind::SingleServer { server_size: 8, .. }
        ));
        assert_eq!(c.dropcompute.policy, ThresholdPolicy::Auto);
        assert_eq!(c.dropcompute.compensation, Compensation::ExtraSteps);
        assert_eq!(c.train.optimizer, OptimizerKind::Lamb);
        assert_eq!(c.train.steps, 7038);
        assert!(matches!(
            c.train.schedule,
            LrSchedule::WarmupPoly { .. }
        ));
    }

    #[test]
    fn comm_section_roundtrip() {
        let doc = Document::parse(
            r#"
            [comm]
            topology = "hierarchical:4"
            link_latency = 1e-4
            link_bandwidth = 1e9
            grad_bytes = 4e6
            drop_deadline = 1.5
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(
            c.cluster.topology,
            Some(crate::topology::TopologyKind::Hierarchical { group: 4 })
        );
        assert_eq!(c.cluster.link_latency, 1e-4);
        assert_eq!(c.cluster.link_bandwidth, 1e9);
        assert_eq!(c.cluster.grad_bytes, 4e6);
        assert_eq!(c.cluster.comm_drop_deadline, 1.5);
        // default stays the paper's fixed-T^c model with no comm drop
        let d = Config::default();
        assert_eq!(d.cluster.topology, None);
        assert_eq!(d.cluster.comm_drop_deadline, 0.0);
        // bad values rejected
        let bad = Document::parse("[comm]\ntopology = \"moebius\"").unwrap();
        assert!(Config::from_doc(&bad).is_err());
        let neg =
            Document::parse("[comm]\ndrop_deadline = -1.0").unwrap();
        assert!(Config::from_doc(&neg).is_err());
    }

    #[test]
    fn sweep_section_roundtrip() {
        let doc = Document::parse(
            r#"
            [sweep]
            jobs = 4
            iters = 25
            batch = 8
            workers = [8, 16, 32]
            thresholds = [0.0, 2.5, 9]
            deadlines = [0.0, 3.0]
            seeds = [1, 2, 3, 4]
            progress = false
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.sweep.jobs, 4);
        assert_eq!(c.sweep.iters, 25);
        assert_eq!(c.sweep.batch, 8);
        assert_eq!(c.sweep.workers, vec![8, 16, 32]);
        assert_eq!(c.sweep.thresholds, vec![0.0, 2.5, 9.0]);
        assert_eq!(c.sweep.deadlines, vec![0.0, 3.0]);
        assert_eq!(c.sweep.seeds, vec![1, 2, 3, 4]);
        assert!(!c.sweep.progress);
        // defaults: auto jobs, one point per axis, scalar stepping
        let d = Config::default();
        assert_eq!(d.sweep.jobs, 0);
        assert_eq!(d.sweep.workers, vec![16]);
        assert_eq!(d.sweep.batch, 1);
        // scalars act as one-element lists
        let doc1 = Document::parse("[sweep]\nworkers = 64").unwrap();
        assert_eq!(
            Config::from_doc(&doc1).unwrap().sweep.workers,
            vec![64]
        );
        // bad values rejected
        for bad in [
            "[sweep]\nworkers = [\"x\"]",
            "[sweep]\nworkers = [-2]",
            "[sweep]\nseeds = [-1]",
            "[sweep]\njobs = -4",
            "[sweep]\niters = -40",
            "[sweep]\nbatch = 0",
            "[sweep]\nbatch = -2",
            "[sweep]\nthresholds = [-1.0]",
            "[sweep]\nworkers = []",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(Config::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn policy_section_roundtrip_and_comm_back_compat() {
        use crate::policy::DropPolicy;
        let doc = Document::parse(
            r#"
            [policy]
            spec = "tau=9,between+deadline=3"
            sweep = ["none", "tau=9", "phase-deadline=1.5/0.5"]
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        let want = DropPolicy::parse("tau=9,between+deadline=3").unwrap();
        assert_eq!(c.policy, Some(want.clone()));
        assert_eq!(c.effective_policy(), want);
        assert_eq!(c.sweep.policies.len(), 3);
        assert_eq!(c.sweep.policies[2].spec(), "phase-deadline=1.5/0.5");

        // back-compat: the [comm] deadline alone surfaces as a policy
        let doc = Document::parse("[comm]\ndrop_deadline = 1.5").unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.policy, None);
        assert_eq!(
            c.effective_policy(),
            DropPolicy::CommDeadline { deadline: 1.5 }
        );
        // an explicit [policy] spec wins over the [comm] deadline
        let doc = Document::parse(
            "[comm]\ndrop_deadline = 1.5\n[policy]\nspec = \"deadline=3\"",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(
            c.effective_policy(),
            DropPolicy::CommDeadline { deadline: 3.0 }
        );
        // no policy anywhere: no drops
        assert!(Config::default().effective_policy().is_none());

        // bad specs rejected at the config boundary
        for bad in [
            "[policy]\nspec = \"wat=1\"",
            "[policy]\nspec = 3",
            "[policy]\nsweep = [\"tau=-1\"]",
            "[policy]\nsweep = [3]",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(Config::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn scenario_section_roundtrip() {
        let doc = Document::parse(
            r#"
            [cluster]
            workers = 8
            [scenario]
            spec = "fail@100:w3,rejoin+50;slow@20:w1,x2.5"
            sweep = ["none", "fail@10:w0", "drift@0:w2,+0.01"]
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        let plan = c.scenario.expect("spec installs a plan");
        assert_eq!(plan.spec(), "fail@100:w3,rejoin+50;slow@20:w1,x2.5");
        assert_eq!(c.sweep.scenarios.len(), 3);
        assert!(c.sweep.scenarios[0].is_empty());
        assert_eq!(c.sweep.scenarios[1].spec(), "fail@10:w0");

        // "none" and an absent section both mean fault-free
        let doc = Document::parse("[scenario]\nspec = \"none\"").unwrap();
        assert!(Config::from_doc(&doc).unwrap().scenario.is_none());
        assert!(Config::default().scenario.is_none());

        // a plan naming a worker outside the cluster is a config error
        let doc = Document::parse(
            "[cluster]\nworkers = 4\n[scenario]\nspec = \"fail@10:w7\"",
        )
        .unwrap();
        assert!(Config::from_doc(&doc).is_err());

        // bad specs rejected at the config boundary
        for bad in [
            "[scenario]\nspec = \"explode@3\"",
            "[scenario]\nspec = 3",
            "[scenario]\nsweep = [\"fail@:w1\"]",
            "[scenario]\nsweep = [3]",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(Config::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn churn_noise_kinds_roundtrip() {
        let doc = Document::parse(
            r#"
            [noise]
            kind = "shared_burst"
            p = 0.25
            period = 5
            delay = 2.0
            subset = 3
            seed = 7
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(
            c.cluster.noise,
            NoiseKind::SharedBurst {
                p: 0.25,
                period: 5,
                delay: 2.0,
                subset: 3,
                seed: 7
            }
        );
        let doc = Document::parse(
            "[noise]\nkind = \"drift\"\nsigma = 0.02\nseed = 9",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.cluster.noise, NoiseKind::Drift { sigma: 0.02, seed: 9 });
    }

    #[test]
    fn trace_section_and_single_restart_roundtrip() {
        let doc = Document::parse(
            r#"
            [policy]
            single_restart = true
            [trace]
            path = "runs/golden.trace.json"
            iters = 12
            fit_grid = 24
            fit_deadlines = 8
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert!(c.cluster.single_restart);
        assert_eq!(c.trace.path, "runs/golden.trace.json");
        assert_eq!(c.trace.iters, 12);
        assert_eq!(c.trace.fit_grid, 24);
        assert_eq!(c.trace.fit_deadlines, 8);
        // defaults: recursive restarts, artifacts trace path
        let d = Config::default();
        assert!(!d.cluster.single_restart);
        assert_eq!(d.trace.path, "artifacts/trace.json");
        assert_eq!(d.trace.iters, 50);
        // bad values rejected
        for bad in [
            "[trace]\niters = 0",
            "[trace]\nfit_grid = 1",
            "[trace]\nfit_deadlines = 0",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(Config::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn transport_section_roundtrip() {
        let doc = Document::parse(
            r#"
            [transport]
            kind = "tcp"
            iters = 6
            recv_deadline = 5.0
            connect_attempts = 3
            backoff_base = 0.001
            backoff_max = 0.1
            jitter = 0.5
            compute_ms = 2.0
            skew_ms = 8.0
            min_gap = 0.02
            grad_len = 64
            dir = "/tmp/dc-sockets"
            trace_out = "runs/real.trace.json"
            "#,
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.transport.kind, crate::transport::TransportKind::Tcp);
        assert_eq!(c.transport.iters, 6);
        assert_eq!(c.transport.recv_deadline, 5.0);
        assert_eq!(c.transport.connect_attempts, 3);
        assert_eq!(c.transport.backoff_base, 0.001);
        assert_eq!(c.transport.backoff_max, 0.1);
        assert_eq!(c.transport.jitter, 0.5);
        assert_eq!(c.transport.grad_len, 64);
        assert_eq!(c.transport.dir, "/tmp/dc-sockets");
        assert_eq!(c.transport.trace_out, "runs/real.trace.json");
        // defaults: UDS, generous deadline, fresh temp socket dir
        let d = Config::default();
        assert_eq!(d.transport, TransportConfig::default());
        assert_eq!(d.transport.kind, crate::transport::TransportKind::Uds);
        assert!(d.transport.dir.is_empty());
        // bad values rejected at the config boundary
        for bad in [
            "[transport]\nkind = \"pigeon\"",
            "[transport]\niters = 0",
            "[transport]\nconnect_attempts = 0",
            "[transport]\nrecv_deadline = 0.0",
            "[transport]\nbackoff_base = 0.5\nbackoff_max = 0.1",
            "[transport]\njitter = 1.0",
            "[transport]\nmin_gap = 0.0",
            "[transport]\ngrad_len = 0",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(Config::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn bad_enum_values_error() {
        for text in [
            "[dropcompute]\npolicy = \"nope\"",
            "[noise]\nkind = \"nope\"",
            "[train]\noptimizer = \"nope\"",
            "[stragglers]\nkind = \"nope\"",
        ] {
            let doc = Document::parse(text).unwrap();
            assert!(Config::from_doc(&doc).is_err(), "{text}");
        }
    }

    #[test]
    fn invalid_ranges_rejected() {
        let doc =
            Document::parse("[dropcompute]\npolicy = \"drop_rate\"\ndrop_rate = 1.5")
                .unwrap();
        assert!(Config::from_doc(&doc).is_err());
    }

    #[test]
    fn optimizer_parse_all() {
        for s in ["sgd", "momentum", "adam", "adamw", "lamb", "lars", "lans"] {
            OptimizerKind::parse(s).unwrap();
        }
        assert!(OptimizerKind::parse("adagrad").is_err());
    }
}
