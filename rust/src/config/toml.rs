//! A TOML-subset parser (no serde/toml crates in the sandbox registry).
//!
//! Supports the subset the launcher configs use: `[section]` and
//! `[section.sub]` tables, `key = value` with string / integer / float /
//! boolean / homogeneous-array values, `#` comments, and quoted strings
//! with `\"`/`\\`/`\n`/`\t` escapes. Line-oriented; good error messages
//! with line numbers.

use std::collections::BTreeMap;

use crate::util::{Error, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`3` == `3.0`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path -> value (`section.key`).
#[derive(Debug, Clone, Default)]
pub struct Document {
    values: BTreeMap<String, Value>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(err(lineno, "unterminated table header"));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(err(lineno, "empty table name"));
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.values.insert(path.clone(), value).is_some() {
                return Err(err(lineno, &format!("duplicate key `{path}`")));
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.values.get(path)
    }

    /// Set/override a value (CLI `--set section.key=value` overrides).
    pub fn set(&mut self, path: &str, value: Value) {
        self.values.insert(path.to_string(), value);
    }

    /// Parse-and-set from a raw `path=value` string.
    pub fn set_raw(&mut self, assignment: &str) -> Result<()> {
        let eq = assignment.find('=').ok_or_else(|| {
            Error::Config(format!("override `{assignment}` is not key=value"))
        })?;
        let value = parse_value(assignment[eq + 1..].trim(), 0)?;
        self.set(assignment[..eq].trim(), value);
        Ok(())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    // Typed accessors with defaults — the shape every config struct uses.

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(Value::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| default.to_string())
    }

    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn require_str(&self, path: &str) -> Result<String> {
        self.get(path)
            .and_then(Value::as_str)
            .map(str::to_string)
            .ok_or_else(|| Error::Config(format!("missing string key `{path}`")))
    }
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut prev_escape = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !prev_escape => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        prev_escape = c == '\\' && !prev_escape;
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(stripped) = s.strip_prefix('"') {
        return parse_string(stripped, lineno).map(Value::Str);
    }
    if s.starts_with('[') {
        return parse_array(s, lineno);
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, &format!("cannot parse value `{s}`")))
}

fn parse_string(rest: &str, lineno: usize) -> Result<String> {
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Ok(out),
            '\\' => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => {
                    return Err(err(
                        lineno,
                        &format!("bad escape `\\{}`", other.unwrap_or(' ')),
                    ))
                }
            },
            c => out.push(c),
        }
    }
    Err(err(lineno, "unterminated string"))
}

fn parse_array(s: &str, lineno: usize) -> Result<Value> {
    let inner = s
        .strip_prefix('[')
        .and_then(|t| t.strip_suffix(']'))
        .ok_or_else(|| err(lineno, "unterminated array"))?;
    let mut items = Vec::new();
    // split on commas outside strings/brackets (no nested arrays needed)
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                let piece = inner[start..i].trim();
                if !piece.is_empty() {
                    items.push(parse_value(piece, lineno)?);
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    let piece = inner[start..].trim();
    if !piece.is_empty() {
        items.push(parse_value(piece, lineno)?);
    }
    Ok(Value::Array(items))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(
            r#"
            top = 1
            [model]
            size = "base"      # comment
            lr = 1.5e-3
            layers = 6
            tied = true
            dims = [1, 2, 3]
            [noise.lognormal]
            mu = -1.84
            "#,
        )
        .unwrap();
        assert_eq!(doc.int_or("top", 0), 1);
        assert_eq!(doc.str_or("model.size", ""), "base");
        assert!((doc.float_or("model.lr", 0.0) - 1.5e-3).abs() < 1e-12);
        assert_eq!(doc.int_or("model.layers", 0), 6);
        assert!(doc.bool_or("model.tied", false));
        assert_eq!(doc.float_or("noise.lognormal.mu", 0.0), -1.84);
        let arr = doc.get("model.dims").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
    }

    #[test]
    fn int_literal_as_float() {
        let doc = Document::parse("x = 3").unwrap();
        assert_eq!(doc.float_or("x", 0.0), 3.0);
    }

    #[test]
    fn string_escapes_and_hash_inside() {
        let doc = Document::parse(r#"s = "a#b\n\"q\"""#).unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b\n\"q\"");
    }

    #[test]
    fn underscored_numbers() {
        let doc = Document::parse("n = 1_000_000").unwrap();
        assert_eq!(doc.int_or("n", 0), 1_000_000);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(Document::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_lines_have_numbers() {
        let e = Document::parse("ok = 1\nnonsense").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
    }

    #[test]
    fn overrides() {
        let mut doc = Document::parse("[a]\nb = 1").unwrap();
        doc.set_raw("a.b=2").unwrap();
        doc.set_raw("c.d=\"x\"").unwrap();
        assert_eq!(doc.int_or("a.b", 0), 2);
        assert_eq!(doc.str_or("c.d", ""), "x");
        assert!(doc.set_raw("nope").is_err());
    }

    #[test]
    fn empty_and_missing() {
        let doc = Document::parse("").unwrap();
        assert_eq!(doc.int_or("missing", 7), 7);
        assert!(doc.require_str("missing").is_err());
    }
}
