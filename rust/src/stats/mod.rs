//! Descriptive statistics: streaming moments, quantiles, histograms, ECDF.
//!
//! Used everywhere: Algorithm 2 synchronizes *empirical latency
//! distributions* between workers; the figures report iteration-time
//! histograms; the analytical model consumes means/variances.

pub mod normal;

/// Streaming mean/variance (Welford's algorithm) — numerically stable.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample (Bessel-corrected) variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge two accumulators (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Quantile of a sample by linear interpolation (type-7, numpy default).
/// Sorts a copy; use [`quantiles_sorted`] on pre-sorted data in hot loops.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Type-7 quantile of pre-sorted data.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let h = q * (sorted.len() - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    sorted[lo] + (h - lo as f64) * (sorted[hi] - sorted[lo])
}

/// Fixed-width histogram over [lo, hi] with out-of-range clamping.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1);
        self.counts[idx as usize] += 1;
        self.total += 1;
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Normalized density per bin (integrates to ~1).
    pub fn density(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.counts
            .iter()
            .map(|&c| c as f64 / (self.total.max(1) as f64 * w))
            .collect()
    }

    /// Render as a unicode sparkline for terminal reports.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = *self.counts.iter().max().unwrap_or(&1) as f64;
        self.counts
            .iter()
            .map(|&c| {
                let t = if max > 0.0 { c as f64 / max } else { 0.0 };
                BARS[((t * 7.0).round() as usize).min(7)]
            })
            .collect()
    }
}

/// Empirical CDF over an owned, sorted sample.
#[derive(Debug, Clone)]
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    pub fn new(mut xs: Vec<f64>) -> Self {
        assert!(!xs.is_empty(), "ECDF of empty sample");
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { sorted: xs }
    }

    /// P(X <= x).
    pub fn cdf(&self, x: f64) -> f64 {
        let idx = self.sorted.partition_point(|&v| v <= x);
        idx as f64 / self.sorted.len() as f64
    }

    /// Inverse CDF (quantile).
    pub fn inv(&self, q: f64) -> f64 {
        quantile_sorted(&self.sorted, q)
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn values(&self) -> &[f64] {
        &self.sorted
    }

    pub fn mean(&self) -> f64 {
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }

    pub fn variance(&self) -> f64 {
        let m = self.mean();
        self.sorted.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.sorted.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.count(), 1000);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..500).map(|i| (i as f64 * 0.7).cos()).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let (mut a, mut b) = (Welford::new(), Welford::new());
        for &x in &xs[..200] {
            a.push(x);
        }
        for &x in &xs[200..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
    }

    #[test]
    fn quantile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn histogram_counts_and_density() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.push(i as f64 / 10.0); // uniform over [0,10)
        }
        assert_eq!(h.total, 100);
        for &c in &h.counts {
            assert_eq!(c, 10);
        }
        let d = h.density();
        for &x in &d {
            assert!((x - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(-5.0);
        h.push(99.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn ecdf_monotone_and_correct() {
        let e = Ecdf::new(vec![3.0, 1.0, 2.0, 4.0]);
        assert_eq!(e.cdf(0.5), 0.0);
        assert_eq!(e.cdf(2.5), 0.5);
        assert_eq!(e.cdf(10.0), 1.0);
        assert!((e.mean() - 2.5).abs() < 1e-12);
    }
}
