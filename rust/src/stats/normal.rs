//! Standard-normal special functions: `erf`, Φ, Φ⁻¹.
//!
//! The paper's analytical runtime model is built entirely on Φ and Φ⁻¹:
//! Eq. 4 (expected max of N normals, via Bailey et al.'s approximation),
//! Eq. 5 (expected completed micro-batches) and Eq. 11 (effective
//! speedup). No libm special functions exist in `std`, so both are
//! implemented here and tested against tabulated values.

/// Complementary error function with *relative* error < 1.2e-7
/// everywhere (Numerical Recipes' Chebyshev fit) — relative accuracy in
/// the tail is what Eq. 4's `Φ⁻¹(1 - 1/N)` needs at large `N`.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223
                                            + t * 0.17087277)))))))))
            .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Error function.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Standard normal CDF Φ(x).
pub fn phi(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Standard normal tail 1 - Φ(x), accurate for large x.
pub fn phi_tail(x: f64) -> f64 {
    0.5 * erfc(x / std::f64::consts::SQRT_2)
}

/// Standard normal PDF φ(x).
pub fn pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Inverse standard normal CDF Φ⁻¹(p), Acklam's rational approximation
/// refined by one Halley step (|rel err| < 1e-9 after refinement).
pub fn phi_inv(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "phi_inv domain: got {p}");

    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // Halley refinement against the forward CDF (tail-aware difference
    // to keep relative precision near p -> 0 or 1).
    // (phi(x) - p == (1-p) - phi_tail(x), computed without cancellation)
    let e = if p > 0.5 { (1.0 - p) - phi_tail(x) } else { phi(x) - p };
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (0.5 * x * x).exp();
    x - u / (1.0 + 0.5 * x * u)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_table_values() {
        // (x, erf(x)) from tables.
        for (x, want) in [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ] {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})={}", erf(x));
        }
    }

    #[test]
    fn phi_table_values() {
        for (x, want) in [
            (0.0, 0.5),
            (1.0, 0.8413447461),
            (1.6448536270, 0.95),
            (2.3263478740, 0.99),
            (-1.0, 0.1586552539),
        ] {
            assert!((phi(x) - want).abs() < 2e-7, "phi({x})={}", phi(x));
        }
    }

    #[test]
    fn phi_inv_table_values() {
        for (p, want) in [
            (0.5, 0.0),
            (0.95, 1.6448536270),
            (0.99, 2.3263478740),
            (0.999, 3.0902323062),
            (0.05, -1.6448536270),
        ] {
            assert!(
                (phi_inv(p) - want).abs() < 1e-6,
                "phi_inv({p})={}",
                phi_inv(p)
            );
        }
    }

    #[test]
    fn phi_roundtrip() {
        for i in 1..200 {
            let p = i as f64 / 200.0;
            let x = phi_inv(p);
            assert!((phi(x) - p).abs() < 1e-6, "p={p}");
        }
    }

    #[test]
    fn pdf_integrates_to_one() {
        let (mut sum, h) = (0.0, 1e-3);
        let mut x = -8.0;
        while x < 8.0 {
            sum += pdf(x) * h;
            x += h;
        }
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn phi_inv_rejects_zero() {
        phi_inv(0.0);
    }
}
