//! Communication timing models for the synchronous AllReduce phase.
//!
//! The paper folds communication into a serial constant `T^c`; we
//! provide that plus **schedule-driven** event simulation: any
//! [`crate::topology::Schedule`] (ring, tree, hierarchical, torus — see
//! [`crate::topology`]) is timed by [`schedule_completion`] honoring
//! per-worker arrival times, so late arrivals stall exactly the
//! dependency chains the topology implies. The same schedule object is
//! executed over real threads by [`crate::collective::engine`], which
//! is what keeps virtual time and real execution in agreement.
//!
//! On top sits the bounded-wait **DropComm** membership rule
//! ([`CommModel::bounded_wait_completion`]): the collective closes its
//! membership a deadline after the first arrival and reduces over the
//! survivors only — the communication-side analogue of DropCompute's
//! compute threshold (cf. OptiReduce, arXiv:2310.06993).

use crate::topology::{Schedule, TopologyKind};

use super::event::EventQueue;

/// Timing model for one AllReduce of `bytes` across `n` workers.
#[derive(Debug, Clone, PartialEq)]
pub enum CommModel {
    /// Fixed serial latency `T^c` regardless of arrival times
    /// (the paper's model: `T + T^c`).
    Fixed(f64),
    /// Ring all-reduce: 2(N-1) phases of `bytes/N` chunks; each hop costs
    /// `latency + chunk_bytes / bandwidth`. Shorthand for
    /// [`CommModel::Topology`] with [`TopologyKind::Ring`].
    Ring {
        /// Per-hop latency, seconds.
        latency: f64,
        /// Link bandwidth, bytes/second.
        bandwidth: f64,
        /// Gradient bytes reduced.
        bytes: f64,
    },
    /// Any topology's schedule, timed by discrete-event simulation.
    Topology {
        kind: TopologyKind,
        /// Per-hop latency, seconds.
        latency: f64,
        /// Link bandwidth, bytes/second.
        bandwidth: f64,
        /// Gradient bytes reduced.
        bytes: f64,
    },
}

impl CommModel {
    /// Time until every worker holds the reduced result; returns the
    /// absolute completion time. Empty `arrivals` (a zero-worker
    /// reduction) completes instantly at 0.0.
    pub fn completion_time(&self, arrivals: &[f64]) -> f64 {
        self.completion_time_with(arrivals, None)
    }

    /// [`Self::completion_time`] with an optional pre-built schedule
    /// for `arrivals.len()` workers — the hot-loop variant: a
    /// `ClusterSim` caches its full-cluster schedule once instead of
    /// rebuilding O(N^2) transfers every step. A schedule of the wrong
    /// size (or `None`) falls back to building one.
    pub fn completion_time_with(
        &self,
        arrivals: &[f64],
        cached: Option<&Schedule>,
    ) -> f64 {
        if arrivals.is_empty() {
            return 0.0;
        }
        match *self {
            CommModel::Fixed(tc) => {
                let start =
                    arrivals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                start + tc
            }
            CommModel::Ring { latency, bandwidth, bytes }
            | CommModel::Topology { latency, bandwidth, bytes, .. } => {
                match cached {
                    Some(s) if s.workers == arrivals.len() => {
                        schedule_completion(s, arrivals, latency, bandwidth, bytes)
                    }
                    _ => {
                        let s = self
                            .schedule_for(arrivals.len())
                            .expect("non-fixed model has a schedule");
                        schedule_completion(&s, arrivals, latency, bandwidth, bytes)
                    }
                }
            }
        }
    }

    /// The schedule this model executes for `n` workers (`None` for
    /// the fixed-`T^c` model, which has no schedule).
    pub fn schedule_for(&self, n: usize) -> Option<Schedule> {
        match *self {
            CommModel::Fixed(_) => None,
            CommModel::Ring { .. } => Some(TopologyKind::Ring.build(n)),
            CommModel::Topology { kind, .. } => Some(kind.build(n)),
        }
    }

    /// `(latency, bandwidth, bytes)` of the schedule-driven models
    /// (`None` for the fixed-`T^c` model).
    pub fn link_params(&self) -> Option<(f64, f64, f64)> {
        match *self {
            CommModel::Fixed(_) => None,
            CommModel::Ring { latency, bandwidth, bytes }
            | CommModel::Topology { latency, bandwidth, bytes, .. } => {
                Some((latency, bandwidth, bytes))
            }
        }
    }

    /// Lower this model's `n`-worker schedule into the heapless compiled
    /// fast path ([`super::compiled::CompiledSchedule`]), with the hop
    /// costs baked in. `None` for the fixed-`T^c` model. Callers that
    /// already hold the built [`Schedule`] should compile it directly
    /// ([`super::compiled::CompiledSchedule::compile`]) instead of
    /// rebuilding it here.
    pub fn compile_for(&self, n: usize) -> Option<super::compiled::CompiledSchedule> {
        let (latency, bandwidth, bytes) = self.link_params()?;
        self.schedule_for(n).map(|s| {
            super::compiled::CompiledSchedule::compile(
                &s, latency, bandwidth, bytes,
            )
        })
    }

    /// The serial constant `T^c` this model contributes when all workers
    /// arrive simultaneously (used by the analytical speedup model).
    pub fn serial_latency(&self, n: usize) -> f64 {
        match *self {
            CommModel::Fixed(tc) => tc,
            CommModel::Ring { latency, bandwidth, bytes } => {
                if n <= 1 {
                    return 0.0;
                }
                let phases = 2 * (n - 1);
                let chunk = bytes / n as f64;
                phases as f64 * (latency + chunk / bandwidth)
            }
            CommModel::Topology { kind, latency, bandwidth, bytes } => {
                kind.build(n).uniform_cost(latency, bandwidth, bytes)
            }
        }
    }

    /// Bounded-wait (DropComm) all-reduce: membership closes `deadline`
    /// seconds after the *first* arrival; later workers are excluded
    /// from the reduction (their gradient contribution is dropped and
    /// the sum reweighted by the caller) and simply receive the result.
    ///
    /// This is the *oracle* form: it allocates a mask and a compacted
    /// arrival vector and rebuilds the k-survivor schedule through the
    /// event-queue simulation on every call. Hot loops route the
    /// exclusion branch through
    /// [`super::survivor::SurvivorScheduleCache`], which is bitwise
    /// identical (property-tested) and allocation-free after warmup.
    ///
    /// Returns the per-worker survivor mask and the completion time of
    /// the survivors' collective. The first arrival always survives, so
    /// the reduction is never empty.
    ///
    /// Timing: with no exclusions, membership closes the moment the
    /// last worker arrives and the collective runs exactly as the
    /// plain model (no deadline wait is ever paid). When someone *is*
    /// excluded, the survivor set — and therefore the k-member
    /// schedule — is only knowable at `close = first + deadline`, so
    /// the survivors' collective starts there (all of them have
    /// arrived by definition) and completion is `close` plus its
    /// simultaneous-start cost. No clairvoyant overlap of collective
    /// work with the waiting window is assumed.
    pub fn bounded_wait_completion(
        &self,
        arrivals: &[f64],
        deadline: f64,
    ) -> (Vec<bool>, f64) {
        let survivors = bounded_wait_survivors(arrivals, deadline);
        let sub: Vec<f64> = arrivals
            .iter()
            .zip(&survivors)
            .filter(|(_, &s)| s)
            .map(|(&a, _)| a)
            .collect();
        let t = if sub.len() < arrivals.len() {
            // every survivor arrived by the membership close; the
            // k-member collective starts simultaneously there
            let close = bounded_wait_cutoff(arrivals, deadline);
            self.completion_time(&vec![close; sub.len()])
        } else {
            self.completion_time(&sub)
        };
        (survivors, t)
    }

    /// Per-phase bounded-wait (the
    /// [`crate::policy::DropPolicy::PerPhaseDeadline`] policy), oracle
    /// form: the event-queue twin of
    /// [`super::compiled::CompiledSchedule::bounded_completion_with`],
    /// bitwise identical to it (property-tested in
    /// `tests/policy_equivalence.rs`).
    ///
    /// `budget_offsets` are the *cumulative* checkpoint offsets
    /// ([`crate::policy::cumulative_offsets`]): phase `p`'s entry closes
    /// at `first_arrival + budget_offsets[p]`. Checkpoint 0 is the
    /// step-level membership rule on raw arrivals (a single lumped
    /// budget is exactly [`Self::bounded_wait_completion`]); later
    /// checkpoints see the per-phase readiness of the event simulation.
    /// When anyone is dropped, the survivors' collective restarts
    /// simultaneously at the last triggering cutoff — same
    /// non-clairvoyant reasoning as the step-level rule. The fixed-`T^c`
    /// model has no phase structure, so its budgets lump to their total.
    ///
    /// Returns the per-worker *survivor* mask (`true` = participates)
    /// and the completion time.
    pub fn per_phase_bounded_completion(
        &self,
        arrivals: &[f64],
        budget_offsets: &[f64],
        cached: Option<&Schedule>,
    ) -> (Vec<bool>, f64) {
        if arrivals.is_empty() {
            return (Vec::new(), 0.0);
        }
        let (latency, bandwidth, bytes) = match *self {
            CommModel::Fixed(_) => {
                // no phases: the budgets lump to their (cumulative)
                // total; no budgets at all is unconstrained, matching
                // the schedule models' checkpoint-free scan
                return match budget_offsets.last() {
                    None => {
                        (vec![true; arrivals.len()],
                         self.completion_time(arrivals))
                    }
                    Some(&total) => {
                        self.bounded_wait_completion(arrivals, total)
                    }
                };
            }
            CommModel::Ring { latency, bandwidth, bytes }
            | CommModel::Topology { latency, bandwidth, bytes, .. } => {
                (latency, bandwidth, bytes)
            }
        };
        let built;
        let schedule = match cached {
            Some(s) if s.workers == arrivals.len() => s,
            _ => {
                built = self
                    .schedule_for(arrivals.len())
                    .expect("non-fixed model has a schedule");
                &built
            }
        };
        let scan = per_phase_event_scan(
            schedule,
            arrivals,
            budget_offsets,
            latency,
            bandwidth,
            bytes,
        );
        if scan.survivors == arrivals.len() {
            let t =
                scan.ready.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            (scan.alive, t)
        } else if scan.survivors == 0 {
            // every worker missed a checkpoint: nothing to reduce, the
            // step ends when the last membership window closes
            (scan.alive, scan.close.max(0.0))
        } else {
            let t = self.completion_time(&vec![scan.close; scan.survivors]);
            (scan.alive, t)
        }
    }

    /// [`Self::per_phase_bounded_completion`] under the *recursive*
    /// restart semantics (the default since the trace PR): when a
    /// checkpoint drops workers, the survivors' restarted collective is
    /// itself re-checked against the budgets *after* the triggering
    /// checkpoint, rebased to the restart instant
    /// ([`crate::policy::rebased_offsets`]) — and so on recursively,
    /// until a level completes, runs out of checkpoints, or drops
    /// everyone. A level with no remaining budgets times the survivors
    /// exactly like the single-restart rule, so the two semantics agree
    /// bitwise whenever no checkpoint follows the triggering one (in
    /// particular, a single lumped budget is still bitwise the
    /// step-level [`Self::bounded_wait_completion`]).
    ///
    /// This is the event-queue oracle of the compiled recursion in
    /// [`crate::sim::ClusterSim`] — bitwise identical (property-tested
    /// in `tests/policy_equivalence.rs`). The fixed-`T^c` model has no
    /// phase structure, so there is nothing to re-check and the lumped
    /// single-restart form applies unchanged.
    pub fn per_phase_bounded_completion_recursive(
        &self,
        arrivals: &[f64],
        budget_offsets: &[f64],
        cached: Option<&Schedule>,
    ) -> (Vec<bool>, f64) {
        if arrivals.is_empty() {
            return (Vec::new(), 0.0);
        }
        let (latency, bandwidth, bytes) = match self.link_params() {
            // fixed model: budgets lump, no phases to re-check
            None => {
                return self.per_phase_bounded_completion(
                    arrivals,
                    budget_offsets,
                    cached,
                )
            }
            Some(p) => p,
        };
        let mut alive = vec![true; arrivals.len()];
        let mut alive_idx: Vec<usize> = (0..arrivals.len()).collect();
        let mut cur_arrivals: Vec<f64> = arrivals.to_vec();
        let mut offsets: Vec<f64> = budget_offsets.to_vec();
        let mut top_level = true;
        loop {
            let built;
            let schedule = match (top_level, cached) {
                (true, Some(s)) if s.workers == cur_arrivals.len() => s,
                _ => {
                    built = self
                        .schedule_for(cur_arrivals.len())
                        .expect("non-fixed model has a schedule");
                    &built
                }
            };
            let scan = per_phase_event_scan(
                schedule,
                &cur_arrivals,
                &offsets,
                latency,
                bandwidth,
                bytes,
            );
            if scan.survivors == cur_arrivals.len() {
                let t = scan
                    .ready
                    .iter()
                    .cloned()
                    .fold(f64::NEG_INFINITY, f64::max);
                return (alive, t);
            }
            // map the level's drops back to global worker ids and
            // compact the alive list
            let mut w = 0usize;
            for (j, &worker) in alive_idx.clone().iter().enumerate() {
                if scan.alive[j] {
                    alive_idx[w] = worker;
                    w += 1;
                } else {
                    alive[worker] = false;
                }
            }
            alive_idx.truncate(w);
            if scan.survivors == 0 {
                return (alive, scan.close.max(0.0));
            }
            let rem = crate::policy::rebased_offsets(&offsets, scan.checkpoint);
            if rem.is_empty() {
                // no checkpoints beyond the trigger: the single-restart
                // rule, bit for bit
                let t =
                    self.completion_time(&vec![scan.close; scan.survivors]);
                return (alive, t);
            }
            offsets = rem;
            cur_arrivals.clear();
            cur_arrivals.resize(scan.survivors, scan.close);
            top_level = false;
        }
    }
}

/// Result of one bounded per-phase event-queue scan (the oracle twin of
/// [`super::compiled::CompiledSchedule::bounded_completion_with`]).
struct PhaseScan {
    /// `true` = survived every checkpoint of this scan.
    alive: Vec<bool>,
    /// Per-worker readiness after the last phase.
    ready: Vec<f64>,
    survivors: usize,
    /// Cutoff of the last checkpoint that dropped anyone
    /// (`NEG_INFINITY` when nobody dropped).
    close: f64,
    /// Index of that checkpoint (0 when nobody dropped).
    checkpoint: usize,
}

/// One bounded per-phase scan of `schedule` with event-queue phase
/// timing: checkpoint `p` closes phase-`p` entry at
/// `first_arrival + budget_offsets[p]` (checkpoint 0 on raw arrivals),
/// phases drain one [`EventQueue`] each — exactly
/// [`schedule_completion`]'s inner loop. Shared by the single-restart
/// and recursive oracle forms so both see identical bits.
fn per_phase_event_scan(
    schedule: &Schedule,
    arrivals: &[f64],
    budget_offsets: &[f64],
    latency: f64,
    bandwidth: f64,
    bytes: f64,
) -> PhaseScan {
    let first = arrivals.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut ready: Vec<f64> = arrivals.iter().map(|a| a.max(0.0)).collect();
    let mut alive = vec![true; arrivals.len()];
    let mut survivors = arrivals.len();
    let mut close = f64::NEG_INFINITY;
    let mut checkpoint = 0usize;
    let phases = schedule.phases.len();
    for p in 0..phases.max(budget_offsets.len()) {
        if p < budget_offsets.len() {
            let cutoff = first + budget_offsets[p];
            for (n, a) in alive.iter_mut().enumerate() {
                if !*a {
                    continue;
                }
                let v = if p == 0 { arrivals[n] } else { ready[n] };
                if v > cutoff {
                    *a = false;
                    survivors -= 1;
                    close = cutoff;
                    checkpoint = p;
                }
            }
        }
        if p < phases {
            // one event-queue drain, exactly schedule_completion's
            // per-phase inner loop
            let phase = &schedule.phases[p];
            let mut q = EventQueue::new();
            for (k, t) in phase.transfers.iter().enumerate() {
                let hop = latency + t.chunk.fraction() * bytes / bandwidth;
                q.schedule_at(ready[t.src] + hop, k as u64);
            }
            let mut next = ready.clone();
            while let Some(ev) = q.pop() {
                let t = &phase.transfers[ev.tag as usize];
                if ev.time > next[t.dst] {
                    next[t.dst] = ev.time;
                }
                if ev.time > next[t.src] {
                    next[t.src] = ev.time;
                }
            }
            ready = next;
        }
    }
    PhaseScan { alive, ready, survivors, close, checkpoint }
}

/// The DropComm membership cutoff: the single source of truth for the
/// rule shared by [`bounded_wait_survivors`] and the allocation-free
/// check in `ClusterSim` — worker `w` participates iff
/// `arrival <= cutoff` (`deadline < 0` is treated as 0, so only ties
/// with the first arrival survive).
pub fn bounded_wait_cutoff(arrivals: &[f64], deadline: f64) -> f64 {
    let first = arrivals.iter().cloned().fold(f64::INFINITY, f64::min);
    first + deadline.max(0.0)
}

/// The DropComm membership rule as a per-worker mask (see
/// [`bounded_wait_cutoff`]).
pub fn bounded_wait_survivors(arrivals: &[f64], deadline: f64) -> Vec<bool> {
    if arrivals.is_empty() {
        return Vec::new();
    }
    let cutoff = bounded_wait_cutoff(arrivals, deadline);
    arrivals.iter().map(|&a| a <= cutoff).collect()
}

/// Event-driven completion of a [`Schedule`] with heterogeneous
/// arrivals.
///
/// Worker `w` can launch its phase-`p` send once it has arrived,
/// delivered its earlier sends, and received everything addressed to it
/// in phases `< p`; each transfer occupies its link for
/// `latency + fraction·bytes/bandwidth`. Phases layer the dependency
/// DAG, so the simulation drains one [`EventQueue`] per phase (events
/// pop in time order, ties broken by schedule order) and carries each
/// worker's readiness forward. With simultaneous arrivals this
/// reproduces [`Schedule::uniform_cost`] exactly — for the ring, the
/// closed-form `2(N-1)·(latency + bytes/(N·bw))`.
pub fn schedule_completion(
    schedule: &Schedule,
    arrivals: &[f64],
    latency: f64,
    bandwidth: f64,
    bytes: f64,
) -> f64 {
    assert_eq!(
        schedule.workers,
        arrivals.len(),
        "schedule built for a different worker count"
    );
    if arrivals.is_empty() {
        return 0.0;
    }
    // ready[w] = earliest time w can act in the next phase.
    let mut ready: Vec<f64> = arrivals.iter().map(|a| a.max(0.0)).collect();
    for phase in &schedule.phases {
        let mut q = EventQueue::new();
        for (k, t) in phase.transfers.iter().enumerate() {
            let hop = latency + t.chunk.fraction() * bytes / bandwidth;
            q.schedule_at(ready[t.src] + hop, k as u64);
        }
        let mut next = ready.clone();
        while let Some(ev) = q.pop() {
            let t = &phase.transfers[ev.tag as usize];
            // data dependency: dst holds the chunk at delivery time
            if ev.time > next[t.dst] {
                next[t.dst] = ev.time;
            }
            // egress occupancy: src's link is busy until delivery
            if ev.time > next[t.src] {
                next[t.src] = ev.time;
            }
        }
        ready = next;
    }
    ready.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_model_adds_tc_to_max_arrival() {
        let m = CommModel::Fixed(0.5);
        assert!((m.completion_time(&[1.0, 3.0, 2.0]) - 3.5).abs() < 1e-12);
        assert_eq!(m.serial_latency(8), 0.5);
    }

    #[test]
    fn empty_arrivals_complete_at_zero() {
        // Regression: the old fold over max started at NEG_INFINITY and
        // returned it for an empty reduction.
        for m in [
            CommModel::Fixed(0.5),
            CommModel::Ring { latency: 1e-4, bandwidth: 1e9, bytes: 4e6 },
            CommModel::Topology {
                kind: TopologyKind::Tree,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            },
        ] {
            let t = m.completion_time(&[]);
            assert_eq!(t, 0.0, "{m:?}");
            assert!(t.is_finite());
        }
    }

    #[test]
    fn ring_simultaneous_arrivals_match_closed_form() {
        let (lat, bw, bytes) = (1e-4, 1e9, 4e6);
        let m = CommModel::Ring { latency: lat, bandwidth: bw, bytes };
        for n in [2usize, 4, 8, 16] {
            let arrivals = vec![0.0; n];
            let got = m.completion_time(&arrivals);
            let want = m.serial_latency(n);
            assert!(
                (got - want).abs() < 1e-9,
                "n={n}: event-sim {got} vs closed form {want}"
            );
        }
    }

    #[test]
    fn every_topology_uniform_arrivals_match_uniform_cost() {
        let (lat, bw, bytes) = (25e-6, 12.5e9, 1e8);
        for kind in TopologyKind::ALL {
            for n in [2usize, 4, 7, 8, 12] {
                let m = CommModel::Topology {
                    kind,
                    latency: lat,
                    bandwidth: bw,
                    bytes,
                };
                let got = m.completion_time(&vec![0.0; n]);
                let want = kind.build(n).uniform_cost(lat, bw, bytes);
                assert!(
                    (got - want).abs() < 1e-9,
                    "{} n={n}: {got} vs {want}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn ring_variant_is_topology_ring() {
        let (lat, bw, bytes) = (1e-4, 1e9, 4e6);
        let ring = CommModel::Ring { latency: lat, bandwidth: bw, bytes };
        let topo = CommModel::Topology {
            kind: TopologyKind::Ring,
            latency: lat,
            bandwidth: bw,
            bytes,
        };
        let arrivals = [0.3, 0.1, 0.7, 0.2, 0.5];
        assert_eq!(
            ring.completion_time(&arrivals).to_bits(),
            topo.completion_time(&arrivals).to_bits()
        );
    }

    #[test]
    fn ring_straggler_dominates() {
        let m = CommModel::Ring { latency: 1e-4, bandwidth: 1e9, bytes: 4e6 };
        let fast = m.completion_time(&[0.0, 0.0, 0.0, 0.0]);
        let strag = m.completion_time(&[0.0, 0.0, 5.0, 0.0]);
        // a 5s-late worker pushes completion past 5s + ring time ~ fast
        assert!(strag > 5.0);
        assert!((strag - (5.0 + fast)).abs() < fast, "{strag} vs {fast}");
    }

    #[test]
    fn straggler_stalls_every_topology() {
        // the dependency chains differ, but in every topology a very
        // late worker delays global completion past its arrival.
        for kind in TopologyKind::ALL {
            let m = CommModel::Topology {
                kind,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            };
            let fast = m.completion_time(&vec![0.0; 8]);
            let mut arr = vec![0.0; 8];
            arr[3] = 5.0;
            let strag = m.completion_time(&arr);
            assert!(strag > 5.0, "{}: {strag}", kind.name());
            assert!(fast < 1.0, "{}: {fast}", kind.name());
        }
    }

    #[test]
    fn ring_more_workers_not_cheaper_total_latency() {
        let m = CommModel::Ring { latency: 1e-3, bandwidth: 1e9, bytes: 1e3 };
        // latency-dominated regime: more workers = more phases = slower
        assert!(m.serial_latency(32) > m.serial_latency(4));
    }

    #[test]
    fn ring_bandwidth_term_scales_with_bytes() {
        let small = CommModel::Ring { latency: 0.0, bandwidth: 1e9, bytes: 1e6 };
        let large = CommModel::Ring { latency: 0.0, bandwidth: 1e9, bytes: 4e6 };
        let n = 8;
        let r = large.serial_latency(n) / small.serial_latency(n);
        assert!((r - 4.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_single_worker() {
        let m = CommModel::Ring { latency: 1e-3, bandwidth: 1e9, bytes: 1e6 };
        assert_eq!(m.completion_time(&[2.0]), 2.0);
        assert_eq!(m.serial_latency(1), 0.0);
    }

    #[test]
    fn bounded_wait_mask_and_first_always_survives() {
        let arr = [3.0, 0.5, 0.6, 9.0];
        let surv = bounded_wait_survivors(&arr, 1.0);
        assert_eq!(surv, vec![false, true, true, false]);
        // negative deadline clamps to 0: only the first arrival survives
        let surv0 = bounded_wait_survivors(&arr, -5.0);
        assert_eq!(surv0, vec![false, true, false, false]);
        assert!(bounded_wait_survivors(&[], 1.0).is_empty());
    }

    #[test]
    fn dropcomm_caps_the_straggler_tail() {
        let m = CommModel::Ring { latency: 1e-4, bandwidth: 1e9, bytes: 4e6 };
        let arrivals = [0.1, 0.2, 0.15, 100.0];
        let full = m.completion_time(&arrivals);
        assert!(full > 100.0, "baseline waits for the straggler: {full}");
        let (surv, t) = m.bounded_wait_completion(&arrivals, 1.0);
        assert_eq!(surv, vec![true, true, true, false]);
        // the membership decision is made at first + deadline = 1.1
        // (no clairvoyance), then the survivors' collective is done.
        assert!(t >= 1.1 - 1e-12, "cannot close membership early: {t}");
        assert!(t < 2.0, "bounded wait completes without the straggler: {t}");
    }

    #[test]
    fn per_phase_lumped_budget_is_step_level_bounded_wait() {
        // a single lumped budget must be bitwise the step-level rule,
        // for every model kind, with and without exclusions
        let models = [
            CommModel::Fixed(0.5),
            CommModel::Ring { latency: 1e-4, bandwidth: 1e9, bytes: 4e6 },
            CommModel::Topology {
                kind: TopologyKind::Torus { rows: 0 },
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            },
        ];
        let arrivals = [0.3, 0.1, 7.0, 0.2, 0.5];
        for m in &models {
            for deadline in [0.0, 1.0, 100.0] {
                let (want_mask, want_t) =
                    m.bounded_wait_completion(&arrivals, deadline);
                let offsets = crate::policy::cumulative_offsets(&[deadline]);
                let (mask, t) = m.per_phase_bounded_completion(
                    &arrivals, &offsets, None,
                );
                assert_eq!(mask, want_mask, "{m:?} deadline={deadline}");
                assert_eq!(
                    t.to_bits(),
                    want_t.to_bits(),
                    "{m:?} deadline={deadline}"
                );
            }
        }
    }

    #[test]
    fn per_phase_unconstrained_is_plain_collective() {
        let m = CommModel::Topology {
            kind: TopologyKind::Tree,
            latency: 1e-4,
            bandwidth: 1e9,
            bytes: 4e6,
        };
        let arrivals = [0.3, 0.1, 0.7, 0.2, 0.5];
        let (mask, t) =
            m.per_phase_bounded_completion(&arrivals, &[1e9, 2e9], None);
        assert!(mask.iter().all(|&s| s));
        assert_eq!(t.to_bits(), m.completion_time(&arrivals).to_bits());
        // empty arrivals complete instantly
        let (mask, t) = m.per_phase_bounded_completion(&[], &[1.0], None);
        assert!(mask.is_empty());
        assert_eq!(t, 0.0);
    }

    #[test]
    fn recursive_restart_agrees_with_single_when_no_budgets_remain() {
        // a single lumped budget (and any trigger at the last
        // checkpoint) leaves nothing to re-check: the recursive form
        // must be bitwise the single-restart form — and therefore still
        // bitwise the step-level bounded wait.
        let models = [
            CommModel::Fixed(0.5),
            CommModel::Ring { latency: 1e-4, bandwidth: 1e9, bytes: 4e6 },
            CommModel::Topology {
                kind: TopologyKind::Tree,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            },
        ];
        let arrivals = [0.3, 0.1, 7.0, 0.2, 0.5];
        for m in &models {
            for deadline in [0.0, 1.0, 100.0] {
                let offsets = crate::policy::cumulative_offsets(&[deadline]);
                let (want_mask, want_t) =
                    m.per_phase_bounded_completion(&arrivals, &offsets, None);
                let (mask, t) = m.per_phase_bounded_completion_recursive(
                    &arrivals, &offsets, None,
                );
                assert_eq!(mask, want_mask, "{m:?} deadline={deadline}");
                assert_eq!(
                    t.to_bits(),
                    want_t.to_bits(),
                    "{m:?} deadline={deadline}"
                );
            }
        }
        // empty arrivals complete instantly in both forms
        let m = &models[1];
        let (mask, t) =
            m.per_phase_bounded_completion_recursive(&[], &[1.0], None);
        assert!(mask.is_empty());
        assert_eq!(t, 0.0);
    }

    #[test]
    fn recursive_restart_rechecks_survivors_against_remaining_budgets() {
        // tree, the ROOT straggles: during the reduce phases the other
        // workers' readiness stays low (they only send), so the single
        // scan's later checkpoints admit all four survivors and the last
        // trigger stays at the entry checkpoint. Single-restart then
        // times the survivors' full tree unchecked; the recursive
        // semantics re-check that restart against the remaining tight
        // budgets, whose cutoff (restart + 0.004) the restart's first
        // 0.005s hop already misses — everyone is dropped and the step
        // ends at the final window close.
        let m = CommModel::Topology {
            kind: TopologyKind::Tree,
            latency: 1e-3,
            bandwidth: 1e9,
            bytes: 4e6, // full-buffer tree hop = 1e-3 + 4e-3 = 5e-3
        };
        let arrivals = [1.0005, 0.0, 0.1, 0.2, 0.15];
        let offsets = crate::policy::cumulative_offsets(&[1.0, 0.004, 0.0, 0.0]);
        let (mask_s, t_single) =
            m.per_phase_bounded_completion(&arrivals, &offsets, None);
        assert_eq!(
            mask_s,
            vec![false, true, true, true, true],
            "single scan drops only the root straggler"
        );
        let want_single = m.completion_time(&vec![1.0; 4]);
        assert_eq!(t_single.to_bits(), want_single.to_bits());
        let (mask_r, t_rec) =
            m.per_phase_bounded_completion_recursive(&arrivals, &offsets, None);
        assert_eq!(
            mask_r,
            vec![false; 5],
            "the restarted tree misses the rebased 0.004 budget"
        );
        assert!(t_rec < t_single, "{t_rec} vs {t_single}");
        // the recursive step ends at the re-check's window close:
        // restart at 1.0 plus the rebased second budget
        assert!((t_rec - 1.004).abs() < 1e-9, "{t_rec}");
    }

    #[test]
    fn dropcomm_with_loose_deadline_is_plain_allreduce() {
        let m = CommModel::Topology {
            kind: TopologyKind::Tree,
            latency: 1e-4,
            bandwidth: 1e9,
            bytes: 4e6,
        };
        let arrivals = [0.3, 0.1, 0.7, 0.2, 0.5];
        let (surv, t) = m.bounded_wait_completion(&arrivals, 10.0);
        assert!(surv.iter().all(|&s| s));
        assert_eq!(t.to_bits(), m.completion_time(&arrivals).to_bits());
    }
}
