//! Communication timing models for the synchronous AllReduce phase.
//!
//! The paper folds communication into a serial constant `T^c`; we provide
//! that plus an event-driven **ring** model (Patarasuk & Yuan 2009 —
//! the bandwidth-optimal algorithm the paper's decentralized setting
//! assumes) where workers *arrive* at different times: late arrivals
//! stall their ring neighbours, which is exactly why stragglers hurt.

use super::event::EventQueue;

/// Timing model for one AllReduce of `bytes` across `n` workers.
#[derive(Debug, Clone)]
pub enum CommModel {
    /// Fixed serial latency `T^c` regardless of arrival times
    /// (the paper's model: `T + T^c`).
    Fixed(f64),
    /// Ring all-reduce: 2(N-1) phases of `bytes/N` chunks; each hop costs
    /// `latency + chunk_bytes / bandwidth`. Completion is computed by a
    /// discrete-event simulation honoring per-worker arrival times.
    Ring {
        /// Per-hop latency, seconds.
        latency: f64,
        /// Link bandwidth, bytes/second.
        bandwidth: f64,
        /// Gradient bytes reduced.
        bytes: f64,
    },
}

impl CommModel {
    /// Time from `max(arrivals)` until every worker holds the reduced
    /// result; returns the absolute completion time.
    pub fn completion_time(&self, arrivals: &[f64]) -> f64 {
        let start = arrivals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        match self {
            CommModel::Fixed(tc) => start + tc,
            CommModel::Ring { latency, bandwidth, bytes } => {
                ring_completion(arrivals, *latency, *bandwidth, *bytes)
            }
        }
    }

    /// The serial constant `T^c` this model contributes when all workers
    /// arrive simultaneously (used by the analytical speedup model).
    pub fn serial_latency(&self, n: usize) -> f64 {
        match self {
            CommModel::Fixed(tc) => *tc,
            CommModel::Ring { latency, bandwidth, bytes } => {
                if n <= 1 {
                    return 0.0;
                }
                let phases = 2 * (n - 1);
                let chunk = bytes / n as f64;
                phases as f64 * (latency + chunk / bandwidth)
            }
        }
    }
}

/// Event-driven ring all-reduce completion with heterogeneous arrivals.
///
/// Worker `w` can send its phase-`p` message once (a) it has arrived,
/// and (b) it has received the phase-`p-1` message from its predecessor.
/// Dependency: recv(w, p) happens at
/// `max(arrive(w-1), recv(w-1, p-1)) + hop`, which we simulate rather
/// than solve in closed form so the model extends to irregular topologies.
fn ring_completion(arrivals: &[f64], latency: f64, bandwidth: f64, bytes: f64) -> f64 {
    let n = arrivals.len();
    if n <= 1 {
        return arrivals.first().copied().unwrap_or(0.0);
    }
    let phases = 2 * (n - 1);
    let hop = latency + bytes / n as f64 / bandwidth;

    // ready[w] = earliest time worker w can send its next message.
    let mut ready = arrivals.to_vec();
    let mut recv_done = vec![0.0f64; n];
    let mut q = EventQueue::new();
    // tag encodes (phase, worker): fire when w's phase-p send *completes*
    // at the receiver (w+1) % n.
    let tag = |p: usize, w: usize| (p * n + w) as u64;

    for w in 0..n {
        q.schedule_at(ready[w].max(0.0) + hop, tag(0, w));
    }
    let mut last = 0.0f64;
    while let Some(ev) = q.pop() {
        let p = ev.tag as usize / n;
        let w = ev.tag as usize % n; // sender
        let dst = (w + 1) % n;
        recv_done[dst] = recv_done[dst].max(ev.time);
        last = last.max(ev.time);
        if p + 1 < phases {
            // dst forwards in phase p+1 once it has arrived and received.
            let t_send = ready[dst].max(recv_done[dst]);
            ready[dst] = t_send;
            q.schedule_at(t_send.max(ev.time) + hop, tag(p + 1, dst));
        }
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_model_adds_tc_to_max_arrival() {
        let m = CommModel::Fixed(0.5);
        assert!((m.completion_time(&[1.0, 3.0, 2.0]) - 3.5).abs() < 1e-12);
        assert_eq!(m.serial_latency(8), 0.5);
    }

    #[test]
    fn ring_simultaneous_arrivals_match_closed_form() {
        let (lat, bw, bytes) = (1e-4, 1e9, 4e6);
        let m = CommModel::Ring { latency: lat, bandwidth: bw, bytes };
        for n in [2usize, 4, 8, 16] {
            let arrivals = vec![0.0; n];
            let got = m.completion_time(&arrivals);
            let want = m.serial_latency(n);
            assert!(
                (got - want).abs() < 1e-9,
                "n={n}: event-sim {got} vs closed form {want}"
            );
        }
    }

    #[test]
    fn ring_straggler_dominates() {
        let m = CommModel::Ring { latency: 1e-4, bandwidth: 1e9, bytes: 4e6 };
        let fast = m.completion_time(&[0.0, 0.0, 0.0, 0.0]);
        let strag = m.completion_time(&[0.0, 0.0, 5.0, 0.0]);
        // a 5s-late worker pushes completion past 5s + ring time ~ fast
        assert!(strag > 5.0);
        assert!((strag - (5.0 + fast)).abs() < fast, "{strag} vs {fast}");
    }

    #[test]
    fn ring_more_workers_not_cheaper_total_latency() {
        let m = CommModel::Ring { latency: 1e-3, bandwidth: 1e9, bytes: 1e3 };
        // latency-dominated regime: more workers = more phases = slower
        assert!(m.serial_latency(32) > m.serial_latency(4));
    }

    #[test]
    fn ring_bandwidth_term_scales_with_bytes() {
        let small = CommModel::Ring { latency: 0.0, bandwidth: 1e9, bytes: 1e6 };
        let large = CommModel::Ring { latency: 0.0, bandwidth: 1e9, bytes: 4e6 };
        let n = 8;
        let r = large.serial_latency(n) / small.serial_latency(n);
        assert!((r - 4.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_single_worker() {
        let m = CommModel::Ring { latency: 1e-3, bandwidth: 1e9, bytes: 1e6 };
        assert_eq!(m.completion_time(&[2.0]), 2.0);
        assert_eq!(m.serial_latency(1), 0.0);
    }
}
