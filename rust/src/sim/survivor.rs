//! Per-survivor-count compiled schedules for the DropComm collective —
//! the drop-path twin of [`super::compiled`].
//!
//! When the bounded-wait membership rule excludes at least one worker,
//! the k survivors run a *k*-member collective starting simultaneously
//! at the membership close (`first arrival + deadline`; see
//! [`super::comm::CommModel::bounded_wait_completion`]). The oracle path
//! rebuilds that k-worker [`crate::topology::Schedule`] — O(N²)
//! transfers for torus/hierarchical — and times it through the
//! event-queue simulation on **every** drop step, plus a survivor mask
//! and a compacted-arrivals vector: three allocations and a schedule
//! build in exactly the regime the Fig 1/13/14 sweeps hit millions of
//! times.
//!
//! [`SurvivorScheduleCache`] memoizes one [`CompiledSchedule`] (and its
//! [`ScheduleScratch`]) per survivor count k, compiled lazily on first
//! use, plus one reusable arrivals buffer. After warmup a drop step
//! performs zero allocations and zero schedule builds. The result is
//! **bitwise identical** to the event-queue oracle: the cache builds the
//! same k-worker schedule (`CommModel::schedule_for`), all survivors
//! start at the same instant, and the compiled per-phase pass is
//! bitwise equal to the event simulation (the PR-2 invariant) —
//! property-tested in `tests/perf_equivalence.rs`.

use super::comm::CommModel;
use super::compiled::{CompiledSchedule, PhaseBounded, ScheduleScratch};

#[derive(Debug)]
struct Slot {
    compiled: CompiledSchedule,
    scratch: ScheduleScratch,
}

/// Lazily-compiled per-k survivor collectives for one [`CommModel`].
/// Owned by [`super::ClusterSim`]; `completion` is its drop-branch hot
/// path.
#[derive(Debug)]
pub struct SurvivorScheduleCache {
    model: CommModel,
    /// `slots[k]` holds the compiled k-survivor schedule once some step
    /// has dropped down to k members.
    slots: Vec<Option<Slot>>,
    /// Reusable compacted-arrivals buffer (`[close; k]`).
    arrivals: Vec<f64>,
    compiled: usize,
}

impl SurvivorScheduleCache {
    pub fn new(model: &CommModel) -> Self {
        Self {
            model: model.clone(),
            slots: Vec::new(),
            arrivals: Vec::new(),
            compiled: 0,
        }
    }

    /// How many distinct survivor counts have been compiled so far
    /// (memoization introspection for tests and diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.compiled
    }

    /// Whether this cache was built for `model` — the guard that lets a
    /// warm cache hop between sims (and sweep points) sharing a comm
    /// model. Survivor schedules depend only on the topology kind and
    /// link parameters (a k-member schedule is the same whatever the
    /// full cluster size), so one cache serves every `N`.
    pub fn matches(&self, model: &CommModel) -> bool {
        self.model == *model
    }

    /// Lazily compile (and memoize) the k-member schedule. Callers have
    /// already dispatched away the fixed-`T^c` model, which has no
    /// schedule to compile. Returns nothing so call sites can take the
    /// slot as a direct field projection alongside the arrivals buffer
    /// (disjoint borrows).
    fn ensure_slot(&mut self, k: usize) {
        if self.slots.len() <= k {
            self.slots.resize_with(k + 1, || None);
        }
        if self.slots[k].is_none() {
            let (latency, bandwidth, bytes) = self
                .model
                .link_params()
                .expect("schedule-driven model has link params");
            let schedule = self
                .model
                .schedule_for(k)
                .expect("schedule-driven model has a schedule");
            self.slots[k] = Some(Slot {
                compiled: CompiledSchedule::compile(
                    &schedule, latency, bandwidth, bytes,
                ),
                scratch: ScheduleScratch::with_capacity(k),
            });
            self.compiled += 1;
        }
    }

    /// Completion time of the k-survivor collective whose members all
    /// start at `close` (the membership decision instant). Bitwise equal
    /// to the oracle's `completion_time(&vec![close; k])` — the max over
    /// k equal arrivals is `close`, and the compiled pass is bitwise
    /// equal to the event-queue simulation of the same k-worker
    /// schedule — with no allocation or schedule build after the first
    /// drop to a given k.
    pub fn completion(&mut self, k: usize, close: f64) -> f64 {
        if k == 0 {
            // an empty reduction completes instantly, matching
            // `CommModel::completion_time(&[])`
            return 0.0;
        }
        if let CommModel::Fixed(tc) = self.model {
            return close + tc;
        }
        self.ensure_slot(k);
        // lint:allow(hotpath-panic): ensure_slot(k) filled this slot on the line above
        let slot = self.slots[k].as_mut().expect("slot just ensured");
        self.arrivals.clear();
        self.arrivals.resize(k, close);
        slot.compiled.completion_with(&self.arrivals, &mut slot.scratch)
    }

    /// Completion time of the `arrivals.len()`-member collective over
    /// *heterogeneous* arrivals — the fault path's plain collective:
    /// live workers keep their own arrival times (unlike the
    /// membership-close restart, where all k start together). Bitwise
    /// equal to the oracle's `completion_time(arrivals)` over the same
    /// k-worker schedule, through the same memoized per-k slots.
    pub fn completion_at(&mut self, arrivals: &[f64]) -> f64 {
        let k = arrivals.len();
        if k == 0 {
            return 0.0;
        }
        if let CommModel::Fixed(tc) = self.model {
            let start =
                arrivals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            return start + tc;
        }
        self.ensure_slot(k);
        // lint:allow(hotpath-panic): ensure_slot(k) filled this slot on the line above
        let slot = self.slots[k].as_mut().expect("slot just ensured");
        slot.compiled.completion_with(arrivals, &mut slot.scratch)
    }

    /// The per-phase bounded scan over *heterogeneous* arrivals — the
    /// fault path's per-phase collective: the live sub-cluster's
    /// k-member schedule is checked against the cumulative budget
    /// `offsets` exactly like the full-cluster compiled scan, bitwise
    /// equal to the event-queue oracle
    /// ([`CommModel::per_phase_bounded_completion`]) over the same
    /// arrivals. `dropped` is indexed by arrival position, not global
    /// worker id. The fixed-`T^c` model has no phase structure, so its
    /// budgets lump to their total — same rule as the oracle.
    pub fn bounded_completion_at(
        &mut self,
        arrivals: &[f64],
        offsets: &[f64],
        dropped: &mut Vec<bool>,
    ) -> PhaseBounded {
        let k = arrivals.len();
        if k == 0 {
            dropped.clear();
            return PhaseBounded::Complete(0.0);
        }
        if let CommModel::Fixed(tc) = self.model {
            // lumped membership rule on raw arrivals (the oracle's
            // fixed-model arm, bit for bit): one cutoff at the last
            // cumulative offset
            dropped.clear();
            dropped.resize(k, false);
            let Some(&total) = offsets.last() else {
                let start =
                    arrivals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                return PhaseBounded::Complete(start + tc);
            };
            let first =
                arrivals.iter().cloned().fold(f64::INFINITY, f64::min);
            let cutoff = first + total;
            let mut survivors = k;
            for (j, &a) in arrivals.iter().enumerate() {
                if a > cutoff {
                    dropped[j] = true;
                    survivors -= 1;
                }
            }
            if survivors == k {
                let start =
                    arrivals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                return PhaseBounded::Complete(start + tc);
            }
            return PhaseBounded::Dropped {
                survivors,
                close: cutoff,
                checkpoint: offsets.len() - 1,
            };
        }
        self.ensure_slot(k);
        // lint:allow(hotpath-panic): ensure_slot(k) filled this slot on the line above
        let slot = self.slots[k].as_mut().expect("slot just ensured");
        slot.compiled.bounded_completion_with(
            arrivals,
            offsets,
            &mut slot.scratch,
            dropped,
        )
    }

    /// The k-survivor collective starting at `close`, *re-checked*
    /// against the (rebased) remaining per-phase budget offsets — the
    /// compiled arm of the recursive restart semantics
    /// ([`crate::policy::rebased_offsets`]). Same memoized per-k
    /// schedule and scratch as [`Self::completion`]; with no drops the
    /// returned `Complete` value is bitwise [`Self::completion`]'s
    /// (checkpoint comparisons never perturb the readiness pass).
    /// `dropped` is the caller's reusable sub-mask (index = survivor
    /// position, not global worker id).
    pub fn bounded_completion(
        &mut self,
        k: usize,
        close: f64,
        offsets: &[f64],
        dropped: &mut Vec<bool>,
    ) -> PhaseBounded {
        if k == 0 {
            dropped.clear();
            return PhaseBounded::Complete(0.0);
        }
        if let CommModel::Fixed(tc) = self.model {
            // no phase structure: equal arrivals survive every cumulative
            // cutoff (cutoff = close + offset >= close), so the re-check
            // can never drop — same as the unchecked completion
            dropped.clear();
            dropped.resize(k, false);
            return PhaseBounded::Complete(close + tc);
        }
        self.ensure_slot(k);
        // lint:allow(hotpath-panic): ensure_slot(k) filled this slot on the line above
        let slot = self.slots[k].as_mut().expect("slot just ensured");
        self.arrivals.clear();
        self.arrivals.resize(k, close);
        slot.compiled.bounded_completion_with(
            &self.arrivals,
            offsets,
            &mut slot.scratch,
            dropped,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologyKind;

    #[test]
    fn fixed_model_adds_tc_at_close() {
        let mut cache = SurvivorScheduleCache::new(&CommModel::Fixed(0.5));
        let (_, want) = CommModel::Fixed(0.5)
            .bounded_wait_completion(&[0.0, 0.1, 9.0], 1.0);
        assert_eq!(cache.completion(2, 1.0).to_bits(), want.to_bits());
        assert_eq!(cache.compiled_count(), 0, "fixed model compiles nothing");
        assert_eq!(cache.completion(0, 3.0), 0.0);
    }

    #[test]
    fn memoizes_one_compile_per_k() {
        let model = CommModel::Topology {
            kind: TopologyKind::Torus { rows: 0 },
            latency: 1e-4,
            bandwidth: 1e9,
            bytes: 4e6,
        };
        let mut cache = SurvivorScheduleCache::new(&model);
        let a = cache.completion(5, 0.7);
        assert_eq!(cache.compiled_count(), 1);
        let b = cache.completion(5, 0.7);
        assert_eq!(cache.compiled_count(), 1, "same k must not recompile");
        assert_eq!(a.to_bits(), b.to_bits());
        cache.completion(3, 0.7);
        assert_eq!(cache.compiled_count(), 2);
        cache.completion(1, 0.7);
        assert_eq!(cache.compiled_count(), 3);
    }

    #[test]
    fn matches_oracle_exclusion_branch() {
        // the cache against bounded_wait_completion's exclusion arm on a
        // concrete case per topology (the randomized sweep lives in
        // tests/perf_equivalence.rs)
        for kind in TopologyKind::ALL {
            let model = CommModel::Topology {
                kind,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            };
            let mut cache = SurvivorScheduleCache::new(&model);
            let arrivals = [0.2, 0.05, 7.0, 0.3, 50.0, 0.1];
            for deadline in [0.0, 0.5, 2.0] {
                let (mask, want) =
                    model.bounded_wait_completion(&arrivals, deadline);
                let k = mask.iter().filter(|&&s| s).count();
                assert!(k < arrivals.len(), "exclusion case");
                let close = crate::sim::comm::bounded_wait_cutoff(
                    &arrivals, deadline,
                );
                let got = cache.completion(k, close);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{} deadline={deadline} k={k}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn bounded_completion_matches_unchecked_when_budgets_are_loose() {
        // the re-checked form with budgets nobody can miss must return
        // Complete with exactly the unchecked completion's bits, reuse
        // the same memoized slots, and drop no one
        use crate::sim::compiled::PhaseBounded;
        for kind in TopologyKind::ALL {
            let model = CommModel::Topology {
                kind,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            };
            let mut cache = SurvivorScheduleCache::new(&model);
            let mut dropped = Vec::new();
            for k in [1usize, 3, 5] {
                let want = cache.completion(k, 0.7);
                let compiles = cache.compiled_count();
                let got = cache.bounded_completion(
                    k,
                    0.7,
                    &[1e6, 2e6],
                    &mut dropped,
                );
                assert_eq!(
                    got,
                    PhaseBounded::Complete(want),
                    "{} k={k}",
                    kind.name()
                );
                assert!(dropped.iter().all(|&d| !d));
                assert_eq!(
                    cache.compiled_count(),
                    compiles,
                    "re-check must reuse the slot"
                );
            }
            // k = 0 completes instantly, like the unchecked form
            assert_eq!(
                cache.bounded_completion(0, 3.0, &[1.0], &mut dropped),
                PhaseBounded::Complete(0.0)
            );
        }
        // fixed model: equal arrivals can never miss a cumulative cutoff
        let mut fixed = SurvivorScheduleCache::new(&CommModel::Fixed(0.5));
        let mut dropped = Vec::new();
        assert_eq!(
            fixed.bounded_completion(3, 1.0, &[0.0], &mut dropped),
            PhaseBounded::Complete(1.5)
        );
    }

    #[test]
    fn completion_at_matches_oracle_over_heterogeneous_arrivals() {
        // the fault path's plain collective: live workers keep their
        // own arrivals; the per-k compiled pass must be bitwise the
        // event-queue oracle's completion_time over the same k
        for kind in TopologyKind::ALL {
            let model = CommModel::Topology {
                kind,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            };
            let mut cache = SurvivorScheduleCache::new(&model);
            for arrivals in [
                &[0.3][..],
                &[0.3, 0.1][..],
                &[0.3, 0.1, 0.7, 0.2, 0.5][..],
            ] {
                let want = model.completion_time(arrivals);
                let got = cache.completion_at(arrivals);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{} k={}",
                    kind.name(),
                    arrivals.len()
                );
            }
            // the homogeneous form is the special case
            let close = 0.7;
            assert_eq!(
                cache.completion_at(&[close; 3]).to_bits(),
                cache.completion(3, close).to_bits(),
                "{}",
                kind.name()
            );
        }
        // fixed model and degenerates
        let mut fixed = SurvivorScheduleCache::new(&CommModel::Fixed(0.5));
        assert_eq!(fixed.completion_at(&[1.0, 3.0, 2.0]), 3.5);
        assert_eq!(fixed.completion_at(&[]), 0.0);
    }

    #[test]
    fn bounded_completion_at_matches_per_phase_oracle() {
        use crate::sim::compiled::PhaseBounded;
        for kind in TopologyKind::ALL {
            let model = CommModel::Topology {
                kind,
                latency: 1e-4,
                bandwidth: 1e9,
                bytes: 4e6,
            };
            let mut cache = SurvivorScheduleCache::new(&model);
            let mut dropped = Vec::new();
            let arrivals = [0.3, 0.1, 7.0, 0.2, 0.5];
            for deadline in [0.0, 1.0, 100.0] {
                let offsets = crate::policy::cumulative_offsets(&[deadline]);
                let (want_mask, want_t) = model
                    .per_phase_bounded_completion(&arrivals, &offsets, None);
                let res = cache.bounded_completion_at(
                    &arrivals, &offsets, &mut dropped,
                );
                match res {
                    PhaseBounded::Complete(t) => {
                        assert!(want_mask.iter().all(|&a| a));
                        assert_eq!(
                            t.to_bits(),
                            want_t.to_bits(),
                            "{} d={deadline}",
                            kind.name()
                        );
                    }
                    PhaseBounded::Dropped { survivors, close, .. } => {
                        for (j, &d) in dropped.iter().enumerate() {
                            assert_eq!(
                                d, !want_mask[j],
                                "{} d={deadline} pos {j}",
                                kind.name()
                            );
                        }
                        // the single-budget restart is the step-level
                        // rule: survivors start at the window close
                        let t = cache.completion(survivors, close);
                        assert_eq!(
                            t.to_bits(),
                            want_t.to_bits(),
                            "{} d={deadline}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bounded_completion_at_fixed_model_lumps_budgets() {
        use crate::sim::compiled::PhaseBounded;
        let model = CommModel::Fixed(0.5);
        let mut cache = SurvivorScheduleCache::new(&model);
        let mut dropped = Vec::new();
        let arrivals = [0.3, 0.1, 7.0, 0.2];
        // lumped cutoff at the last cumulative offset: first + 1.0
        let res =
            cache.bounded_completion_at(&arrivals, &[0.4, 1.0], &mut dropped);
        let PhaseBounded::Dropped { survivors, close, checkpoint } = res else {
            panic!("the 7.0 arrival must miss the lumped cutoff: {res:?}");
        };
        assert_eq!(survivors, 3);
        assert_eq!(checkpoint, 1, "attributed to the closing checkpoint");
        assert_eq!(dropped, vec![false, false, true, false]);
        // restart at the close is the oracle's exclusion arm, bit for bit
        let (_, want) = model.bounded_wait_completion(&arrivals, 1.0);
        assert_eq!(
            cache.completion(survivors, close).to_bits(),
            want.to_bits()
        );
        // loose budgets: everyone survives, plain fixed-model timing
        let res =
            cache.bounded_completion_at(&arrivals, &[100.0], &mut dropped);
        assert_eq!(res, PhaseBounded::Complete(7.5));
        assert!(dropped.iter().all(|&d| !d));
        // no offsets at all is the unconstrained collective
        let res = cache.bounded_completion_at(&arrivals, &[], &mut dropped);
        assert_eq!(res, PhaseBounded::Complete(7.5));
        // and the empty reduction completes instantly
        let res = cache.bounded_completion_at(&[], &[1.0], &mut dropped);
        assert_eq!(res, PhaseBounded::Complete(0.0));
    }

    #[test]
    fn single_survivor_completes_at_close() {
        // k = 1: an empty schedule — completion is the (clamped) start
        let model = CommModel::Ring {
            latency: 1e-3,
            bandwidth: 1e9,
            bytes: 1e6,
        };
        let mut cache = SurvivorScheduleCache::new(&model);
        assert_eq!(cache.completion(1, 2.5), 2.5);
        // negative close clamps like the event path's arrival clamp
        assert_eq!(cache.completion(1, -1.0), 0.0);
    }
}
