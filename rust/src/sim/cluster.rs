//! Virtual-time cluster simulator: the timing semantics of synchronous
//! training, DropCompute (Algorithm 1) and Local-SGD, over any
//! [`LatencyModel`] and [`CommModel`].
//!
//! This mirrors the paper's own methodology: runtime results (Figs 1, 2,
//! 4, 6, 13, 14) are driven by injected latency distributions; the
//! *training semantics* (which micro-batches survive) feed the real
//! trainer via [`StepOutcome::completed`].

use crate::config::ClusterConfig;
use crate::obs::{DropCause, NoopObserver, SimObserver};
use crate::policy::DropPolicy;
use crate::rng::Xoshiro256pp;
use crate::util::{Error, Result};

use super::comm::CommModel;
use super::compiled::{CompiledSchedule, PhaseBounded};
use super::noise::LatencyModel;
use super::trace::{
    StepTrace, Trace, TraceComm, TraceMeta, TraceMode, TraceRecord,
    TraceWriter, TRACE_FORMAT_VERSION,
};

/// When a worker notices its compute budget `tau` is exhausted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptionMode {
    /// Theory model: worker stops exactly at `tau`
    /// (`T~_n = min(tau, T_n)`; micro-batch m survives iff `T_n^(m) < tau`).
    Preemptive,
    /// Reference-implementation model (paper §6 Limitations): the timeout
    /// is checked between accumulations, so the crossing micro-batch
    /// finishes and counts.
    BetweenAccumulations,
}

/// Timing outcome of one synchronous step.
///
/// Reusable: hot loops keep one value and refill it through
/// [`ClusterSim::step_into`], which recycles the per-worker vectors
/// instead of allocating fresh ones every step.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Compute time per worker (`T~_n`).
    pub worker_compute: Vec<f64>,
    /// Micro-batches completed per worker (`M~_n`).
    pub completed: Vec<usize>,
    /// Max-over-workers compute time (`min(tau, T)` under DropCompute).
    pub compute_time: f64,
    /// Full iteration time including communication.
    pub iter_time: f64,
}

impl StepOutcome {
    pub fn total_completed(&self) -> usize {
        self.completed.iter().sum()
    }

    /// Fraction of scheduled micro-batches that were dropped. A
    /// zero-worker outcome (or `accums == 0`) schedules nothing, so
    /// nothing was dropped: 0.0, not NaN.
    pub fn drop_rate(&self, accums: usize) -> f64 {
        let scheduled = self.completed.len() * accums;
        if scheduled == 0 {
            return 0.0;
        }
        1.0 - self.total_completed() as f64 / scheduled as f64
    }
}

/// The simulated cluster.
pub struct ClusterSim {
    pub workers: usize,
    pub accums: usize,
    model: LatencyModel,
    comm: CommModel,
    pub preemption: PreemptionMode,
    /// The installed drop policy — the single source of truth for
    /// [`Self::step_with`] and friends. The legacy knobs below are its
    /// resolved form, precomputed at install time so stepping pays no
    /// per-step policy resolution.
    policy: DropPolicy,
    /// Resolved compute threshold of the installed policy
    /// ([`crate::policy::EffectivePolicy::tau`]).
    eff_tau: Option<f64>,
    /// Resolved Local-SGD period of the installed policy.
    eff_h: Option<usize>,
    /// Bounded-wait (DropComm) deadline: workers arriving later than
    /// this after the first arrival are excluded from the reduction
    /// (their step contribution is dropped and the sum reweighted over
    /// the survivors). `None` = wait for everyone.
    comm_drop: Option<f64>,
    /// Cumulative per-phase membership cutoff offsets
    /// ([`crate::policy::cumulative_offsets`], with any step deadline
    /// folded into the entry checkpoint). Empty = no per-phase policy.
    phase_cutoffs: Vec<f64>,
    /// Reusable per-worker dropped mask for the per-phase scan.
    drop_mask: Vec<bool>,
    /// Full-cluster schedule, built once (the worker count is fixed
    /// for a sim's lifetime) so the per-step timing doesn't rebuild
    /// O(N^2) transfers. `None` for the fixed-`T^c` model. Kept as the
    /// event-queue reference oracle behind
    /// [`Self::with_reference_timing`].
    schedule: Option<crate::topology::Schedule>,
    /// The schedule lowered to the heapless fast path
    /// ([`super::compiled::CompiledSchedule`]): flat src/dst/hop arrays,
    /// hop costs precomputed at construction.
    compiled: Option<super::compiled::CompiledSchedule>,
    /// Reusable timing buffers so steady-state stepping is
    /// allocation-free.
    scratch: super::compiled::ScheduleScratch,
    /// Per-survivor-count compiled schedules for the DropComm exclusion
    /// branch ([`super::survivor::SurvivorScheduleCache`]): after
    /// warmup a drop step allocates nothing and builds no schedule.
    survivors: super::survivor::SurvivorScheduleCache,
    /// `false` routes collective timing through the event-queue
    /// reference instead of the compiled fast path (perf baselines and
    /// the bitwise-equality property tests).
    use_compiled: bool,
    /// Independent RNG stream per worker (decentralized by construction).
    streams: Vec<Xoshiro256pp>,
    /// Reusable micro-batch sample buffer: each worker's accumulation
    /// run is drawn into it in one batched call.
    sample_buf: Vec<f64>,
    /// Monotone step counter (drives step-indexed failures).
    step_idx: usize,
    /// Recursive survivor-restart semantics (the default): a restarted
    /// per-phase collective is re-checked against the budgets remaining
    /// after its trigger ([`crate::policy::rebased_offsets`]),
    /// recursively. [`Self::with_single_restart`] restores the legacy
    /// unchecked restart.
    recursive_restart: bool,
    /// Reusable survivor-index map for the recursive drop path
    /// (sub-scan position -> global worker id).
    alive_buf: Vec<usize>,
    /// Reusable rebased-offsets buffer for the recursive drop path.
    rebase_buf: Vec<f64>,
    /// Installed fault plan (the scenario lab): scripted fail / rejoin /
    /// slow / drift events varying live membership and per-worker
    /// latency scale between steps. `None` keeps every step on the
    /// exact pre-scenario code path.
    fault: Option<super::fault::FaultPlan>,
    /// Per-worker base latency scales captured at plan install time:
    /// the plan's slow/drift multipliers compose on top of these.
    fault_base_scale: Vec<f64>,
    /// Reusable live-position -> global worker id map for faulted steps.
    live_ids: Vec<usize>,
    /// Reusable compacted live-arrival buffer for faulted steps (a dead
    /// worker's 0.0 "arrival" must never reach collective timing).
    live_arrivals: Vec<f64>,
    /// Root seed (stamped into recorded trace metadata).
    seed: u64,
    /// Active trace recording ([`Self::start_recording`]), if any.
    writer: Option<TraceWriter>,
    /// Replay timing source ([`Self::with_replay`]): when set, worker
    /// compute comes from the recorded trace instead of the latency
    /// model — the comm side stays the sim's own deterministic timing.
    replay: Option<ReplayState>,
}

/// Cursor over a recorded trace's steps (the replay `TimingSource`).
struct ReplayState {
    steps: Vec<StepTrace>,
    mode: TraceMode,
    pos: usize,
}

impl ClusterSim {
    pub fn new(cfg: &ClusterConfig, seed: u64) -> Self {
        let comm = match cfg.topology {
            Some(kind) => CommModel::Topology {
                kind,
                latency: cfg.link_latency,
                bandwidth: cfg.link_bandwidth,
                bytes: cfg.grad_bytes,
            },
            None => CommModel::Fixed(cfg.comm_latency),
        };
        let sim = Self::with_model(
            cfg.workers,
            cfg.accumulations,
            LatencyModel::from_config(cfg),
            comm,
            seed,
        )
        .with_policy(DropPolicy::from_cluster(cfg));
        if cfg.single_restart {
            sim.with_single_restart()
        } else {
            sim
        }
    }

    pub fn with_model(
        workers: usize,
        accums: usize,
        model: LatencyModel,
        comm: CommModel,
        seed: u64,
    ) -> Self {
        let root = Xoshiro256pp::seed_from_u64(seed);
        let streams = (0..workers).map(|n| root.split(n as u64)).collect();
        let schedule = comm.schedule_for(workers);
        // compile from the schedule just built rather than rebuilding
        // O(N^2) transfers inside compile_for — sweeps construct one
        // sim per grid point, so this fixed cost is paid per point
        let compiled = match (&schedule, comm.link_params()) {
            (Some(s), Some((latency, bandwidth, bytes))) => {
                Some(super::compiled::CompiledSchedule::compile(
                    s, latency, bandwidth, bytes,
                ))
            }
            _ => None,
        };
        let survivors = super::survivor::SurvivorScheduleCache::new(&comm);
        Self {
            workers,
            accums,
            model,
            comm,
            preemption: PreemptionMode::Preemptive,
            policy: DropPolicy::None,
            eff_tau: None,
            eff_h: None,
            comm_drop: None,
            phase_cutoffs: Vec::new(),
            drop_mask: Vec::new(),
            schedule,
            compiled,
            scratch: super::compiled::ScheduleScratch::default(),
            survivors,
            use_compiled: true,
            streams,
            sample_buf: Vec::new(),
            step_idx: 0,
            recursive_restart: true,
            alive_buf: Vec::new(),
            rebase_buf: Vec::new(),
            fault: None,
            fault_base_scale: Vec::new(),
            live_ids: Vec::new(),
            live_arrivals: Vec::new(),
            seed,
            writer: None,
            replay: None,
        }
    }

    pub fn with_preemption(mut self, mode: PreemptionMode) -> Self {
        self.preemption = mode;
        self
    }

    /// Install a [`DropPolicy`]: the unified drop-decision surface.
    /// Resolves the policy once (compute threshold, preemption model,
    /// step-level deadline, cumulative per-phase cutoffs, Local-SGD
    /// period) so [`Self::step_installed_into`] pays nothing per step.
    pub fn with_policy(mut self, policy: DropPolicy) -> Self {
        self.set_policy(&policy);
        self
    }

    /// [`Self::with_policy`] in place.
    pub fn set_policy(&mut self, policy: &DropPolicy) {
        if let Some(w) = self.writer.as_mut() {
            if *policy != self.policy {
                // a mid-recording policy swap would make the recorded
                // metadata lie about what the steps ran under
                w.mark_policy_changed();
            }
        }
        let eff = policy.effective();
        self.eff_tau = eff.tau;
        if eff.tau.is_some() {
            // a policy without a compute clause leaves the (builder-set)
            // preemption mode alone
            self.preemption = eff.preemption;
        }
        self.eff_h = eff.local_sgd_h;
        self.phase_cutoffs = eff.merged_phase_offsets();
        // a per-phase policy subsumes the step deadline (folded into
        // its entry checkpoint by merged_phase_offsets)
        self.comm_drop = if self.phase_cutoffs.is_empty() {
            eff.step_deadline
        } else {
            None
        };
        self.policy = policy.clone();
    }

    /// The installed policy.
    pub fn policy(&self) -> &DropPolicy {
        &self.policy
    }

    /// Route collective timing through the per-phase event-queue
    /// reference instead of the compiled heapless pass. The two are
    /// bitwise identical (property-tested); this exists as the oracle
    /// for those tests and as the "before" arm of perf benchmarks.
    pub fn with_reference_timing(mut self) -> Self {
        self.use_compiled = false;
        self
    }

    /// Restore the legacy *single-restart* per-phase semantics: a
    /// restarted survivor collective is timed unchecked, ignoring the
    /// budgets after the triggering checkpoint. The default (recursive)
    /// semantics re-check the restart against the rebased remaining
    /// budgets — see [`CommModel::per_phase_bounded_completion_recursive`]
    /// — which only differs when checkpoints follow the trigger, so a
    /// single lumped budget behaves identically under both.
    pub fn with_single_restart(mut self) -> Self {
        self.recursive_restart = false;
        self
    }

    /// Install a [`super::fault::FaultPlan`] (the scenario lab). Dead
    /// workers compute nothing, consume no random draws — per-worker
    /// streams keep every survivor's draws bitwise those of an
    /// undisturbed run — and take no seat in the collective, which
    /// reduces over the live sub-cluster through the per-k
    /// [`super::survivor::SurvivorScheduleCache`]; a rejoin restores
    /// the full-membership fast path. Slow and drift events rescale
    /// the worker's base latency per step through the same seam Fig 6's
    /// static heterogeneity uses. An empty plan is a no-op install.
    pub fn with_fault_plan(mut self, plan: super::fault::FaultPlan) -> Self {
        self.fault_base_scale =
            (0..self.workers).map(|n| self.model.worker_scale(n)).collect();
        self.fault = if plan.is_empty() { None } else { Some(plan) };
        self
    }

    /// The installed fault plan, if any.
    pub fn fault_plan(&self) -> Option<&super::fault::FaultPlan> {
        self.fault.as_ref()
    }

    /// Whether worker `n` is dead at `step_idx` under the installed
    /// fault plan (always live without one).
    #[inline]
    fn worker_dead(&self, n: usize, step_idx: usize) -> bool {
        match &self.fault {
            Some(plan) => !plan.alive(n, step_idx as u64),
            None => false,
        }
    }

    /// Whether the installed fault plan kills anyone at `step_idx` —
    /// the gate between [`Self::finish_into`] (full membership, the
    /// exact pre-scenario path) and [`Self::finish_faulted`].
    #[inline]
    fn any_worker_dead(&self, step_idx: usize) -> bool {
        match &self.fault {
            Some(plan) => plan.any_dead(self.workers, step_idx as u64),
            None => false,
        }
    }

    /// Apply the plan's per-step latency scaling (slow windows, drift)
    /// on top of the install-time base scales. An event scale of
    /// exactly 1.0 writes back exactly the base scale, so inert steps
    /// stay bitwise identical to an unscaled run; plans without
    /// scaling events skip the loop entirely.
    fn apply_fault_scaling(&mut self, step_idx: usize) {
        let Some(plan) = &self.fault else { return };
        if !plan.has_scaling() {
            return;
        }
        for n in 0..self.workers {
            let s = self.fault_base_scale[n] * plan.scale(n, step_idx as u64);
            self.model.set_worker_scale(n, s);
        }
    }

    /// Enable/disable the step-level bounded-wait (DropComm)
    /// collective. Legacy shim for [`Self::with_policy`] with a
    /// [`DropPolicy::CommDeadline`]; replaces the installed policy's
    /// clauses (per-phase cutoffs, compute and Local-SGD included) so
    /// the installed state stays internally consistent. The
    /// builder-level preemption mode is preserved, as it always was —
    /// it only matters with a per-call `step(Some(tau))` threshold.
    pub fn with_comm_drop(mut self, deadline: Option<f64>) -> Self {
        let policy = match deadline {
            Some(d) => DropPolicy::comm_deadline(d),
            None => DropPolicy::None,
        };
        self.set_policy(&policy);
        self
    }

    /// Adopt a warm survivor-schedule cache (e.g. from a sweep's
    /// [`crate::sweep::SurvivorCachePool`]). A cache built for a
    /// different comm model is discarded — memoization must never
    /// change results, only skip compiles.
    pub fn with_survivor_cache(
        mut self,
        cache: super::survivor::SurvivorScheduleCache,
    ) -> Self {
        if cache.matches(&self.comm) {
            self.survivors = cache;
        }
        self
    }

    /// Hand the survivor cache back (for pooling across sims sharing a
    /// comm model), leaving a fresh empty one behind.
    pub fn take_survivor_cache(&mut self) -> super::survivor::SurvivorScheduleCache {
        std::mem::replace(
            &mut self.survivors,
            super::survivor::SurvivorScheduleCache::new(&self.comm),
        )
    }

    pub fn latency_model(&self) -> &LatencyModel {
        &self.model
    }

    pub fn comm_model(&self) -> &CommModel {
        &self.comm
    }

    /// Serial comm constant `T^c` for the analytical model.
    pub fn comm_latency(&self) -> f64 {
        self.comm.serial_latency(self.workers)
    }

    /// Full-cluster collective completion for `arrivals`: the compiled
    /// heapless pass when available, else the cached-schedule event
    /// reference, else the fixed-`T^c` model.
    ///
    /// The observer's [`SimObserver::on_phase`] hook fires after each
    /// phase of the compiled pass with the raw readiness slice (the
    /// event/fixed reference arms have no per-phase structure to
    /// report). With [`NoopObserver`] the closure is empty and the pass
    /// monomorphizes to exactly the unhooked loop.
    fn collective_time<O: SimObserver>(
        &mut self,
        arrivals: &[f64],
        obs: &mut O,
    ) -> f64 {
        if self.use_compiled {
            if let Some(c) = self.compiled.as_ref() {
                return c.completion_with_phases(
                    arrivals,
                    &mut self.scratch,
                    |p, ready| obs.on_phase(p, ready),
                );
            }
        }
        self.comm.completion_time_with(arrivals, self.schedule.as_ref())
    }

    /// Common tail of a simulated step: the collective. Under a
    /// comm-side drop policy late workers are excluded — their
    /// completed micro-batches are zeroed (dropped work) and the
    /// survivors' reduction sets the iteration time. Operates in place
    /// on `out`'s already-filled per-worker vectors. Emits the
    /// comm-side [`DropCause`] events and the closing
    /// [`SimObserver::on_step`].
    fn finish_into<O: SimObserver>(&mut self, out: &mut StepOutcome, obs: &mut O) {
        // max over an empty set folds to -inf; a zero-worker outcome
        // computes for zero seconds
        out.compute_time = if out.worker_compute.is_empty() {
            0.0
        } else {
            out.worker_compute
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max)
        };
        if !self.phase_cutoffs.is_empty() {
            out.iter_time = self.per_phase_iter_time(out, obs);
            obs.on_step(out);
            return;
        }
        out.iter_time = match self.comm_drop {
            None => self.collective_time(&out.worker_compute, obs),
            Some(deadline) => {
                // the shared membership rule, evaluated allocation-free
                // for the common no-drop case
                let cutoff = crate::sim::comm::bounded_wait_cutoff(
                    &out.worker_compute,
                    deadline,
                );
                if out.worker_compute.iter().all(|&a| a <= cutoff) {
                    // common path: nobody missed the deadline — plain
                    // collective over the compiled full-N schedule
                    self.collective_time(&out.worker_compute, obs)
                } else {
                    // drop path: zero the late workers' contributions
                    // and count the k survivors while at it
                    let mut k = 0usize;
                    for (n, (done, &a)) in out
                        .completed
                        .iter_mut()
                        .zip(&out.worker_compute)
                        .enumerate()
                    {
                        if a > cutoff {
                            *done = 0;
                            obs.on_drop(n, DropCause::StepDeadline);
                        } else {
                            k += 1;
                        }
                    }
                    if self.use_compiled {
                        // the k-survivor collective starts at the
                        // membership close (`cutoff`); memoized per k —
                        // no allocation, no schedule rebuild
                        self.survivors.completion(k, cutoff)
                    } else {
                        let (_, t) = self.comm.bounded_wait_completion(
                            &out.worker_compute,
                            deadline,
                        );
                        t
                    }
                }
            }
        };
        obs.on_step(out);
    }

    /// The per-phase-deadline collective: compiled scan
    /// ([`super::compiled::CompiledSchedule::bounded_completion_with`])
    /// when available, else the event-queue oracle / fixed-`T^c` lumped
    /// form ([`CommModel::per_phase_bounded_completion`]) — bitwise
    /// identical pair, property-tested. Zeroes dropped workers'
    /// completed counts; the survivors' restart reuses the per-k
    /// compiled cache, so drop-heavy per-phase stepping is as
    /// allocation-free as the step-level drop path.
    ///
    /// Restart semantics: by default a restarted survivor collective is
    /// *re-checked* against the budgets remaining after its trigger
    /// (rebased to the restart instant), recursively — the compiled arm
    /// of [`CommModel::per_phase_bounded_completion_recursive`], bitwise
    /// identical to it. [`Self::with_single_restart`] restores the old
    /// unchecked restart.
    ///
    /// Drop attribution: per-phase drop events report the scan's
    /// *closing* checkpoint (one scan can merge drops from several
    /// checkpoints; the last — triggering — one is reported). The
    /// event-queue oracle arm only produces a merged mask, so it
    /// reports `checkpoint: 0`.
    fn per_phase_iter_time<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        obs: &mut O,
    ) -> f64 {
        if self.use_compiled {
            if let Some(c) = self.compiled.as_ref() {
                let res = c.bounded_completion_with(
                    &out.worker_compute,
                    &self.phase_cutoffs,
                    &mut self.scratch,
                    &mut self.drop_mask,
                );
                return match res {
                    PhaseBounded::Complete(t) => t,
                    PhaseBounded::Dropped { survivors, close, checkpoint } => {
                        for (n, (done, &d)) in out
                            .completed
                            .iter_mut()
                            .zip(&self.drop_mask)
                            .enumerate()
                        {
                            if d {
                                *done = 0;
                                obs.on_drop(
                                    n,
                                    DropCause::PhaseCheckpoint { checkpoint },
                                );
                            }
                        }
                        if survivors == 0 {
                            close.max(0.0)
                        } else {
                            // budgets remaining after the trigger,
                            // rebased to the restart instant — the same
                            // subtraction the oracle's rebased_offsets
                            // performs, bit for bit
                            self.rebase_buf.clear();
                            self.rebase_buf
                                .extend_from_slice(&self.phase_cutoffs);
                            crate::policy::rebase_offsets_in_place(
                                &mut self.rebase_buf,
                                checkpoint,
                            );
                            if !self.recursive_restart
                                || self.rebase_buf.is_empty()
                            {
                                self.survivors.completion(survivors, close)
                            } else {
                                self.recursive_survivor_time(
                                    out, survivors, close, obs,
                                )
                            }
                        }
                    }
                };
            }
        }
        // event-queue reference timing, or the fixed-T^c model (which
        // has no phase structure — budgets lump to their total and
        // nothing remains to re-check)
        let (mask, t) = if self.recursive_restart {
            self.comm.per_phase_bounded_completion_recursive(
                &out.worker_compute,
                &self.phase_cutoffs,
                self.schedule.as_ref(),
            )
        } else {
            self.comm.per_phase_bounded_completion(
                &out.worker_compute,
                &self.phase_cutoffs,
                self.schedule.as_ref(),
            )
        };
        for (n, (done, &alive)) in
            out.completed.iter_mut().zip(&mask).enumerate()
        {
            if !alive {
                *done = 0;
                // the oracle reports a merged mask, not per-checkpoint
                // structure — coarse attribution (checkpoint 0)
                obs.on_drop(n, DropCause::PhaseCheckpoint { checkpoint: 0 });
            }
        }
        t
    }

    /// The recursive restart loop of the compiled per-phase path:
    /// survivors restart at `close` and are re-checked against
    /// `self.rebase_buf` (the already-rebased remaining offsets), each
    /// further drop rebasing again — through the per-k compiled cache,
    /// with reusable index/offset buffers so even deep recursion
    /// allocates nothing in steady state. Structurally identical to the
    /// oracle loop in
    /// [`CommModel::per_phase_bounded_completion_recursive`] (bitwise
    /// pair, property-tested in `tests/policy_equivalence.rs`).
    fn recursive_survivor_time<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        k: usize,
        close: f64,
        obs: &mut O,
    ) -> f64 {
        // sub-scan position -> global worker id, from the level-0 mask
        self.alive_buf.clear();
        for (w, &d) in self.drop_mask.iter().enumerate() {
            if !d {
                self.alive_buf.push(w);
            }
        }
        debug_assert_eq!(self.alive_buf.len(), k);
        self.recursive_restart_rounds(out, k, close, obs)
    }

    /// [`Self::recursive_survivor_time`] for a *faulted* step: the
    /// level-0 drop mask is indexed by live position, so the survivor
    /// map routes through `self.live_ids` instead of global worker ids.
    fn recursive_survivor_time_mapped<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        k: usize,
        close: f64,
        obs: &mut O,
    ) -> f64 {
        self.alive_buf.clear();
        for (j, &d) in self.drop_mask.iter().enumerate() {
            if !d {
                self.alive_buf.push(self.live_ids[j]);
            }
        }
        debug_assert_eq!(self.alive_buf.len(), k);
        self.recursive_restart_rounds(out, k, close, obs)
    }

    /// The shared restart loop of both recursive drop paths:
    /// `self.alive_buf` maps sub-scan positions to global worker ids,
    /// `self.rebase_buf` holds the already-rebased remaining offsets.
    fn recursive_restart_rounds<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        mut k: usize,
        mut close: f64,
        obs: &mut O,
    ) -> f64 {
        loop {
            let res = self.survivors.bounded_completion(
                k,
                close,
                &self.rebase_buf,
                &mut self.drop_mask,
            );
            match res {
                PhaseBounded::Complete(t) => return t,
                PhaseBounded::Dropped { survivors, close: c2, checkpoint } => {
                    // zero the newly dropped and compact the alive map
                    let mut w = 0usize;
                    for j in 0..k {
                        let worker = self.alive_buf[j];
                        if self.drop_mask[j] {
                            out.completed[worker] = 0;
                            obs.on_drop(
                                worker,
                                DropCause::SurvivorRestart { checkpoint },
                            );
                        } else {
                            self.alive_buf[w] = worker;
                            w += 1;
                        }
                    }
                    self.alive_buf.truncate(w);
                    if survivors == 0 {
                        return c2.max(0.0);
                    }
                    crate::policy::rebase_offsets_in_place(
                        &mut self.rebase_buf,
                        checkpoint,
                    );
                    if self.rebase_buf.is_empty() {
                        return self.survivors.completion(survivors, c2);
                    }
                    k = survivors;
                    close = c2;
                }
            }
        }
    }

    /// [`Self::finish_into`] for a step where the installed fault plan
    /// killed at least one worker. The dead seats are compacted out
    /// *before* any collective timing — a dead worker's 0.0 "arrival"
    /// would otherwise drag first-arrival cutoffs to zero — and the
    /// installed policy's comm-side rules run over the live
    /// sub-cluster: its k-member collective comes from the per-k
    /// survivor cache (compiled path) or a freshly built k-schedule
    /// (event-queue oracle), bitwise pair as everywhere else.
    /// Degenerates are well-defined: zero live workers complete
    /// instantly with the step's (zero) compute, one live worker
    /// reduces as a 1-member collective.
    fn finish_faulted<O: SimObserver>(
        &mut self,
        step_idx: usize,
        out: &mut StepOutcome,
        obs: &mut O,
    ) {
        out.compute_time = if out.worker_compute.is_empty() {
            0.0
        } else {
            out.worker_compute
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max)
        };
        // compact the live seats: position -> global id, plus arrivals
        self.live_ids.clear();
        self.live_arrivals.clear();
        if let Some(plan) = &self.fault {
            for n in 0..self.workers {
                if plan.alive(n, step_idx as u64) {
                    self.live_ids.push(n);
                    self.live_arrivals.push(out.worker_compute[n]);
                }
            }
        }
        if self.live_ids.is_empty() {
            // every worker is dead: nothing to reduce, nothing computed
            out.iter_time = out.compute_time;
            obs.on_step(out);
            return;
        }
        if !self.phase_cutoffs.is_empty() {
            out.iter_time = self.per_phase_faulted_time(out, obs);
            obs.on_step(out);
            return;
        }
        out.iter_time = match self.comm_drop {
            None => {
                if self.use_compiled {
                    self.survivors.completion_at(&self.live_arrivals)
                } else {
                    // the cached full-N schedule cannot time the live
                    // sub-cluster; the oracle builds the k-schedule
                    self.comm.completion_time_with(&self.live_arrivals, None)
                }
            }
            Some(deadline) => {
                // the DropComm membership rule over the live arrivals
                let cutoff = crate::sim::comm::bounded_wait_cutoff(
                    &self.live_arrivals,
                    deadline,
                );
                if self.live_arrivals.iter().all(|&a| a <= cutoff) {
                    if self.use_compiled {
                        self.survivors.completion_at(&self.live_arrivals)
                    } else {
                        self.comm
                            .completion_time_with(&self.live_arrivals, None)
                    }
                } else {
                    let mut k = 0usize;
                    for (j, &a) in self.live_arrivals.iter().enumerate() {
                        if a > cutoff {
                            out.completed[self.live_ids[j]] = 0;
                            obs.on_drop(
                                self.live_ids[j],
                                DropCause::StepDeadline,
                            );
                        } else {
                            k += 1;
                        }
                    }
                    if self.use_compiled {
                        self.survivors.completion(k, cutoff)
                    } else {
                        let (_, t) = self.comm.bounded_wait_completion(
                            &self.live_arrivals,
                            deadline,
                        );
                        t
                    }
                }
            }
        };
        obs.on_step(out);
    }

    /// The per-phase-deadline collective over the live sub-cluster of a
    /// faulted step — [`Self::per_phase_iter_time`] with the dead seats
    /// compacted out. The compiled arm runs the k-live schedule from
    /// the per-k survivor cache (the full-N compiled schedule cannot
    /// time a sub-cluster); drop events map back to global worker ids
    /// through `self.live_ids`.
    fn per_phase_faulted_time<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        obs: &mut O,
    ) -> f64 {
        let k = self.live_ids.len();
        if self.use_compiled {
            let res = self.survivors.bounded_completion_at(
                &self.live_arrivals,
                &self.phase_cutoffs,
                &mut self.drop_mask,
            );
            return match res {
                PhaseBounded::Complete(t) => t,
                PhaseBounded::Dropped { survivors, close, checkpoint } => {
                    for j in 0..k {
                        if self.drop_mask[j] {
                            out.completed[self.live_ids[j]] = 0;
                            obs.on_drop(
                                self.live_ids[j],
                                DropCause::PhaseCheckpoint { checkpoint },
                            );
                        }
                    }
                    if survivors == 0 {
                        close.max(0.0)
                    } else {
                        self.rebase_buf.clear();
                        self.rebase_buf
                            .extend_from_slice(&self.phase_cutoffs);
                        crate::policy::rebase_offsets_in_place(
                            &mut self.rebase_buf,
                            checkpoint,
                        );
                        if !self.recursive_restart
                            || self.rebase_buf.is_empty()
                        {
                            self.survivors.completion(survivors, close)
                        } else {
                            self.recursive_survivor_time_mapped(
                                out, survivors, close, obs,
                            )
                        }
                    }
                }
            };
        }
        // event-queue reference / fixed-T^c arm over the live seats
        let (mask, t) = if self.recursive_restart {
            self.comm.per_phase_bounded_completion_recursive(
                &self.live_arrivals,
                &self.phase_cutoffs,
                None,
            )
        } else {
            self.comm.per_phase_bounded_completion(
                &self.live_arrivals,
                &self.phase_cutoffs,
                None,
            )
        };
        for (j, &alive) in mask.iter().enumerate() {
            if !alive {
                out.completed[self.live_ids[j]] = 0;
                // the oracle reports a merged mask — coarse attribution
                obs.on_drop(
                    self.live_ids[j],
                    DropCause::PhaseCheckpoint { checkpoint: 0 },
                );
            }
        }
        t
    }

    /// Simulate one step (or Local-SGD period, if the policy carries
    /// one) under `policy`, installing it first when it differs from
    /// the current one — a cheap equality check, so sweeps that step
    /// the same policy repeatedly pay nothing.
    pub fn step_with(&mut self, policy: &DropPolicy) -> StepOutcome {
        let mut out = StepOutcome::default();
        self.step_with_into(policy, &mut out);
        out
    }

    /// [`Self::step_with`] into a caller-owned outcome.
    pub fn step_with_into(
        &mut self,
        policy: &DropPolicy,
        out: &mut StepOutcome,
    ) {
        self.step_with_observed(policy, out, &mut NoopObserver);
    }

    /// [`Self::step_with_into`] with a [`SimObserver`] receiving the
    /// step's per-worker, per-phase and drop events.
    pub fn step_with_observed<O: SimObserver>(
        &mut self,
        policy: &DropPolicy,
        out: &mut StepOutcome,
        obs: &mut O,
    ) {
        if *policy != self.policy {
            self.set_policy(policy);
        }
        self.step_installed_observed(out, obs);
    }

    /// One step under the already-installed policy
    /// ([`Self::with_policy`]): a `LocalSgdPeriod` clause routes to
    /// [`Self::local_sgd_period_into`] (threshold per local step),
    /// anything else to [`Self::step_into`].
    pub fn step_installed_into(&mut self, out: &mut StepOutcome) {
        self.step_installed_observed(out, &mut NoopObserver);
    }

    /// [`Self::step_installed_into`] with a [`SimObserver`]. The
    /// [`NoopObserver`] monomorphization is exactly the un-instrumented
    /// step (bitwise and perf-identical — `tests/obs_equivalence.rs`,
    /// `obs_overhead` bench pair).
    pub fn step_installed_observed<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        obs: &mut O,
    ) {
        match self.eff_h {
            Some(h) => self.local_sgd_period_observed(h, self.eff_tau, out, obs),
            None => self.step_observed(self.eff_tau, out, obs),
        }
    }

    /// Simulate one synchronous step; `threshold = None` is the
    /// baseline. Legacy shim: the threshold rides per call while the
    /// comm side comes from the installed policy — new code should
    /// install a full [`DropPolicy`] and use [`Self::step_with`].
    pub fn step(&mut self, threshold: Option<f64>) -> StepOutcome {
        let mut out = StepOutcome::default();
        self.step_into(threshold, &mut out);
        out
    }

    /// [`Self::step`] into a caller-owned outcome, recycling its
    /// per-worker vectors — with a schedule-driven comm model the whole
    /// step is allocation-free in steady state.
    ///
    /// Each worker's accumulation run is drawn in one batched
    /// [`LatencyModel::fill_microbatches`] call (enum-dispatched once
    /// per run, not per draw), then scanned against the threshold. The
    /// bounded fill stops drawing exactly where the sequential
    /// preemption loop stopped, so per-worker streams — and therefore
    /// all seeded results — are bitwise identical to the un-batched
    /// code (property-tested in `tests/perf_equivalence.rs`).
    pub fn step_into(&mut self, threshold: Option<f64>, out: &mut StepOutcome) {
        self.step_observed(threshold, out, &mut NoopObserver);
    }

    /// [`Self::step_into`] with a [`SimObserver`]: per worker an
    /// [`SimObserver::on_worker`] event (plus a [`DropCause::Tau`]
    /// drop when the threshold trimmed micro-batches), then the
    /// collective's phase/drop events and the closing
    /// [`SimObserver::on_step`].
    pub fn step_observed<O: SimObserver>(
        &mut self,
        threshold: Option<f64>,
        out: &mut StepOutcome,
        obs: &mut O,
    ) {
        let step_idx = self.begin_step_observed(threshold, out, obs);
        self.finish_step_observed(step_idx, out, obs);
    }

    /// The compute side of one step: advance the step index, draw (or
    /// replay) every worker's straggle and micro-batch run, scan against
    /// the threshold, and fill `out`'s per-worker vectors. Returns the
    /// step index the collective must be finished under
    /// ([`Self::finish_step_observed`]). Split out so
    /// [`super::batch::ReplicaBatch`] can run the compute side of S
    /// replicas back to back, then time their collectives in one
    /// lane-parallel pass — recomposed verbatim by
    /// [`Self::step_observed`], so the scalar step is bitwise untouched.
    pub(crate) fn begin_step_observed<O: SimObserver>(
        &mut self,
        threshold: Option<f64>,
        out: &mut StepOutcome,
        obs: &mut O,
    ) -> usize {
        let step_idx = self.step_idx;
        self.step_idx += 1;
        self.apply_fault_scaling(step_idx);
        out.worker_compute.clear();
        out.completed.clear();
        out.worker_compute.reserve(self.workers);
        out.completed.reserve(self.workers);
        if let Some(r) = &self.replay {
            assert!(
                r.mode == TraceMode::Step,
                "replay source records Local-SGD periods, not synchronous \
                 steps (ClusterSim::replay_into reports this as a typed \
                 error)"
            );
            assert!(
                r.pos < r.steps.len(),
                "replay source exhausted after {} steps \
                 (ClusterSim::replay_into reports this as a typed error)",
                r.steps.len()
            );
        }
        if let Some(w) = self.writer.as_mut() {
            w.begin_step(TraceMode::Step, threshold == self.eff_tau);
        }
        for n in 0..self.workers {
            if self.worker_dead(n, step_idx) {
                // dead under the fault plan: no compute, no random
                // draws (the worker's stream simply does not advance,
                // so survivors' draws stay bitwise those of an
                // undisturbed run), and no seat in the collective —
                // finish_faulted compacts it out below
                self.sample_buf.clear();
                if let Some(w) = self.writer.as_mut() {
                    w.push_worker(0.0, &self.sample_buf);
                }
                out.worker_compute.push(0.0);
                out.completed.push(0);
                obs.on_worker(n, 0.0, 0);
                obs.on_drop(n, DropCause::WorkerFault);
                continue;
            }
            let straggle;
            if let Some(r) = &self.replay {
                // replay: the recorded draws stand in for the latency
                // model; the shared scan below then reproduces the
                // recorded run's compute decisions bit for bit (the
                // recorded straggle already folds in any step-indexed
                // burst/drift offset)
                let rec = &r.steps[r.pos];
                straggle = rec.straggle[n];
                self.sample_buf.clear();
                self.sample_buf.extend_from_slice(&rec.samples[n]);
            } else {
                // the step-indexed burst/drift offset delays the step
                // start like a straggler; exactly 0.0 for the classic
                // noise families, so the sum is a bitwise no-op there
                straggle = self.model.sample_straggler_at(
                    n,
                    step_idx,
                    &mut self.streams[n],
                ) + self.model.step_offset(n, step_idx as u64);
                match threshold {
                    None => {
                        self.model.fill_microbatches(
                            n,
                            self.accums,
                            &mut self.sample_buf,
                            &mut self.streams[n],
                        );
                    }
                    Some(tau) => {
                        // the bounded fill stops drawing at the first
                        // threshold crossing in both preemption modes
                        self.model.fill_microbatches_bounded(
                            n,
                            straggle,
                            tau,
                            self.accums,
                            &mut self.sample_buf,
                            &mut self.streams[n],
                        );
                    }
                }
            }
            if let Some(w) = self.writer.as_mut() {
                w.push_worker(straggle, &self.sample_buf);
            }
            let (t, done) = scan_samples(
                threshold,
                self.preemption,
                self.accums,
                straggle,
                &self.sample_buf,
            );
            out.worker_compute.push(t);
            out.completed.push(done);
            obs.on_worker(n, t, done);
            if done < self.accums {
                obs.on_drop(
                    n,
                    DropCause::Tau { microbatches: self.accums - done },
                );
            }
        }
        if let Some(r) = self.replay.as_mut() {
            r.pos += 1;
        }
        step_idx
    }

    /// The collective side of one step: time the reduction over the
    /// arrivals [`Self::begin_step_observed`] left in `out` (fault-
    /// compacted when the plan kills anyone this step) and record the
    /// outcome. The other half of the [`Self::step_observed`] split.
    pub(crate) fn finish_step_observed<O: SimObserver>(
        &mut self,
        step_idx: usize,
        out: &mut StepOutcome,
        obs: &mut O,
    ) {
        if self.any_worker_dead(step_idx) {
            self.finish_faulted(step_idx, out, obs);
        } else {
            self.finish_into(out, obs);
        }
        if let Some(w) = self.writer.as_mut() {
            w.push_outcome(out);
        }
    }

    /// Whether this step's collective can be timed by the lane-parallel
    /// batched pass instead of [`Self::finish_step_observed`]: the
    /// compiled full-membership pass must be the path the scalar step
    /// would take, with no drop/fault branch diverting it. Per-phase
    /// checkpoints, fault-compacted steps, the event-queue reference
    /// ([`Self::with_reference_timing`]) and the fixed-`T^c` model all
    /// answer `false` — those replicas fall back to the scalar oracle.
    /// A step-level DropComm deadline stays eligible exactly when no
    /// worker misses it (the no-drop fast path times the same full-N
    /// compiled collective).
    pub(crate) fn batch_lockstep_eligible(
        &self,
        step_idx: usize,
        arrivals: &[f64],
    ) -> bool {
        if !self.use_compiled
            || self.compiled.is_none()
            || self.workers == 0
            || !self.phase_cutoffs.is_empty()
            || self.any_worker_dead(step_idx)
        {
            return false;
        }
        match self.comm_drop {
            None => true,
            Some(deadline) => {
                let cutoff = crate::sim::comm::bounded_wait_cutoff(
                    arrivals, deadline,
                );
                arrivals.iter().all(|&a| a <= cutoff)
            }
        }
    }

    /// The installed policy's Local-SGD period, if any — such replicas
    /// take the whole-period scalar path in a batch.
    pub(crate) fn installed_local_sgd(&self) -> Option<usize> {
        self.eff_h
    }

    /// The installed policy's compute threshold (what
    /// [`Self::step_installed_into`] steps under).
    pub(crate) fn installed_tau(&self) -> Option<f64> {
        self.eff_tau
    }

    /// The compiled schedule driving this sim's collectives, when the
    /// compiled path is selected — the schedule the batched pass
    /// replays lane-parallel.
    pub(crate) fn batch_schedule(&self) -> Option<&CompiledSchedule> {
        if self.use_compiled {
            self.compiled.as_ref()
        } else {
            None
        }
    }

    /// Close out a step whose collective was timed externally (the
    /// batched pass): `out` is fully populated; fire the closing
    /// observer event and record the outcome — exactly the tail
    /// [`Self::finish_into`] + [`Self::finish_step_observed`] would
    /// have run.
    pub(crate) fn seal_batched_step<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        obs: &mut O,
    ) {
        obs.on_step(out);
        if let Some(w) = self.writer.as_mut() {
            w.push_outcome(out);
        }
    }

    /// Swap the survivor cache with a caller-held one (the batch's
    /// shared cache) in place, guarded like
    /// [`Self::with_survivor_cache`]: a cache built for a different
    /// comm model is left untouched — memoization must never change
    /// results, only skip compiles.
    pub(crate) fn swap_survivor_cache(
        &mut self,
        cache: &mut super::survivor::SurvivorScheduleCache,
    ) {
        if cache.matches(&self.comm) {
            std::mem::swap(&mut self.survivors, cache);
        }
    }

    /// Worker count (the width of every per-worker vector this sim
    /// fills).
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    /// Simulate one Local-SGD synchronization period: `h` local steps of
    /// one micro-batch group each, then a sync. DropCompute integrates by
    /// thresholding each local step's compute (App. B.3).
    pub fn local_sgd_period(&mut self, h: usize, threshold: Option<f64>)
        -> StepOutcome
    {
        let mut out = StepOutcome::default();
        self.local_sgd_period_into(h, threshold, &mut out);
        out
    }

    /// [`Self::local_sgd_period`] into a caller-owned outcome, recycling
    /// its per-worker vectors (the allocating form built two fresh
    /// `Vec`s per period).
    ///
    /// Workers are processed worker-major: each worker owns its stream,
    /// so its draw order — straggler then micro-batch, per local step —
    /// is unchanged from the local-major loop and all seeded results
    /// stay bitwise identical (property-tested). When the straggler
    /// scenario consumes no randomness for a worker
    /// ([`LatencyModel::straggler_draws`]), its h micro-batches are
    /// drawn in one batched fill; when it flips a coin per local step,
    /// the fused [`LatencyModel::fill_local_steps`] batches the
    /// interleaved (coin, micro-batch) pairs instead — either way, one
    /// dispatch per period, zero per-draw branches.
    pub fn local_sgd_period_into(
        &mut self,
        h: usize,
        threshold: Option<f64>,
        out: &mut StepOutcome,
    ) {
        self.local_sgd_period_observed(h, threshold, out, &mut NoopObserver);
    }

    /// [`Self::local_sgd_period_into`] with a [`SimObserver`]; a
    /// [`DropCause::Tau`] event counts local steps the threshold
    /// skipped.
    pub fn local_sgd_period_observed<O: SimObserver>(
        &mut self,
        h: usize,
        threshold: Option<f64>,
        out: &mut StepOutcome,
        obs: &mut O,
    ) {
        let step_idx = self.step_idx;
        self.step_idx += 1;
        self.apply_fault_scaling(step_idx);
        out.worker_compute.clear();
        out.completed.clear();
        out.worker_compute.resize(self.workers, 0.0);
        out.completed.resize(self.workers, 0);
        if let Some(r) = &self.replay {
            assert!(
                r.mode == TraceMode::Period,
                "replay source records synchronous steps, not Local-SGD \
                 periods (ClusterSim::replay_into reports this as a typed \
                 error)"
            );
            assert!(
                r.pos < r.steps.len(),
                "replay source exhausted after {} periods \
                 (ClusterSim::replay_into reports this as a typed error)",
                r.steps.len()
            );
        }
        if let Some(w) = self.writer.as_mut() {
            w.begin_step(
                TraceMode::Period,
                threshold == self.eff_tau && Some(h) == self.eff_h,
            );
        }
        for n in 0..self.workers {
            if self.worker_dead(n, step_idx) {
                // dead under the fault plan: no local steps, no random
                // draws, no seat in the sync collective (the resize
                // above already zeroed this worker's outcome columns)
                self.sample_buf.clear();
                if let Some(w) = self.writer.as_mut() {
                    w.push_worker(0.0, &self.sample_buf);
                }
                obs.on_worker(n, 0.0, 0);
                obs.on_drop(n, DropCause::WorkerFault);
                continue;
            }
            if let Some(r) = &self.replay {
                // replay: each recorded entry is one local step's total
                // compute time (straggle and any step-indexed offset
                // folded in at record time)
                let rec = &r.steps[r.pos];
                self.sample_buf.clear();
                self.sample_buf.extend_from_slice(&rec.samples[n]);
            } else if self.model.straggler_draws(n) {
                // straggler coin flips interleave with micro-batch draws
                // in this worker's stream: the fused fill keeps the
                // sequential (coin, sample) order draw for draw while
                // paying the straggler/noise dispatch once per period
                self.model.fill_local_steps(
                    n,
                    h,
                    &mut self.sample_buf,
                    &mut self.streams[n],
                );
                // step-indexed burst/drift offset: delays every local
                // step; the guard keeps classic families untouched
                let off = self.model.step_offset(n, step_idx as u64);
                if off != 0.0 {
                    for s in self.sample_buf.iter_mut() {
                        *s += off;
                    }
                }
            } else {
                // straggle is a pure function of (worker, step): draw the
                // whole period's micro-batches in one batched fill, then
                // fold the constant straggle into each local step — the
                // same `straggle + s` sum the tally always consumed (the
                // step-indexed burst/drift offset rides along, exactly
                // 0.0 for the classic noise families)
                let straggle = self.model.sample_straggler_at(
                    n,
                    step_idx,
                    &mut self.streams[n],
                ) + self.model.step_offset(n, step_idx as u64);
                self.model.fill_microbatches(
                    n,
                    h,
                    &mut self.sample_buf,
                    &mut self.streams[n],
                );
                for s in self.sample_buf.iter_mut() {
                    *s = straggle + *s;
                }
            }
            if let Some(w) = self.writer.as_mut() {
                // period traces record the combined local-step times;
                // the straggle column is unused
                w.push_worker(0.0, &self.sample_buf);
            }
            let mut compute = 0.0f64;
            let mut done = 0usize;
            for &t in &self.sample_buf {
                match threshold {
                    Some(tau) => {
                        if t < tau {
                            done += 1;
                            compute += t;
                        } else {
                            compute += tau;
                        }
                    }
                    None => {
                        done += 1;
                        compute += t;
                    }
                }
            }
            out.worker_compute[n] = compute;
            out.completed[n] = done;
            obs.on_worker(n, compute, done);
            if done < h {
                obs.on_drop(n, DropCause::Tau { microbatches: h - done });
            }
        }
        if let Some(r) = self.replay.as_mut() {
            r.pos += 1;
        }
        if self.any_worker_dead(step_idx) {
            self.finish_faulted(step_idx, out, obs);
        } else {
            self.finish_into(out, obs);
        }
        if let Some(w) = self.writer.as_mut() {
            w.push_outcome(out);
        }
    }

    /// Begin recording a [`TraceRecord`] of every subsequent step: each
    /// worker's straggler delay and drawn micro-batch latencies (or, in
    /// Local-SGD mode, per-local-step compute times), plus the step's
    /// [`StepOutcome`] — the versioned-JSON trace the `trace` CLI
    /// subcommands, the conformance fixtures and
    /// [`crate::analysis::budget_fit`] consume. Replaying the record
    /// through [`Self::from_trace`] reproduces the recorded outcomes
    /// bitwise (property-tested in `tests/trace_conformance.rs`).
    ///
    /// Recording captures steps made under the *installed* policy;
    /// [`Self::finish_recording`] returns a typed error if per-call
    /// thresholds diverged from it (or the policy was swapped
    /// mid-recording), because the metadata would then lie about what
    /// the steps ran under.
    pub fn start_recording(&mut self) {
        self.writer = Some(TraceWriter::new(TraceMeta {
            version: TRACE_FORMAT_VERSION,
            mode: if self.eff_h.is_some() {
                TraceMode::Period
            } else {
                TraceMode::Step
            },
            workers: self.workers,
            accums: self.accums,
            seed: self.seed,
            policy: self.policy.spec(),
            comm: TraceComm::from_model(&self.comm),
            single_restart: !self.recursive_restart,
            scenario: self.fault.as_ref().map(|p| p.spec()),
            transport: None,
        }));
    }

    /// Stop recording and return the finished [`TraceRecord`]
    /// (validated). Typed errors: no recording in progress, or the
    /// recorded steps diverged from the installed policy (see
    /// [`Self::start_recording`]).
    pub fn finish_recording(&mut self) -> Result<TraceRecord> {
        match self.writer.take() {
            Some(w) => w.finish(),
            None => Err(Error::Runtime(
                "no trace recording in progress (ClusterSim::start_recording)"
                    .into(),
            )),
        }
    }

    /// Whether a [`Self::start_recording`] recording is active.
    pub fn is_recording(&self) -> bool {
        self.writer.is_some()
    }

    /// Install `trace` as this sim's timing source: subsequent steps
    /// draw compute from the recorded steps instead of the latency
    /// model (the comm side stays the sim's own deterministic timing —
    /// compiled pass or event-queue oracle, whichever is selected).
    /// Validates the trace and its shape against the sim.
    pub fn with_replay(mut self, trace: &TraceRecord) -> Result<Self> {
        trace.validate()?;
        if trace.meta.workers != self.workers
            || trace.meta.accums != self.accums
        {
            return Err(Error::Data(format!(
                "replay shape mismatch: trace is {}x{} (workers x accums), \
                 sim is {}x{}",
                trace.meta.workers,
                trace.meta.accums,
                self.workers,
                self.accums
            )));
        }
        self.replay = Some(ReplayState {
            steps: trace.steps.clone(),
            mode: trace.meta.mode,
            pos: 0,
        });
        Ok(self)
    }

    /// Build a complete replay sim from a recorded trace: cluster shape,
    /// comm model, policy and seed all come from the trace metadata, and
    /// the recorded steps are installed as the timing source. Replaying
    /// ([`Self::replay_all`]) reproduces the recorded run's
    /// [`StepOutcome`]s bitwise. Chain [`Self::with_reference_timing`]
    /// for the event-queue oracle arm, or [`Self::set_policy`] to
    /// re-time the recorded compute under a *different* drop policy
    /// (the [`crate::analysis::budget_fit`] evaluator).
    pub fn from_trace(trace: &TraceRecord) -> Result<Self> {
        trace.validate()?;
        let policy = DropPolicy::parse(&trace.meta.policy)?;
        let cfg = ClusterConfig {
            workers: trace.meta.workers,
            accumulations: trace.meta.accums,
            ..Default::default()
        };
        let mut sim = Self::with_model(
            trace.meta.workers,
            trace.meta.accums,
            LatencyModel::from_config(&cfg),
            trace.meta.comm.to_model(),
            trace.meta.seed,
        )
        .with_policy(policy);
        if trace.meta.single_restart {
            // restore the recorded run's restart semantics — bitwise
            // conformance requires replaying under the same rules
            sim = sim.with_single_restart();
        }
        if let Some(spec) = &trace.meta.scenario {
            // churn traces replay under the recorded fault plan; the
            // membership schedule is part of the timing semantics
            let plan = super::fault::FaultPlan::parse(spec)?;
            plan.validate_for(trace.meta.workers)?;
            sim = sim.with_fault_plan(plan);
        }
        sim.with_replay(trace)
    }

    /// Steps left in the installed replay source (0 when none).
    pub fn replay_remaining(&self) -> usize {
        self.replay.as_ref().map_or(0, |r| r.steps.len() - r.pos)
    }

    /// Reset the replay cursor to the first recorded step, so the same
    /// source can be re-timed under another policy without rebuilding
    /// the sim (the [`crate::analysis::budget_fit`] evaluator replays
    /// one trace hundreds of times; cursor resets beat hundreds of
    /// deep trace copies). Typed error when no source is installed.
    pub fn rewind_replay(&mut self) -> Result<()> {
        match self.replay.as_mut() {
            Some(r) => {
                r.pos = 0;
                Ok(())
            }
            None => Err(Error::Runtime(
                "no replay source installed (ClusterSim::with_replay)".into(),
            )),
        }
    }

    /// One replayed step under the installed policy. Typed errors
    /// instead of panics: no replay source, source exhausted (short
    /// trace), or the trace's mode (step vs Local-SGD period) does not
    /// match the installed policy.
    pub fn replay_into(&mut self, out: &mut StepOutcome) -> Result<()> {
        self.replay_observed(out, &mut NoopObserver)
    }

    /// [`Self::replay_into`] with a [`SimObserver`] — the same event
    /// stream a live step emits, driven by the recorded draws.
    pub fn replay_observed<O: SimObserver>(
        &mut self,
        out: &mut StepOutcome,
        obs: &mut O,
    ) -> Result<()> {
        let r = self.replay.as_ref().ok_or_else(|| {
            Error::Runtime(
                "no replay source installed (ClusterSim::with_replay)".into(),
            )
        })?;
        if r.pos >= r.steps.len() {
            return Err(Error::Data(format!(
                "replay source exhausted after {} steps",
                r.steps.len()
            )));
        }
        match (self.eff_h, r.mode) {
            (Some(_), TraceMode::Step) => Err(Error::Data(
                "replay mode mismatch: the trace records synchronous steps \
                 but the installed policy measures Local-SGD periods"
                    .into(),
            )),
            (None, TraceMode::Period) => Err(Error::Data(
                "replay mode mismatch: the trace records Local-SGD periods \
                 but the installed policy measures synchronous steps"
                    .into(),
            )),
            _ => {
                self.step_installed_observed(out, obs);
                Ok(())
            }
        }
    }

    /// Replay every remaining recorded step ([`Self::replay_into`] in a
    /// loop), returning the outcomes in step order.
    pub fn replay_all(&mut self) -> Result<Vec<StepOutcome>> {
        let mut outs = Vec::with_capacity(self.replay_remaining());
        while self.replay_remaining() > 0 {
            let mut out = StepOutcome::default();
            self.replay_into(&mut out)?;
            outs.push(out);
        }
        Ok(outs)
    }

    /// Record a no-drop latency trace of `iters` iterations — the input
    /// of Algorithm 2 and of the Fig 4 post-analysis.
    pub fn record_trace(&mut self, iters: usize) -> Trace {
        let mut trace = Trace::new(iters, self.workers, self.accums);
        for i in 0..iters {
            let step_idx = self.step_idx;
            self.step_idx += 1;
            for n in 0..self.workers {
                let straggle = self.model.sample_straggler_at(
                    n,
                    step_idx,
                    &mut self.streams[n],
                ) + self.model.step_offset(n, step_idx as u64);
                self.model.fill_microbatches(
                    n,
                    self.accums,
                    &mut self.sample_buf,
                    &mut self.streams[n],
                );
                for (m, &s) in self.sample_buf.iter().enumerate() {
                    let t = if m == 0 { s + straggle } else { s };
                    trace.set(i, n, m, t);
                }
            }
            trace.comm[i] = self.comm_latency();
        }
        trace
    }

    /// Mean iteration time over `iters` simulated steps (reuses one
    /// outcome buffer across the loop).
    pub fn mean_iter_time(&mut self, iters: usize, threshold: Option<f64>) -> f64 {
        let mut out = StepOutcome::default();
        let mut sum = 0.0;
        for _ in 0..iters {
            self.step_into(threshold, &mut out);
            sum += out.iter_time;
        }
        sum / iters as f64
    }

    /// Mean synchronization-period time over `periods` Local-SGD periods
    /// of `h` local steps each — the Local-SGD analogue of
    /// [`Self::mean_iter_time`], reusing one outcome buffer across the
    /// loop.
    pub fn mean_period_time(
        &mut self,
        periods: usize,
        h: usize,
        threshold: Option<f64>,
    ) -> f64 {
        let mut out = StepOutcome::default();
        let mut sum = 0.0;
        for _ in 0..periods {
            self.local_sgd_period_into(h, threshold, &mut out);
            sum += out.iter_time;
        }
        sum / periods as f64
    }
}

/// Scan one worker's micro-batch samples against the compute threshold —
/// the single compute-side decision procedure shared by the live path
/// (samples freshly drawn, the bounded fill having stopped at the first
/// crossing) and the replay path (samples from a recorded trace), so
/// both produce bitwise-identical `(compute_time, completed)` for the
/// same sample values.
#[inline]
fn scan_samples(
    threshold: Option<f64>,
    preemption: PreemptionMode,
    accums: usize,
    straggle: f64,
    samples: &[f64],
) -> (f64, usize) {
    let mut t = straggle;
    let mut done = 0usize;
    match (threshold, preemption) {
        (None, _) => {
            for &s in samples {
                t += s;
            }
            done = samples.len();
        }
        (Some(tau), PreemptionMode::Preemptive) => {
            for &s in samples {
                let next = t + s;
                if next < tau {
                    t = next;
                    done += 1;
                } else {
                    break;
                }
            }
            // The timeout fires on the wall clock, so even a stalled
            // compute pipeline (Fatal stragglers) is preempted at
            // exactly tau — the worker joins the AllReduce with
            // whatever it has (possibly nothing).
            if done < accums {
                t = tau;
            }
        }
        (Some(tau), PreemptionMode::BetweenAccumulations) => {
            for &s in samples {
                t += s;
                done += 1;
                if t >= tau {
                    break;
                }
            }
        }
    }
    (t, done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, NoiseKind};

    fn config(workers: usize, accums: usize) -> ClusterConfig {
        ClusterConfig {
            workers,
            accumulations: accums,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.2,
            noise: NoiseKind::None,
            ..Default::default()
        }
    }

    #[test]
    fn baseline_step_all_complete() {
        let mut sim = ClusterSim::new(&config(8, 12), 0);
        let out = sim.step(None);
        assert_eq!(out.total_completed(), 8 * 12);
        assert!(out.iter_time > out.compute_time);
        assert!((out.iter_time - out.compute_time - 0.2).abs() < 1e-12);
        // with sigma=0.02 and M=12 the step should be ~5.4s
        assert!((out.compute_time - 5.4).abs() < 0.5, "{}", out.compute_time);
    }

    #[test]
    fn iteration_time_grows_with_workers() {
        // E[max of N] increases with N — the core scalability problem.
        let mut small = ClusterSim::new(&config(2, 12), 1);
        let mut large = ClusterSim::new(&config(128, 12), 1);
        let t_small = small.mean_iter_time(200, None);
        let t_large = large.mean_iter_time(200, None);
        assert!(t_large > t_small, "{t_large} vs {t_small}");
    }

    #[test]
    fn threshold_caps_compute_time() {
        let mut c = config(16, 12);
        c.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        let tau = 9.0;
        let mut sim = ClusterSim::new(&c, 2);
        for _ in 0..50 {
            let out = sim.step(Some(tau));
            assert!(out.compute_time <= tau + 1e-9);
            for (&t, &done) in out.worker_compute.iter().zip(&out.completed) {
                assert!(t <= tau + 1e-9);
                assert!(done <= 12);
            }
        }
    }

    #[test]
    fn dropcompute_faster_but_drops() {
        let mut c = config(64, 12);
        c.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        let mut base = ClusterSim::new(&c, 3);
        let mut dc = ClusterSim::new(&c, 3);
        let t_base = base.mean_iter_time(100, None);
        let mut dropped = 0usize;
        let mut total = 0usize;
        let mut t_dc = 0.0;
        for _ in 0..100 {
            let out = dc.step(Some(9.0));
            t_dc += out.iter_time / 100.0;
            dropped += 64 * 12 - out.total_completed();
            total += 64 * 12;
        }
        let rate = dropped as f64 / total as f64;
        assert!(t_dc < t_base, "dc {t_dc} vs base {t_base}");
        assert!(rate > 0.0 && rate < 0.5, "drop rate {rate}");
    }

    #[test]
    fn preemption_modes_differ_as_expected() {
        let mut c = config(4, 8);
        c.noise = NoiseKind::Exponential { mean: 0.3 };
        let tau = 2.0;
        let mut pre = ClusterSim::new(&c, 7)
            .with_preemption(PreemptionMode::Preemptive);
        let mut between = ClusterSim::new(&c, 7)
            .with_preemption(PreemptionMode::BetweenAccumulations);
        // Preemptive never exceeds tau; between-accums can overshoot.
        let mut overshoot = false;
        for _ in 0..200 {
            let a = pre.step(Some(tau));
            assert!(a.compute_time <= tau + 1e-9);
            let b = between.step(Some(tau));
            if b.compute_time > tau {
                overshoot = true;
            }
        }
        assert!(overshoot, "between-accumulations should overshoot sometimes");
    }

    #[test]
    fn fatal_worker_stalls_baseline_but_not_dropcompute() {
        // §2 robustness claim: a dead worker freezes synchronous
        // training; DropCompute degrades to the survivors.
        let mut c = config(6, 4);
        c.stragglers = crate::config::StragglerKind::Fatal {
            worker: 2,
            from_step: 3,
        };
        let mut base = ClusterSim::new(&c, 17);
        let mut dc = ClusterSim::new(&c, 17);
        for step in 0..6 {
            let b = base.step(None);
            let d = dc.step(Some(2.5));
            if step < 3 {
                assert!(b.iter_time < 100.0);
                assert_eq!(d.completed[2] > 0, true);
            } else {
                // baseline waits ~forever
                assert!(b.iter_time >= LatencyModel::FATAL_DELAY);
                // DropCompute: capped step, dead worker contributes 0
                assert!(d.iter_time < 10.0, "{}", d.iter_time);
                assert_eq!(d.completed[2], 0);
                assert!(d.total_completed() > 0);
            }
        }
    }

    #[test]
    fn comm_drop_excludes_stragglers_and_caps_iter_time() {
        // DropComm alone (no compute threshold): a fatally stalled
        // worker is excluded at the collective membership deadline, so
        // iteration time stays bounded — the comm-side dual of the
        // DropCompute robustness test below.
        let mut c = config(6, 4);
        c.stragglers = crate::config::StragglerKind::Fatal {
            worker: 2,
            from_step: 0,
        };
        c.topology = Some(crate::topology::TopologyKind::Ring);
        c.comm_drop_deadline = 2.0;
        let mut sim = ClusterSim::new(&c, 5);
        let out = sim.step(None);
        assert_eq!(out.completed[2], 0, "dropped worker contributes 0");
        assert_eq!(out.total_completed(), 5 * 4, "survivors all count");
        assert!(out.iter_time < 10.0, "{}", out.iter_time);
        // without DropComm the same cluster stalls
        c.comm_drop_deadline = 0.0;
        let mut base = ClusterSim::new(&c, 5);
        assert!(base.step(None).iter_time >= LatencyModel::FATAL_DELAY);
    }

    #[test]
    fn comm_drop_loose_deadline_changes_nothing() {
        let mut c = config(8, 6);
        c.noise = NoiseKind::Exponential { mean: 0.1 };
        let mut plain = ClusterSim::new(&c, 21);
        c.comm_drop_deadline = 1e6;
        let mut drop = ClusterSim::new(&c, 21);
        for _ in 0..20 {
            let a = plain.step(None);
            let b = drop.step(None);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
    }

    #[test]
    fn topology_config_drives_comm_model() {
        let mut c = config(8, 4);
        c.topology = Some(crate::topology::TopologyKind::Tree);
        c.link_latency = 1e-4;
        c.link_bandwidth = 1e9;
        c.grad_bytes = 4e6;
        let sim = ClusterSim::new(&c, 1);
        let want = crate::topology::TopologyKind::Tree
            .build(8)
            .uniform_cost(1e-4, 1e9, 4e6);
        assert!((sim.comm_latency() - want).abs() < 1e-12);
    }

    #[test]
    fn trace_dimensions_and_determinism() {
        let mut a = ClusterSim::new(&config(3, 5), 42);
        let mut b = ClusterSim::new(&config(3, 5), 42);
        let ta = a.record_trace(4);
        let tb = b.record_trace(4);
        assert_eq!(ta, tb);
        assert_eq!(ta.iters, 4);
        assert_eq!(ta.workers, 3);
        assert_eq!(ta.accums, 5);
    }

    #[test]
    fn local_sgd_period_counts() {
        let mut sim = ClusterSim::new(&config(4, 1), 9);
        let out = sim.local_sgd_period(8, None);
        assert_eq!(out.total_completed(), 4 * 8);
        // 8 local steps of ~0.45s each
        assert!((out.compute_time - 3.6).abs() < 0.5, "{}", out.compute_time);
    }

    #[test]
    fn drop_rate_guards_degenerate_outcomes() {
        // Regression: workers == 0 or accums == 0 used to divide by zero
        // and return NaN; an empty schedule drops nothing.
        let empty = StepOutcome::default();
        assert_eq!(empty.drop_rate(12), 0.0);
        let out = StepOutcome {
            worker_compute: vec![1.0, 1.0],
            completed: vec![0, 0],
            compute_time: 1.0,
            iter_time: 1.5,
        };
        assert_eq!(out.drop_rate(0), 0.0);
        assert!(!out.drop_rate(0).is_nan());
        // the normal case still reports real drops
        assert!((out.drop_rate(4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn step_into_reuses_buffers_and_matches_step() {
        let mut c = config(8, 6);
        c.noise = NoiseKind::Exponential { mean: 0.2 };
        c.topology = Some(crate::topology::TopologyKind::Ring);
        let mut a = ClusterSim::new(&c, 31);
        let mut b = ClusterSim::new(&c, 31);
        let mut out = StepOutcome::default();
        for _ in 0..10 {
            let fresh = a.step(Some(2.0));
            b.step_into(Some(2.0), &mut out);
            assert_eq!(fresh.completed, out.completed);
            assert_eq!(fresh.iter_time.to_bits(), out.iter_time.to_bits());
            assert_eq!(
                fresh.compute_time.to_bits(),
                out.compute_time.to_bits()
            );
        }
    }

    #[test]
    fn compiled_timing_bitwise_equals_reference() {
        // the compiled heapless pass and the event-queue oracle must
        // agree to the bit on every topology, with and without DropComm.
        for kind in crate::topology::TopologyKind::ALL {
            for deadline in [0.0, 1.5] {
                let mut c = config(12, 6);
                c.noise = NoiseKind::Exponential { mean: 0.4 };
                c.topology = Some(kind);
                c.link_latency = 1e-4;
                c.link_bandwidth = 1e9;
                c.grad_bytes = 4e6;
                c.comm_drop_deadline = deadline;
                let mut fast = ClusterSim::new(&c, 99);
                let mut slow =
                    ClusterSim::new(&c, 99).with_reference_timing();
                for _ in 0..15 {
                    let f = fast.step(Some(3.0));
                    let s = slow.step(Some(3.0));
                    assert_eq!(
                        f.iter_time.to_bits(),
                        s.iter_time.to_bits(),
                        "{} deadline={deadline}",
                        kind.name()
                    );
                    assert_eq!(f.completed, s.completed);
                }
            }
        }
    }

    #[test]
    fn finish_into_guards_zero_worker_outcome() {
        // Regression: a zero-worker step used to fold compute_time to
        // -inf (`fold(NEG_INFINITY, max)` over no elements). It must be
        // 0.0 — nothing computed for zero seconds — and stay finite
        // with and without DropComm.
        for deadline in [None, Some(1.0)] {
            let mut sim = ClusterSim::with_model(
                0,
                4,
                LatencyModel::from_config(&config(0, 4)),
                CommModel::Fixed(0.2),
                13,
            )
            .with_comm_drop(deadline);
            let out = sim.step(None);
            assert_eq!(out.compute_time, 0.0, "deadline={deadline:?}");
            assert!(out.compute_time.is_finite());
            assert_eq!(out.iter_time, 0.0);
            assert_eq!(out.drop_rate(4), 0.0);
            assert!(!out.drop_rate(4).is_nan());
        }
        // zero accumulations: workers arrive with only their straggle,
        // nothing scheduled, nothing dropped
        let mut sim = ClusterSim::new(&config(3, 0), 13);
        let out = sim.step(None);
        assert_eq!(out.compute_time, 0.0);
        assert_eq!(out.total_completed(), 0);
        assert_eq!(out.drop_rate(0), 0.0);
    }

    #[test]
    fn survivor_cache_drop_path_matches_reference() {
        // a drop on (nearly) every step: the cached survivor collective
        // against the event-queue bounded-wait oracle, bit for bit,
        // while the cache compiles each survivor count at most once
        let mut c = config(16, 4);
        c.noise = NoiseKind::Exponential { mean: 0.6 };
        c.stragglers = crate::config::StragglerKind::Uniform {
            p: 0.4,
            delay: 5.0,
        };
        c.topology = Some(crate::topology::TopologyKind::Torus { rows: 0 });
        c.comm_drop_deadline = 1.0;
        let mut fast = ClusterSim::new(&c, 77);
        let mut slow = ClusterSim::new(&c, 77).with_reference_timing();
        let mut dropped_steps = 0;
        for step in 0..40 {
            let a = fast.step(None);
            let b = slow.step(None);
            assert_eq!(
                a.iter_time.to_bits(),
                b.iter_time.to_bits(),
                "step {step}"
            );
            assert_eq!(a.completed, b.completed);
            if a.total_completed() < 16 * 4 {
                dropped_steps += 1;
            }
        }
        assert!(dropped_steps > 20, "drop-heavy config: {dropped_steps}/40");
        assert!(
            fast.survivors.compiled_count() <= 16,
            "at most one compile per survivor count: {}",
            fast.survivors.compiled_count()
        );
    }

    #[test]
    fn local_sgd_period_into_reuses_buffers_and_matches() {
        // the recycling form against the allocating form, across
        // straggler kinds that do and don't consume rng draws
        for strag in [
            crate::config::StragglerKind::None,
            crate::config::StragglerKind::Uniform { p: 0.3, delay: 1.0 },
            crate::config::StragglerKind::SingleServer {
                p: 0.5,
                delay: 2.0,
                server_size: 2,
            },
            crate::config::StragglerKind::Fatal { worker: 1, from_step: 2 },
        ] {
            let mut c = config(4, 1);
            c.noise = NoiseKind::Exponential { mean: 0.2 };
            c.stragglers = strag.clone();
            let mut a = ClusterSim::new(&c, 19);
            let mut b = ClusterSim::new(&c, 19);
            let mut out = StepOutcome::default();
            for period in 0..6 {
                let fresh = a.local_sgd_period(5, Some(0.9));
                b.local_sgd_period_into(5, Some(0.9), &mut out);
                assert_eq!(fresh.completed, out.completed, "{strag:?} {period}");
                for (x, y) in fresh.worker_compute.iter().zip(&out.worker_compute)
                {
                    assert_eq!(x.to_bits(), y.to_bits(), "{strag:?} {period}");
                }
                assert_eq!(
                    fresh.iter_time.to_bits(),
                    out.iter_time.to_bits(),
                    "{strag:?} {period}"
                );
            }
        }
    }

    #[test]
    fn mean_period_time_matches_manual_loop() {
        let mut c = config(4, 1);
        c.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.2, delay: 1.0 };
        let mut a = ClusterSim::new(&c, 23);
        let mut b = ClusterSim::new(&c, 23);
        let mean = a.mean_period_time(10, 6, Some(0.8));
        let mut sum = 0.0;
        for _ in 0..10 {
            sum += b.local_sgd_period(6, Some(0.8)).iter_time;
        }
        assert_eq!(mean.to_bits(), (sum / 10.0).to_bits());
    }

    #[test]
    fn step_with_policy_matches_legacy_paths_bitwise() {
        // the unified surface against the legacy knobs: tau via the
        // step() argument + deadline via config must equal one composed
        // DropPolicy, bit for bit
        let mut c = config(12, 6);
        c.noise = NoiseKind::Exponential { mean: 0.4 };
        c.topology = Some(crate::topology::TopologyKind::Ring);
        c.comm_drop_deadline = 1.5;
        let mut legacy = ClusterSim::new(&c, 42);
        let mut unified = ClusterSim::new(&c, 42);
        let policy = DropPolicy::compute_tau(3.0)
            .and(DropPolicy::comm_deadline(1.5));
        let mut out = StepOutcome::default();
        for step in 0..15 {
            let a = legacy.step(Some(3.0));
            unified.step_with_into(&policy, &mut out);
            assert_eq!(a.completed, out.completed, "step {step}");
            assert_eq!(a.iter_time.to_bits(), out.iter_time.to_bits());
            assert_eq!(a.compute_time.to_bits(), out.compute_time.to_bits());
        }
    }

    #[test]
    fn step_with_local_sgd_policy_matches_period_call() {
        let mut c = config(4, 1);
        c.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.3, delay: 1.0 };
        let mut a = ClusterSim::new(&c, 7);
        let mut b = ClusterSim::new(&c, 7);
        let policy = DropPolicy::local_sgd(6)
            .and(DropPolicy::compute_tau(0.9));
        for _ in 0..5 {
            let x = a.local_sgd_period(6, Some(0.9));
            let y = b.step_with(&policy);
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.iter_time.to_bits(), y.iter_time.to_bits());
        }
    }

    #[test]
    fn per_phase_lumped_budget_equals_step_deadline() {
        // the acceptance identity: a single lumped budget is the
        // step-level CommDeadline, bitwise, on every topology and the
        // fixed-T^c model, compiled and reference arms
        let topos: Vec<Option<crate::topology::TopologyKind>> =
            std::iter::once(None)
                .chain(crate::topology::TopologyKind::ALL.iter().copied().map(Some))
                .collect();
        for topo in topos {
            for reference in [false, true] {
                let mut c = config(10, 4);
                c.noise = NoiseKind::Exponential { mean: 0.5 };
                c.stragglers = crate::config::StragglerKind::Uniform {
                    p: 0.3,
                    delay: 4.0,
                };
                c.topology = topo;
                let mk = |cfg: &ClusterConfig, reference: bool| {
                    let sim = ClusterSim::new(cfg, 0xFA7E);
                    if reference {
                        sim.with_reference_timing()
                    } else {
                        sim
                    }
                };
                let mut lumped = mk(&c, reference).with_policy(
                    DropPolicy::per_phase_deadline(vec![1.0]),
                );
                let mut step = mk(&c, reference)
                    .with_policy(DropPolicy::comm_deadline(1.0));
                for s in 0..20 {
                    let a = lumped.step(None);
                    let b = step.step(None);
                    assert_eq!(
                        a.completed, b.completed,
                        "{topo:?} ref={reference} step {s}"
                    );
                    assert_eq!(
                        a.iter_time.to_bits(),
                        b.iter_time.to_bits(),
                        "{topo:?} ref={reference} step {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn per_phase_compiled_equals_reference_timing() {
        // multi-budget per-phase cutoffs: the compiled scan against the
        // event-queue oracle, bit for bit, drop-heavy
        for kind in crate::topology::TopologyKind::ALL {
            let mut c = config(12, 4);
            c.noise = NoiseKind::Exponential { mean: 0.6 };
            c.stragglers = crate::config::StragglerKind::Uniform {
                p: 0.4,
                delay: 5.0,
            };
            c.topology = Some(kind);
            let policy =
                DropPolicy::per_phase_deadline(vec![1.0, 0.25, 0.25]);
            let mut fast =
                ClusterSim::new(&c, 99).with_policy(policy.clone());
            let mut slow = ClusterSim::new(&c, 99)
                .with_reference_timing()
                .with_policy(policy);
            let mut dropped_steps = 0;
            for step in 0..25 {
                let a = fast.step(None);
                let b = slow.step(None);
                assert_eq!(
                    a.completed,
                    b.completed,
                    "{} step {step}",
                    kind.name()
                );
                assert_eq!(
                    a.iter_time.to_bits(),
                    b.iter_time.to_bits(),
                    "{} step {step}",
                    kind.name()
                );
                if a.total_completed() < 12 * 4 {
                    dropped_steps += 1;
                }
            }
            assert!(dropped_steps > 5, "{}: {dropped_steps}", kind.name());
        }
    }

    #[test]
    fn policy_install_and_accessor() {
        let c = config(4, 2);
        let policy = DropPolicy::parse("tau=2,between+deadline=1").unwrap();
        let mut sim = ClusterSim::new(&c, 1).with_policy(policy.clone());
        assert_eq!(sim.policy(), &policy);
        assert_eq!(sim.preemption, PreemptionMode::BetweenAccumulations);
        // re-stepping the same policy must not reinstall (observable
        // via the unchanged accessor; the equality check guards it)
        sim.step_with(&policy);
        assert_eq!(sim.policy(), &policy);
        // legacy comm-drop shim replaces the comm side
        let sim2 = ClusterSim::new(&c, 1).with_comm_drop(Some(2.0));
        assert_eq!(sim2.policy(), &DropPolicy::comm_deadline(2.0));
        // ...and the WHOLE installed state: compute/local clauses from
        // an earlier policy must not survive the shim (regression: a
        // stale eff_h/eff_tau made policy() lie about what steps ran)
        let mut sim3 = ClusterSim::new(&c, 1)
            .with_policy(DropPolicy::parse("local-sgd=4+tau=0.9").unwrap())
            .with_comm_drop(Some(2.0));
        assert_eq!(sim3.policy(), &DropPolicy::comm_deadline(2.0));
        let mut plain = ClusterSim::new(&c, 1).with_comm_drop(Some(2.0));
        let a = sim3.step_with(&DropPolicy::comm_deadline(2.0));
        let b = plain.step(None);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
    }

    #[test]
    fn survivor_cache_adoption_is_pure_memoization() {
        // a warm cache hopping between sims must not change a single
        // bit of any outcome
        let mut c = config(8, 4);
        c.noise = NoiseKind::Exponential { mean: 0.6 };
        c.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.4, delay: 5.0 };
        c.topology = Some(crate::topology::TopologyKind::Tree);
        c.comm_drop_deadline = 1.0;
        let mut cold = ClusterSim::new(&c, 3);
        let mut warmer = ClusterSim::new(&c, 3);
        // warm a cache on a different-N sim of the same comm model
        let mut donor_cfg = c.clone();
        donor_cfg.workers = 5;
        let mut donor = ClusterSim::new(&donor_cfg, 9);
        for _ in 0..10 {
            donor.step(None);
        }
        let warm = donor.take_survivor_cache();
        warmer = warmer.with_survivor_cache(warm);
        for _ in 0..20 {
            let a = cold.step(None);
            let b = warmer.step(None);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
        // a mismatched cache is rejected, not adopted
        let mut other = c.clone();
        other.topology = Some(crate::topology::TopologyKind::Ring);
        let mut ring_sim = ClusterSim::new(&other, 1);
        for _ in 0..10 {
            ring_sim.step(None);
        }
        let ring_cache = ring_sim.take_survivor_cache();
        let tree_sim = ClusterSim::new(&c, 3).with_survivor_cache(ring_cache);
        assert_eq!(tree_sim.survivors.compiled_count(), 0);
    }

    #[test]
    fn record_replay_reproduces_outcomes_bitwise() {
        // record a live run under a composed policy, then replay the
        // record from scratch: every StepOutcome must match bit for bit
        // on the compiled path AND the event-queue oracle path (the
        // full topology x policy sweep lives in
        // tests/trace_conformance.rs)
        let mut c = config(6, 4);
        c.noise = NoiseKind::Exponential { mean: 0.4 };
        c.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.3, delay: 3.0 };
        c.topology = Some(crate::topology::TopologyKind::Ring);
        let policy = DropPolicy::parse("tau=2.5+deadline=1").unwrap();
        let mut live = ClusterSim::new(&c, 0x7ACE).with_policy(policy);
        live.start_recording();
        let mut recorded = Vec::new();
        for _ in 0..8 {
            let mut out = StepOutcome::default();
            live.step_installed_into(&mut out);
            recorded.push(out);
        }
        let trace = live.finish_recording().unwrap();
        assert_eq!(trace.len(), 8);
        for (rec, out) in trace.outcomes.iter().zip(&recorded) {
            assert!(rec.matches(out), "writer embeds the live outcomes");
        }
        // compiled replay
        let mut replay = ClusterSim::from_trace(&trace).unwrap();
        let outs = replay.replay_all().unwrap();
        assert_eq!(outs.len(), 8);
        for (i, (rec, out)) in trace.outcomes.iter().zip(&outs).enumerate() {
            assert!(rec.matches(out), "compiled replay step {i}");
        }
        // event-queue oracle replay
        let mut oracle =
            ClusterSim::from_trace(&trace).unwrap().with_reference_timing();
        for (i, rec) in trace.outcomes.iter().enumerate() {
            let mut out = StepOutcome::default();
            oracle.replay_into(&mut out).unwrap();
            assert!(rec.matches(&out), "oracle replay step {i}");
        }
        // JSON round trip preserves all of it
        let parsed =
            crate::sim::TraceRecord::parse(&trace.to_json()).unwrap();
        let mut again = ClusterSim::from_trace(&parsed).unwrap();
        for (i, rec) in parsed.outcomes.iter().enumerate() {
            let mut out = StepOutcome::default();
            again.replay_into(&mut out).unwrap();
            assert!(rec.matches(&out), "parsed replay step {i}");
        }
    }

    #[test]
    fn replay_errors_are_typed_not_panics() {
        let mut c = config(3, 2);
        c.noise = NoiseKind::Exponential { mean: 0.2 };
        let mut live = ClusterSim::new(&c, 5);
        live.start_recording();
        for _ in 0..3 {
            live.step(None);
        }
        let trace = live.finish_recording().unwrap();
        // exhausting the source is an error, not a panic
        let mut replay = ClusterSim::from_trace(&trace).unwrap();
        assert_eq!(replay.replay_remaining(), 3);
        replay.replay_all().unwrap();
        let mut out = StepOutcome::default();
        assert!(replay.replay_into(&mut out).is_err(), "short trace");
        // mode mismatch: replaying a step trace under a local-sgd policy
        let mut wrong_mode = ClusterSim::from_trace(&trace).unwrap();
        wrong_mode.set_policy(&DropPolicy::parse("local-sgd=2").unwrap());
        assert!(wrong_mode.replay_into(&mut out).is_err());
        // shape mismatch: a sim of the wrong size rejects the source
        let other = ClusterSim::new(&config(5, 2), 5);
        assert!(other.with_replay(&trace).is_err());
        // no source installed
        let mut plain = ClusterSim::new(&c, 5);
        assert!(plain.replay_into(&mut out).is_err());
        // no recording in progress
        assert!(plain.finish_recording().is_err());
    }

    #[test]
    fn recording_rejects_divergent_per_call_thresholds() {
        let mut c = config(3, 2);
        c.noise = NoiseKind::Exponential { mean: 0.2 };
        // per-call threshold != installed policy: typed error at finish
        let mut sim = ClusterSim::new(&c, 1);
        sim.start_recording();
        sim.step(Some(1.5));
        assert!(sim.finish_recording().is_err());
        // a mid-recording policy swap is flagged too
        let mut sim = ClusterSim::new(&c, 1);
        sim.start_recording();
        sim.step(None);
        sim.step_with(&DropPolicy::compute_tau(2.0));
        assert!(sim.finish_recording().is_err());
        // stepping the installed policy is fine, including local-SGD
        let mut sim = ClusterSim::new(&c, 1)
            .with_policy(DropPolicy::parse("local-sgd=3+tau=0.9").unwrap());
        sim.start_recording();
        let mut out = StepOutcome::default();
        for _ in 0..4 {
            sim.step_installed_into(&mut out);
        }
        let trace = sim.finish_recording().unwrap();
        assert_eq!(trace.meta.mode, crate::sim::TraceMode::Period);
        // ...and the period trace replays bitwise
        let mut replay = ClusterSim::from_trace(&trace).unwrap();
        for (i, rec) in trace.outcomes.iter().enumerate() {
            let mut out = StepOutcome::default();
            replay.replay_into(&mut out).unwrap();
            assert!(rec.matches(&out), "period replay step {i}");
        }
    }

    #[test]
    fn single_restart_flag_restores_unchecked_survivor_timing() {
        // the crafted re-check case from sim::comm: root straggler on a
        // tree, tight second budget — recursive (default) and
        // single-restart semantics must differ, the flag must restore
        // the legacy value, and each arm must stay bitwise equal to its
        // event-queue oracle
        let mut c = config(5, 1);
        c.microbatch_std = 0.0;
        c.topology = Some(crate::topology::TopologyKind::Tree);
        c.link_latency = 1e-3;
        c.link_bandwidth = 1e9;
        c.grad_bytes = 4e6;
        c.stragglers = crate::config::StragglerKind::Fatal {
            worker: 0,
            from_step: 0,
        };
        let policy =
            DropPolicy::per_phase_deadline(vec![1.0, 0.004, 0.0, 0.0]);
        let mk = |single: bool, reference: bool| {
            let mut sim =
                ClusterSim::new(&c, 3).with_policy(policy.clone());
            if single {
                sim = sim.with_single_restart();
            }
            if reference {
                sim = sim.with_reference_timing();
            }
            sim
        };
        let rec = mk(false, false).step(None);
        let rec_oracle = mk(false, true).step(None);
        let single = mk(true, false).step(None);
        let single_oracle = mk(true, true).step(None);
        assert_eq!(rec.iter_time.to_bits(), rec_oracle.iter_time.to_bits());
        assert_eq!(rec.completed, rec_oracle.completed);
        assert_eq!(
            single.iter_time.to_bits(),
            single_oracle.iter_time.to_bits()
        );
        assert_eq!(single.completed, single_oracle.completed);
        assert_ne!(
            rec.iter_time.to_bits(),
            single.iter_time.to_bits(),
            "the re-check must change this crafted case"
        );
        assert!(
            rec.total_completed() < single.total_completed(),
            "recursive re-check drops more: {} vs {}",
            rec.total_completed(),
            single.total_completed()
        );
        // the config-level flag reaches the sim
        let mut cfg2 = c.clone();
        cfg2.single_restart = true;
        let via_cfg = ClusterSim::new(&cfg2, 3)
            .with_policy(policy.clone())
            .step(None);
        assert_eq!(via_cfg.iter_time.to_bits(), single.iter_time.to_bits());
        // ...and survives the trace round trip: a run recorded under the
        // flag replays bitwise, because the metadata carries it
        let mut rec_sim = ClusterSim::new(&cfg2, 3).with_policy(policy);
        rec_sim.start_recording();
        let mut out = StepOutcome::default();
        for _ in 0..3 {
            rec_sim.step_installed_into(&mut out);
        }
        let trace = rec_sim.finish_recording().unwrap();
        assert!(trace.meta.single_restart);
        let parsed =
            crate::sim::TraceRecord::parse(&trace.to_json()).unwrap();
        assert!(parsed.meta.single_restart, "flag survives the JSON");
        let mut replay = ClusterSim::from_trace(&parsed).unwrap();
        for (i, rec) in parsed.outcomes.iter().enumerate() {
            let mut out = StepOutcome::default();
            replay.replay_into(&mut out).unwrap();
            assert!(rec.matches(&out), "single-restart replay step {i}");
        }
        // rewinding replays the same outcomes again, bit for bit
        replay.rewind_replay().unwrap();
        let mut out = StepOutcome::default();
        replay.replay_into(&mut out).unwrap();
        assert!(parsed.outcomes[0].matches(&out), "rewound replay");
        // no source -> typed error
        assert!(ClusterSim::new(&c, 1).rewind_replay().is_err());
    }

    #[test]
    fn local_sgd_threshold_drops_steps() {
        let mut c = config(4, 1);
        c.stragglers = crate::config::StragglerKind::Uniform { p: 0.5, delay: 1.0 };
        let mut sim = ClusterSim::new(&c, 11);
        let out = sim.local_sgd_period(20, Some(0.9));
        assert!(out.total_completed() < 4 * 20);
        assert!(out.total_completed() > 0);
    }

    // ---- the scenario lab: dynamic membership under fault plans ----

    fn churn_config(workers: usize, accums: usize) -> ClusterConfig {
        let mut c = config(workers, accums);
        c.noise = NoiseKind::Exponential { mean: 0.4 };
        c.link_latency = 1e-4;
        c.link_bandwidth = 1e9;
        c.grad_bytes = 4e6;
        c
    }

    #[test]
    fn churn_compiled_equals_oracle_on_every_topology_and_policy() {
        // dynamic membership degrades the collective through the per-k
        // survivor cache (compiled) or a fresh k-schedule (oracle);
        // both timing paths must stay a bitwise pair through fails,
        // rejoins, slowdowns, and drift, under every drop policy shape
        let plan = crate::sim::FaultPlan::parse(
            "fail@2:w3,rejoin+4;fail@5:w0,rejoin+2;slow@1:w1,x2.5,for6;\
             drift@4:w2,+0.05",
        )
        .unwrap();
        for kind in crate::topology::TopologyKind::ALL {
            for spec in ["tau=3", "deadline=1", "phase-deadline=1.0/0.5"] {
                let mut c = churn_config(6, 4);
                c.topology = Some(kind);
                let policy = DropPolicy::parse(spec).unwrap();
                let mut fast = ClusterSim::new(&c, 99)
                    .with_policy(policy.clone())
                    .with_fault_plan(plan.clone());
                let mut slow = ClusterSim::new(&c, 99)
                    .with_policy(policy)
                    .with_fault_plan(plan.clone())
                    .with_reference_timing();
                let mut faulted_steps = 0;
                let mut out_f = StepOutcome::default();
                let mut out_s = StepOutcome::default();
                for step in 0..10 {
                    fast.step_installed_into(&mut out_f);
                    slow.step_installed_into(&mut out_s);
                    assert_eq!(
                        out_f.iter_time.to_bits(),
                        out_s.iter_time.to_bits(),
                        "{} policy={spec} step={step}",
                        kind.name()
                    );
                    assert_eq!(out_f.completed, out_s.completed);
                    assert!(out_f.iter_time.is_finite());
                    if out_f.completed.iter().any(|&d| d == 0) {
                        faulted_steps += 1;
                    }
                }
                assert!(
                    faulted_steps >= 4,
                    "membership must actually vary: {faulted_steps}"
                );
            }
        }
    }

    #[test]
    fn churn_zero_and_one_survivor_degenerates() {
        // satellite guard: an all-dead step completes instantly with
        // zero compute, a lone survivor reduces as a 1-member
        // collective — finite, NaN-free, and balance-exact on both
        // timing paths, with and without a comm deadline
        for (spec, survivors) in [
            ("fail@1:w0;fail@1:w1;fail@1:w2", 0usize),
            ("fail@1:w0;fail@1:w1", 1usize),
        ] {
            let plan = crate::sim::FaultPlan::parse(spec).unwrap();
            for reference in [false, true] {
                for policy in ["none", "deadline=1", "phase-deadline=1.0"] {
                    let mut c = churn_config(3, 4);
                    c.topology =
                        Some(crate::topology::TopologyKind::Ring);
                    let mut sim = ClusterSim::new(&c, 7)
                        .with_policy(DropPolicy::parse(policy).unwrap())
                        .with_fault_plan(plan.clone());
                    if reference {
                        sim = sim.with_reference_timing();
                    }
                    let mut rec = crate::obs::ObsRecorder::new(3);
                    let mut out = StepOutcome::default();
                    for step in 0..3 {
                        sim.step_installed_observed(&mut out, &mut rec);
                        assert!(
                            out.iter_time.is_finite(),
                            "{spec} step={step}"
                        );
                        assert!(!out.drop_rate(4).is_nan());
                        if step >= 1 {
                            let live = out
                                .completed
                                .iter()
                                .filter(|&&d| d > 0)
                                .count();
                            assert!(
                                live <= survivors,
                                "{spec}: {live} live, want <= {survivors}"
                            );
                            if survivors == 0 {
                                assert_eq!(out.compute_time, 0.0);
                                assert_eq!(out.iter_time, 0.0);
                            }
                        }
                    }
                    assert!(
                        rec.microbatches_balance(),
                        "{spec} policy={policy} reference={reference}"
                    );
                    assert!(rec.drops.worker_fault > 0);
                }
            }
        }
    }

    #[test]
    fn churn_rejoin_restores_full_membership() {
        // a failed worker that rejoins computes again with its RNG
        // stream undisturbed: after the rejoin the run is bitwise the
        // fault-free run again (dead steps consume no draws)
        let plan =
            crate::sim::FaultPlan::parse("fail@2:w1,rejoin+3").unwrap();
        let c = churn_config(4, 3);
        let mut churned =
            ClusterSim::new(&c, 21).with_fault_plan(plan.clone());
        let mut clean = ClusterSim::new(&c, 21);
        for step in 0..8 {
            let a = churned.step(None);
            let b = clean.step(None);
            if (2..5).contains(&step) {
                assert_eq!(a.completed[1], 0, "dead at step {step}");
                assert_eq!(a.worker_compute[1], 0.0);
            } else {
                assert_eq!(
                    a.iter_time.to_bits(),
                    b.iter_time.to_bits(),
                    "step {step} must match the fault-free run"
                );
                assert_eq!(a.completed, b.completed);
                assert_eq!(a.total_completed(), 4 * 3);
            }
        }
    }

    #[test]
    fn churn_local_sgd_periods_pair_bitwise() {
        // the Local-SGD period path routes through the same faulted
        // finish: compiled and oracle stay a pair, dead seats idle
        let plan = crate::sim::FaultPlan::parse(
            "fail@1:w2,rejoin+2;slow@0:w0,x1.5",
        )
        .unwrap();
        let mut c = churn_config(4, 1);
        c.topology = Some(crate::topology::TopologyKind::Tree);
        let policy = DropPolicy::parse("local-sgd=3+tau=2.0").unwrap();
        let mut fast = ClusterSim::new(&c, 31)
            .with_policy(policy.clone())
            .with_fault_plan(plan.clone());
        let mut slow = ClusterSim::new(&c, 31)
            .with_policy(policy)
            .with_fault_plan(plan)
            .with_reference_timing();
        let mut out_f = StepOutcome::default();
        let mut out_s = StepOutcome::default();
        for period in 0..5 {
            fast.step_installed_into(&mut out_f);
            slow.step_installed_into(&mut out_s);
            assert_eq!(
                out_f.iter_time.to_bits(),
                out_s.iter_time.to_bits(),
                "period {period}"
            );
            assert_eq!(out_f.completed, out_s.completed);
        }
    }

    #[test]
    fn churn_record_replay_reproduces_outcomes_bitwise() {
        // a recorded churn run carries its scenario in the trace meta;
        // from_trace reinstalls the plan so the replay reproduces the
        // membership history — and every outcome — bit for bit on both
        // timing paths, through the JSON round trip
        let plan = crate::sim::FaultPlan::parse(
            "fail@2:w1,rejoin+2;slow@1:w0,x2.0,for3",
        )
        .unwrap();
        let mut c = churn_config(4, 3);
        c.topology = Some(crate::topology::TopologyKind::Ring);
        let policy = DropPolicy::parse("tau=2.5+deadline=1").unwrap();
        let mut live = ClusterSim::new(&c, 0xC4A0)
            .with_policy(policy)
            .with_fault_plan(plan.clone());
        live.start_recording();
        let mut out = StepOutcome::default();
        for _ in 0..6 {
            live.step_installed_into(&mut out);
        }
        let trace = live.finish_recording().unwrap();
        assert_eq!(trace.meta.scenario.as_deref(), Some(plan.spec().as_str()));
        let parsed =
            crate::sim::TraceRecord::parse(&trace.to_json()).unwrap();
        assert_eq!(parsed.meta.scenario, trace.meta.scenario);
        for reference in [false, true] {
            let mut replay = ClusterSim::from_trace(&parsed).unwrap();
            assert_eq!(
                replay.fault_plan().map(super::super::fault::FaultPlan::spec),
                Some(plan.spec()),
                "from_trace reinstalls the scenario"
            );
            if reference {
                replay = replay.with_reference_timing();
            }
            for (i, rec) in parsed.outcomes.iter().enumerate() {
                let mut out = StepOutcome::default();
                replay.replay_into(&mut out).unwrap();
                assert!(
                    rec.matches(&out),
                    "churn replay step {i} reference={reference}"
                );
            }
        }
    }

    #[test]
    fn churn_empty_plan_is_inert_and_accessor_reports() {
        // installing the empty plan is a no-op (bitwise the plain run);
        // a real plan is reported back by the accessor
        let c = churn_config(3, 2);
        let mut plain = ClusterSim::new(&c, 5);
        let mut noop = ClusterSim::new(&c, 5)
            .with_fault_plan(crate::sim::FaultPlan::default());
        assert!(noop.fault_plan().is_none());
        for _ in 0..4 {
            let a = plain.step(None);
            let b = noop.step(None);
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
        let plan = crate::sim::FaultPlan::parse("fail@1:w0").unwrap();
        let sim = ClusterSim::new(&c, 5).with_fault_plan(plan.clone());
        assert_eq!(sim.fault_plan(), Some(&plan));
    }

    #[test]
    fn churn_step_indexed_noise_is_reproducible() {
        // SharedBurst / Drift are pure functions of (worker, step):
        // two sims with the same seed agree to the bit, and the burst
        // actually perturbs the timeline relative to quiet noise
        for noise in [
            // seed 4's burst clock fires in windows 0 and 2, so the
            // 6-step horizon is guaranteed to see a burst
            NoiseKind::SharedBurst {
                p: 0.5,
                period: 2,
                delay: 3.0,
                subset: 3,
                seed: 4,
            },
            NoiseKind::Drift { sigma: 0.2, seed: 9 },
        ] {
            let mut c = config(4, 3);
            c.noise = noise.clone();
            let mut a = ClusterSim::new(&c, 17);
            let mut b = ClusterSim::new(&c, 17);
            let mut quiet_cfg = config(4, 3);
            quiet_cfg.noise = NoiseKind::None;
            let mut quiet = ClusterSim::new(&quiet_cfg, 17);
            let mut diverged = false;
            for _ in 0..6 {
                let x = a.step(None);
                let y = b.step(None);
                let q = quiet.step(None);
                assert_eq!(x.iter_time.to_bits(), y.iter_time.to_bits());
                if x.iter_time.to_bits() != q.iter_time.to_bits() {
                    diverged = true;
                }
            }
            assert!(diverged, "{noise:?} must perturb the timeline");
        }
    }
}
