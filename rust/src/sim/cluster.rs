//! Virtual-time cluster simulator: the timing semantics of synchronous
//! training, DropCompute (Algorithm 1) and Local-SGD, over any
//! [`LatencyModel`] and [`CommModel`].
//!
//! This mirrors the paper's own methodology: runtime results (Figs 1, 2,
//! 4, 6, 13, 14) are driven by injected latency distributions; the
//! *training semantics* (which micro-batches survive) feed the real
//! trainer via [`StepOutcome::completed`].

use crate::config::ClusterConfig;
use crate::policy::DropPolicy;
use crate::rng::Xoshiro256pp;

use super::comm::CommModel;
use super::compiled::PhaseBounded;
use super::noise::LatencyModel;
use super::trace::Trace;

/// When a worker notices its compute budget `tau` is exhausted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptionMode {
    /// Theory model: worker stops exactly at `tau`
    /// (`T~_n = min(tau, T_n)`; micro-batch m survives iff `T_n^(m) < tau`).
    Preemptive,
    /// Reference-implementation model (paper §6 Limitations): the timeout
    /// is checked between accumulations, so the crossing micro-batch
    /// finishes and counts.
    BetweenAccumulations,
}

/// Timing outcome of one synchronous step.
///
/// Reusable: hot loops keep one value and refill it through
/// [`ClusterSim::step_into`], which recycles the per-worker vectors
/// instead of allocating fresh ones every step.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Compute time per worker (`T~_n`).
    pub worker_compute: Vec<f64>,
    /// Micro-batches completed per worker (`M~_n`).
    pub completed: Vec<usize>,
    /// Max-over-workers compute time (`min(tau, T)` under DropCompute).
    pub compute_time: f64,
    /// Full iteration time including communication.
    pub iter_time: f64,
}

impl StepOutcome {
    pub fn total_completed(&self) -> usize {
        self.completed.iter().sum()
    }

    /// Fraction of scheduled micro-batches that were dropped. A
    /// zero-worker outcome (or `accums == 0`) schedules nothing, so
    /// nothing was dropped: 0.0, not NaN.
    pub fn drop_rate(&self, accums: usize) -> f64 {
        let scheduled = self.completed.len() * accums;
        if scheduled == 0 {
            return 0.0;
        }
        1.0 - self.total_completed() as f64 / scheduled as f64
    }
}

/// The simulated cluster.
pub struct ClusterSim {
    pub workers: usize,
    pub accums: usize,
    model: LatencyModel,
    comm: CommModel,
    pub preemption: PreemptionMode,
    /// The installed drop policy — the single source of truth for
    /// [`Self::step_with`] and friends. The legacy knobs below are its
    /// resolved form, precomputed at install time so stepping pays no
    /// per-step policy resolution.
    policy: DropPolicy,
    /// Resolved compute threshold of the installed policy
    /// ([`crate::policy::EffectivePolicy::tau`]).
    eff_tau: Option<f64>,
    /// Resolved Local-SGD period of the installed policy.
    eff_h: Option<usize>,
    /// Bounded-wait (DropComm) deadline: workers arriving later than
    /// this after the first arrival are excluded from the reduction
    /// (their step contribution is dropped and the sum reweighted over
    /// the survivors). `None` = wait for everyone.
    comm_drop: Option<f64>,
    /// Cumulative per-phase membership cutoff offsets
    /// ([`crate::policy::cumulative_offsets`], with any step deadline
    /// folded into the entry checkpoint). Empty = no per-phase policy.
    phase_cutoffs: Vec<f64>,
    /// Reusable per-worker dropped mask for the per-phase scan.
    drop_mask: Vec<bool>,
    /// Full-cluster schedule, built once (the worker count is fixed
    /// for a sim's lifetime) so the per-step timing doesn't rebuild
    /// O(N^2) transfers. `None` for the fixed-`T^c` model. Kept as the
    /// event-queue reference oracle behind
    /// [`Self::with_reference_timing`].
    schedule: Option<crate::topology::Schedule>,
    /// The schedule lowered to the heapless fast path
    /// ([`super::compiled::CompiledSchedule`]): flat src/dst/hop arrays,
    /// hop costs precomputed at construction.
    compiled: Option<super::compiled::CompiledSchedule>,
    /// Reusable timing buffers so steady-state stepping is
    /// allocation-free.
    scratch: super::compiled::ScheduleScratch,
    /// Per-survivor-count compiled schedules for the DropComm exclusion
    /// branch ([`super::survivor::SurvivorScheduleCache`]): after
    /// warmup a drop step allocates nothing and builds no schedule.
    survivors: super::survivor::SurvivorScheduleCache,
    /// `false` routes collective timing through the event-queue
    /// reference instead of the compiled fast path (perf baselines and
    /// the bitwise-equality property tests).
    use_compiled: bool,
    /// Independent RNG stream per worker (decentralized by construction).
    streams: Vec<Xoshiro256pp>,
    /// Reusable micro-batch sample buffer: each worker's accumulation
    /// run is drawn into it in one batched call.
    sample_buf: Vec<f64>,
    /// Monotone step counter (drives step-indexed failures).
    step_idx: usize,
}

impl ClusterSim {
    pub fn new(cfg: &ClusterConfig, seed: u64) -> Self {
        let comm = match cfg.topology {
            Some(kind) => CommModel::Topology {
                kind,
                latency: cfg.link_latency,
                bandwidth: cfg.link_bandwidth,
                bytes: cfg.grad_bytes,
            },
            None => CommModel::Fixed(cfg.comm_latency),
        };
        Self::with_model(
            cfg.workers,
            cfg.accumulations,
            LatencyModel::from_config(cfg),
            comm,
            seed,
        )
        .with_policy(DropPolicy::from_cluster(cfg))
    }

    pub fn with_model(
        workers: usize,
        accums: usize,
        model: LatencyModel,
        comm: CommModel,
        seed: u64,
    ) -> Self {
        let root = Xoshiro256pp::seed_from_u64(seed);
        let streams = (0..workers).map(|n| root.split(n as u64)).collect();
        let schedule = comm.schedule_for(workers);
        // compile from the schedule just built rather than rebuilding
        // O(N^2) transfers inside compile_for — sweeps construct one
        // sim per grid point, so this fixed cost is paid per point
        let compiled = match (&schedule, comm.link_params()) {
            (Some(s), Some((latency, bandwidth, bytes))) => {
                Some(super::compiled::CompiledSchedule::compile(
                    s, latency, bandwidth, bytes,
                ))
            }
            _ => None,
        };
        let survivors = super::survivor::SurvivorScheduleCache::new(&comm);
        Self {
            workers,
            accums,
            model,
            comm,
            preemption: PreemptionMode::Preemptive,
            policy: DropPolicy::None,
            eff_tau: None,
            eff_h: None,
            comm_drop: None,
            phase_cutoffs: Vec::new(),
            drop_mask: Vec::new(),
            schedule,
            compiled,
            scratch: super::compiled::ScheduleScratch::default(),
            survivors,
            use_compiled: true,
            streams,
            sample_buf: Vec::new(),
            step_idx: 0,
        }
    }

    pub fn with_preemption(mut self, mode: PreemptionMode) -> Self {
        self.preemption = mode;
        self
    }

    /// Install a [`DropPolicy`]: the unified drop-decision surface.
    /// Resolves the policy once (compute threshold, preemption model,
    /// step-level deadline, cumulative per-phase cutoffs, Local-SGD
    /// period) so [`Self::step_installed_into`] pays nothing per step.
    pub fn with_policy(mut self, policy: DropPolicy) -> Self {
        self.set_policy(&policy);
        self
    }

    /// [`Self::with_policy`] in place.
    pub fn set_policy(&mut self, policy: &DropPolicy) {
        let eff = policy.effective();
        self.eff_tau = eff.tau;
        if eff.tau.is_some() {
            // a policy without a compute clause leaves the (builder-set)
            // preemption mode alone
            self.preemption = eff.preemption;
        }
        self.eff_h = eff.local_sgd_h;
        self.phase_cutoffs = eff.merged_phase_offsets();
        // a per-phase policy subsumes the step deadline (folded into
        // its entry checkpoint by merged_phase_offsets)
        self.comm_drop = if self.phase_cutoffs.is_empty() {
            eff.step_deadline
        } else {
            None
        };
        self.policy = policy.clone();
    }

    /// The installed policy.
    pub fn policy(&self) -> &DropPolicy {
        &self.policy
    }

    /// Route collective timing through the per-phase event-queue
    /// reference instead of the compiled heapless pass. The two are
    /// bitwise identical (property-tested); this exists as the oracle
    /// for those tests and as the "before" arm of perf benchmarks.
    pub fn with_reference_timing(mut self) -> Self {
        self.use_compiled = false;
        self
    }

    /// Enable/disable the step-level bounded-wait (DropComm)
    /// collective. Legacy shim for [`Self::with_policy`] with a
    /// [`DropPolicy::CommDeadline`]; replaces the installed policy's
    /// clauses (per-phase cutoffs, compute and Local-SGD included) so
    /// the installed state stays internally consistent. The
    /// builder-level preemption mode is preserved, as it always was —
    /// it only matters with a per-call `step(Some(tau))` threshold.
    pub fn with_comm_drop(mut self, deadline: Option<f64>) -> Self {
        let policy = match deadline {
            Some(d) => DropPolicy::comm_deadline(d),
            None => DropPolicy::None,
        };
        self.set_policy(&policy);
        self
    }

    /// Adopt a warm survivor-schedule cache (e.g. from a sweep's
    /// [`crate::sweep::SurvivorCachePool`]). A cache built for a
    /// different comm model is discarded — memoization must never
    /// change results, only skip compiles.
    pub fn with_survivor_cache(
        mut self,
        cache: super::survivor::SurvivorScheduleCache,
    ) -> Self {
        if cache.matches(&self.comm) {
            self.survivors = cache;
        }
        self
    }

    /// Hand the survivor cache back (for pooling across sims sharing a
    /// comm model), leaving a fresh empty one behind.
    pub fn take_survivor_cache(&mut self) -> super::survivor::SurvivorScheduleCache {
        std::mem::replace(
            &mut self.survivors,
            super::survivor::SurvivorScheduleCache::new(&self.comm),
        )
    }

    pub fn latency_model(&self) -> &LatencyModel {
        &self.model
    }

    pub fn comm_model(&self) -> &CommModel {
        &self.comm
    }

    /// Serial comm constant `T^c` for the analytical model.
    pub fn comm_latency(&self) -> f64 {
        self.comm.serial_latency(self.workers)
    }

    /// Full-cluster collective completion for `arrivals`: the compiled
    /// heapless pass when available, else the cached-schedule event
    /// reference, else the fixed-`T^c` model.
    fn collective_time(&mut self, arrivals: &[f64]) -> f64 {
        if self.use_compiled {
            if let Some(c) = self.compiled.as_ref() {
                return c.completion_with(arrivals, &mut self.scratch);
            }
        }
        self.comm.completion_time_with(arrivals, self.schedule.as_ref())
    }

    /// Common tail of a simulated step: the collective. Under a
    /// comm-side drop policy late workers are excluded — their
    /// completed micro-batches are zeroed (dropped work) and the
    /// survivors' reduction sets the iteration time. Operates in place
    /// on `out`'s already-filled per-worker vectors.
    fn finish_into(&mut self, out: &mut StepOutcome) {
        // max over an empty set folds to -inf; a zero-worker outcome
        // computes for zero seconds
        out.compute_time = if out.worker_compute.is_empty() {
            0.0
        } else {
            out.worker_compute
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max)
        };
        if !self.phase_cutoffs.is_empty() {
            out.iter_time = self.per_phase_iter_time(out);
            return;
        }
        out.iter_time = match self.comm_drop {
            None => self.collective_time(&out.worker_compute),
            Some(deadline) => {
                // the shared membership rule, evaluated allocation-free
                // for the common no-drop case
                let cutoff = crate::sim::comm::bounded_wait_cutoff(
                    &out.worker_compute,
                    deadline,
                );
                if out.worker_compute.iter().all(|&a| a <= cutoff) {
                    // common path: nobody missed the deadline — plain
                    // collective over the compiled full-N schedule
                    self.collective_time(&out.worker_compute)
                } else {
                    // drop path: zero the late workers' contributions
                    // and count the k survivors while at it
                    let mut k = 0usize;
                    for (done, &a) in
                        out.completed.iter_mut().zip(&out.worker_compute)
                    {
                        if a > cutoff {
                            *done = 0;
                        } else {
                            k += 1;
                        }
                    }
                    if self.use_compiled {
                        // the k-survivor collective starts at the
                        // membership close (`cutoff`); memoized per k —
                        // no allocation, no schedule rebuild
                        self.survivors.completion(k, cutoff)
                    } else {
                        let (_, t) = self.comm.bounded_wait_completion(
                            &out.worker_compute,
                            deadline,
                        );
                        t
                    }
                }
            }
        };
    }

    /// The per-phase-deadline collective: compiled scan
    /// ([`super::compiled::CompiledSchedule::bounded_completion_with`])
    /// when available, else the event-queue oracle / fixed-`T^c` lumped
    /// form ([`CommModel::per_phase_bounded_completion`]) — bitwise
    /// identical pair, property-tested. Zeroes dropped workers'
    /// completed counts; the survivors' restart reuses the per-k
    /// compiled cache, so drop-heavy per-phase stepping is as
    /// allocation-free as the step-level drop path.
    fn per_phase_iter_time(&mut self, out: &mut StepOutcome) -> f64 {
        if self.use_compiled {
            if let Some(c) = self.compiled.as_ref() {
                let res = c.bounded_completion_with(
                    &out.worker_compute,
                    &self.phase_cutoffs,
                    &mut self.scratch,
                    &mut self.drop_mask,
                );
                return match res {
                    PhaseBounded::Complete(t) => t,
                    PhaseBounded::Dropped { survivors, close } => {
                        for (done, &d) in
                            out.completed.iter_mut().zip(&self.drop_mask)
                        {
                            if d {
                                *done = 0;
                            }
                        }
                        if survivors == 0 {
                            close.max(0.0)
                        } else {
                            self.survivors.completion(survivors, close)
                        }
                    }
                };
            }
        }
        // event-queue reference timing, or the fixed-T^c model (which
        // has no phase structure — budgets lump to their total)
        let (mask, t) = self.comm.per_phase_bounded_completion(
            &out.worker_compute,
            &self.phase_cutoffs,
            self.schedule.as_ref(),
        );
        for (done, &alive) in out.completed.iter_mut().zip(&mask) {
            if !alive {
                *done = 0;
            }
        }
        t
    }

    /// Simulate one step (or Local-SGD period, if the policy carries
    /// one) under `policy`, installing it first when it differs from
    /// the current one — a cheap equality check, so sweeps that step
    /// the same policy repeatedly pay nothing.
    pub fn step_with(&mut self, policy: &DropPolicy) -> StepOutcome {
        let mut out = StepOutcome::default();
        self.step_with_into(policy, &mut out);
        out
    }

    /// [`Self::step_with`] into a caller-owned outcome.
    pub fn step_with_into(
        &mut self,
        policy: &DropPolicy,
        out: &mut StepOutcome,
    ) {
        if *policy != self.policy {
            self.set_policy(policy);
        }
        self.step_installed_into(out);
    }

    /// One step under the already-installed policy
    /// ([`Self::with_policy`]): a `LocalSgdPeriod` clause routes to
    /// [`Self::local_sgd_period_into`] (threshold per local step),
    /// anything else to [`Self::step_into`].
    pub fn step_installed_into(&mut self, out: &mut StepOutcome) {
        match self.eff_h {
            Some(h) => self.local_sgd_period_into(h, self.eff_tau, out),
            None => self.step_into(self.eff_tau, out),
        }
    }

    /// Simulate one synchronous step; `threshold = None` is the
    /// baseline. Legacy shim: the threshold rides per call while the
    /// comm side comes from the installed policy — new code should
    /// install a full [`DropPolicy`] and use [`Self::step_with`].
    pub fn step(&mut self, threshold: Option<f64>) -> StepOutcome {
        let mut out = StepOutcome::default();
        self.step_into(threshold, &mut out);
        out
    }

    /// [`Self::step`] into a caller-owned outcome, recycling its
    /// per-worker vectors — with a schedule-driven comm model the whole
    /// step is allocation-free in steady state.
    ///
    /// Each worker's accumulation run is drawn in one batched
    /// [`LatencyModel::fill_microbatches`] call (enum-dispatched once
    /// per run, not per draw), then scanned against the threshold. The
    /// bounded fill stops drawing exactly where the sequential
    /// preemption loop stopped, so per-worker streams — and therefore
    /// all seeded results — are bitwise identical to the un-batched
    /// code (property-tested in `tests/perf_equivalence.rs`).
    pub fn step_into(&mut self, threshold: Option<f64>, out: &mut StepOutcome) {
        let step_idx = self.step_idx;
        self.step_idx += 1;
        out.worker_compute.clear();
        out.completed.clear();
        out.worker_compute.reserve(self.workers);
        out.completed.reserve(self.workers);
        for n in 0..self.workers {
            let mut t = self.model.sample_straggler_at(
                n,
                step_idx,
                &mut self.streams[n],
            );
            let mut done = 0usize;
            match (threshold, self.preemption) {
                (None, _) => {
                    self.model.fill_microbatches(
                        n,
                        self.accums,
                        &mut self.sample_buf,
                        &mut self.streams[n],
                    );
                    for &s in &self.sample_buf {
                        t += s;
                    }
                    done = self.accums;
                }
                (Some(tau), PreemptionMode::Preemptive) => {
                    let filled = self.model.fill_microbatches_bounded(
                        n,
                        t,
                        tau,
                        self.accums,
                        &mut self.sample_buf,
                        &mut self.streams[n],
                    );
                    for &s in &self.sample_buf[..filled] {
                        let next = t + s;
                        if next < tau {
                            t = next;
                            done += 1;
                        } else {
                            break;
                        }
                    }
                    // The timeout fires on the wall clock, so even a
                    // stalled compute pipeline (Fatal stragglers) is
                    // preempted at exactly tau — the worker joins the
                    // AllReduce with whatever it has (possibly nothing).
                    if done < self.accums {
                        t = tau;
                    }
                }
                (Some(tau), PreemptionMode::BetweenAccumulations) => {
                    let filled = self.model.fill_microbatches_bounded(
                        n,
                        t,
                        tau,
                        self.accums,
                        &mut self.sample_buf,
                        &mut self.streams[n],
                    );
                    for &s in &self.sample_buf[..filled] {
                        t += s;
                        done += 1;
                        if t >= tau {
                            break;
                        }
                    }
                }
            }
            out.worker_compute.push(t);
            out.completed.push(done);
        }
        self.finish_into(out);
    }

    /// Simulate one Local-SGD synchronization period: `h` local steps of
    /// one micro-batch group each, then a sync. DropCompute integrates by
    /// thresholding each local step's compute (App. B.3).
    pub fn local_sgd_period(&mut self, h: usize, threshold: Option<f64>)
        -> StepOutcome
    {
        let mut out = StepOutcome::default();
        self.local_sgd_period_into(h, threshold, &mut out);
        out
    }

    /// [`Self::local_sgd_period`] into a caller-owned outcome, recycling
    /// its per-worker vectors (the allocating form built two fresh
    /// `Vec`s per period).
    ///
    /// Workers are processed worker-major: each worker owns its stream,
    /// so its draw order — straggler then micro-batch, per local step —
    /// is unchanged from the local-major loop and all seeded results
    /// stay bitwise identical (property-tested). When the straggler
    /// scenario consumes no randomness for a worker
    /// ([`LatencyModel::straggler_draws`]), its h micro-batches are
    /// drawn in one batched fill; when it flips a coin per local step,
    /// the fused [`LatencyModel::fill_local_steps`] batches the
    /// interleaved (coin, micro-batch) pairs instead — either way, one
    /// dispatch per period, zero per-draw branches.
    pub fn local_sgd_period_into(
        &mut self,
        h: usize,
        threshold: Option<f64>,
        out: &mut StepOutcome,
    ) {
        let step_idx = self.step_idx;
        self.step_idx += 1;
        out.worker_compute.clear();
        out.completed.clear();
        out.worker_compute.resize(self.workers, 0.0);
        out.completed.resize(self.workers, 0);
        for n in 0..self.workers {
            let mut compute = 0.0f64;
            let mut done = 0usize;
            let mut tally = |t: f64| match threshold {
                Some(tau) => {
                    if t < tau {
                        done += 1;
                        compute += t;
                    } else {
                        compute += tau;
                    }
                }
                None => {
                    done += 1;
                    compute += t;
                }
            };
            if self.model.straggler_draws(n) {
                // straggler coin flips interleave with micro-batch draws
                // in this worker's stream: the fused fill keeps the
                // sequential (coin, sample) order draw for draw while
                // paying the straggler/noise dispatch once per period
                self.model.fill_local_steps(
                    n,
                    h,
                    &mut self.sample_buf,
                    &mut self.streams[n],
                );
                for &t in &self.sample_buf {
                    tally(t);
                }
            } else {
                // straggle is a pure function of (worker, step): draw the
                // whole period's micro-batches in one batched fill
                let straggle = self.model.sample_straggler_at(
                    n,
                    step_idx,
                    &mut self.streams[n],
                );
                self.model.fill_microbatches(
                    n,
                    h,
                    &mut self.sample_buf,
                    &mut self.streams[n],
                );
                for &s in &self.sample_buf {
                    tally(straggle + s);
                }
            }
            out.worker_compute[n] = compute;
            out.completed[n] = done;
        }
        self.finish_into(out);
    }

    /// Record a no-drop latency trace of `iters` iterations — the input
    /// of Algorithm 2 and of the Fig 4 post-analysis.
    pub fn record_trace(&mut self, iters: usize) -> Trace {
        let mut trace = Trace::new(iters, self.workers, self.accums);
        for i in 0..iters {
            let step_idx = self.step_idx;
            self.step_idx += 1;
            for n in 0..self.workers {
                let straggle = self.model.sample_straggler_at(
                    n,
                    step_idx,
                    &mut self.streams[n],
                );
                self.model.fill_microbatches(
                    n,
                    self.accums,
                    &mut self.sample_buf,
                    &mut self.streams[n],
                );
                for (m, &s) in self.sample_buf.iter().enumerate() {
                    let t = if m == 0 { s + straggle } else { s };
                    trace.set(i, n, m, t);
                }
            }
            trace.comm[i] = self.comm_latency();
        }
        trace
    }

    /// Mean iteration time over `iters` simulated steps (reuses one
    /// outcome buffer across the loop).
    pub fn mean_iter_time(&mut self, iters: usize, threshold: Option<f64>) -> f64 {
        let mut out = StepOutcome::default();
        let mut sum = 0.0;
        for _ in 0..iters {
            self.step_into(threshold, &mut out);
            sum += out.iter_time;
        }
        sum / iters as f64
    }

    /// Mean synchronization-period time over `periods` Local-SGD periods
    /// of `h` local steps each — the Local-SGD analogue of
    /// [`Self::mean_iter_time`], reusing one outcome buffer across the
    /// loop.
    pub fn mean_period_time(
        &mut self,
        periods: usize,
        h: usize,
        threshold: Option<f64>,
    ) -> f64 {
        let mut out = StepOutcome::default();
        let mut sum = 0.0;
        for _ in 0..periods {
            self.local_sgd_period_into(h, threshold, &mut out);
            sum += out.iter_time;
        }
        sum / periods as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, NoiseKind};

    fn config(workers: usize, accums: usize) -> ClusterConfig {
        ClusterConfig {
            workers,
            accumulations: accums,
            microbatch_mean: 0.45,
            microbatch_std: 0.02,
            comm_latency: 0.2,
            noise: NoiseKind::None,
            ..Default::default()
        }
    }

    #[test]
    fn baseline_step_all_complete() {
        let mut sim = ClusterSim::new(&config(8, 12), 0);
        let out = sim.step(None);
        assert_eq!(out.total_completed(), 8 * 12);
        assert!(out.iter_time > out.compute_time);
        assert!((out.iter_time - out.compute_time - 0.2).abs() < 1e-12);
        // with sigma=0.02 and M=12 the step should be ~5.4s
        assert!((out.compute_time - 5.4).abs() < 0.5, "{}", out.compute_time);
    }

    #[test]
    fn iteration_time_grows_with_workers() {
        // E[max of N] increases with N — the core scalability problem.
        let mut small = ClusterSim::new(&config(2, 12), 1);
        let mut large = ClusterSim::new(&config(128, 12), 1);
        let t_small = small.mean_iter_time(200, None);
        let t_large = large.mean_iter_time(200, None);
        assert!(t_large > t_small, "{t_large} vs {t_small}");
    }

    #[test]
    fn threshold_caps_compute_time() {
        let mut c = config(16, 12);
        c.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        let tau = 9.0;
        let mut sim = ClusterSim::new(&c, 2);
        for _ in 0..50 {
            let out = sim.step(Some(tau));
            assert!(out.compute_time <= tau + 1e-9);
            for (&t, &done) in out.worker_compute.iter().zip(&out.completed) {
                assert!(t <= tau + 1e-9);
                assert!(done <= 12);
            }
        }
    }

    #[test]
    fn dropcompute_faster_but_drops() {
        let mut c = config(64, 12);
        c.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        let mut base = ClusterSim::new(&c, 3);
        let mut dc = ClusterSim::new(&c, 3);
        let t_base = base.mean_iter_time(100, None);
        let mut dropped = 0usize;
        let mut total = 0usize;
        let mut t_dc = 0.0;
        for _ in 0..100 {
            let out = dc.step(Some(9.0));
            t_dc += out.iter_time / 100.0;
            dropped += 64 * 12 - out.total_completed();
            total += 64 * 12;
        }
        let rate = dropped as f64 / total as f64;
        assert!(t_dc < t_base, "dc {t_dc} vs base {t_base}");
        assert!(rate > 0.0 && rate < 0.5, "drop rate {rate}");
    }

    #[test]
    fn preemption_modes_differ_as_expected() {
        let mut c = config(4, 8);
        c.noise = NoiseKind::Exponential { mean: 0.3 };
        let tau = 2.0;
        let mut pre = ClusterSim::new(&c, 7)
            .with_preemption(PreemptionMode::Preemptive);
        let mut between = ClusterSim::new(&c, 7)
            .with_preemption(PreemptionMode::BetweenAccumulations);
        // Preemptive never exceeds tau; between-accums can overshoot.
        let mut overshoot = false;
        for _ in 0..200 {
            let a = pre.step(Some(tau));
            assert!(a.compute_time <= tau + 1e-9);
            let b = between.step(Some(tau));
            if b.compute_time > tau {
                overshoot = true;
            }
        }
        assert!(overshoot, "between-accumulations should overshoot sometimes");
    }

    #[test]
    fn fatal_worker_stalls_baseline_but_not_dropcompute() {
        // §2 robustness claim: a dead worker freezes synchronous
        // training; DropCompute degrades to the survivors.
        let mut c = config(6, 4);
        c.stragglers = crate::config::StragglerKind::Fatal {
            worker: 2,
            from_step: 3,
        };
        let mut base = ClusterSim::new(&c, 17);
        let mut dc = ClusterSim::new(&c, 17);
        for step in 0..6 {
            let b = base.step(None);
            let d = dc.step(Some(2.5));
            if step < 3 {
                assert!(b.iter_time < 100.0);
                assert_eq!(d.completed[2] > 0, true);
            } else {
                // baseline waits ~forever
                assert!(b.iter_time >= LatencyModel::FATAL_DELAY);
                // DropCompute: capped step, dead worker contributes 0
                assert!(d.iter_time < 10.0, "{}", d.iter_time);
                assert_eq!(d.completed[2], 0);
                assert!(d.total_completed() > 0);
            }
        }
    }

    #[test]
    fn comm_drop_excludes_stragglers_and_caps_iter_time() {
        // DropComm alone (no compute threshold): a fatally stalled
        // worker is excluded at the collective membership deadline, so
        // iteration time stays bounded — the comm-side dual of the
        // DropCompute robustness test below.
        let mut c = config(6, 4);
        c.stragglers = crate::config::StragglerKind::Fatal {
            worker: 2,
            from_step: 0,
        };
        c.topology = Some(crate::topology::TopologyKind::Ring);
        c.comm_drop_deadline = 2.0;
        let mut sim = ClusterSim::new(&c, 5);
        let out = sim.step(None);
        assert_eq!(out.completed[2], 0, "dropped worker contributes 0");
        assert_eq!(out.total_completed(), 5 * 4, "survivors all count");
        assert!(out.iter_time < 10.0, "{}", out.iter_time);
        // without DropComm the same cluster stalls
        c.comm_drop_deadline = 0.0;
        let mut base = ClusterSim::new(&c, 5);
        assert!(base.step(None).iter_time >= LatencyModel::FATAL_DELAY);
    }

    #[test]
    fn comm_drop_loose_deadline_changes_nothing() {
        let mut c = config(8, 6);
        c.noise = NoiseKind::Exponential { mean: 0.1 };
        let mut plain = ClusterSim::new(&c, 21);
        c.comm_drop_deadline = 1e6;
        let mut drop = ClusterSim::new(&c, 21);
        for _ in 0..20 {
            let a = plain.step(None);
            let b = drop.step(None);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
    }

    #[test]
    fn topology_config_drives_comm_model() {
        let mut c = config(8, 4);
        c.topology = Some(crate::topology::TopologyKind::Tree);
        c.link_latency = 1e-4;
        c.link_bandwidth = 1e9;
        c.grad_bytes = 4e6;
        let sim = ClusterSim::new(&c, 1);
        let want = crate::topology::TopologyKind::Tree
            .build(8)
            .uniform_cost(1e-4, 1e9, 4e6);
        assert!((sim.comm_latency() - want).abs() < 1e-12);
    }

    #[test]
    fn trace_dimensions_and_determinism() {
        let mut a = ClusterSim::new(&config(3, 5), 42);
        let mut b = ClusterSim::new(&config(3, 5), 42);
        let ta = a.record_trace(4);
        let tb = b.record_trace(4);
        assert_eq!(ta, tb);
        assert_eq!(ta.iters, 4);
        assert_eq!(ta.workers, 3);
        assert_eq!(ta.accums, 5);
    }

    #[test]
    fn local_sgd_period_counts() {
        let mut sim = ClusterSim::new(&config(4, 1), 9);
        let out = sim.local_sgd_period(8, None);
        assert_eq!(out.total_completed(), 4 * 8);
        // 8 local steps of ~0.45s each
        assert!((out.compute_time - 3.6).abs() < 0.5, "{}", out.compute_time);
    }

    #[test]
    fn drop_rate_guards_degenerate_outcomes() {
        // Regression: workers == 0 or accums == 0 used to divide by zero
        // and return NaN; an empty schedule drops nothing.
        let empty = StepOutcome::default();
        assert_eq!(empty.drop_rate(12), 0.0);
        let out = StepOutcome {
            worker_compute: vec![1.0, 1.0],
            completed: vec![0, 0],
            compute_time: 1.0,
            iter_time: 1.5,
        };
        assert_eq!(out.drop_rate(0), 0.0);
        assert!(!out.drop_rate(0).is_nan());
        // the normal case still reports real drops
        assert!((out.drop_rate(4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn step_into_reuses_buffers_and_matches_step() {
        let mut c = config(8, 6);
        c.noise = NoiseKind::Exponential { mean: 0.2 };
        c.topology = Some(crate::topology::TopologyKind::Ring);
        let mut a = ClusterSim::new(&c, 31);
        let mut b = ClusterSim::new(&c, 31);
        let mut out = StepOutcome::default();
        for _ in 0..10 {
            let fresh = a.step(Some(2.0));
            b.step_into(Some(2.0), &mut out);
            assert_eq!(fresh.completed, out.completed);
            assert_eq!(fresh.iter_time.to_bits(), out.iter_time.to_bits());
            assert_eq!(
                fresh.compute_time.to_bits(),
                out.compute_time.to_bits()
            );
        }
    }

    #[test]
    fn compiled_timing_bitwise_equals_reference() {
        // the compiled heapless pass and the event-queue oracle must
        // agree to the bit on every topology, with and without DropComm.
        for kind in crate::topology::TopologyKind::ALL {
            for deadline in [0.0, 1.5] {
                let mut c = config(12, 6);
                c.noise = NoiseKind::Exponential { mean: 0.4 };
                c.topology = Some(kind);
                c.link_latency = 1e-4;
                c.link_bandwidth = 1e9;
                c.grad_bytes = 4e6;
                c.comm_drop_deadline = deadline;
                let mut fast = ClusterSim::new(&c, 99);
                let mut slow =
                    ClusterSim::new(&c, 99).with_reference_timing();
                for _ in 0..15 {
                    let f = fast.step(Some(3.0));
                    let s = slow.step(Some(3.0));
                    assert_eq!(
                        f.iter_time.to_bits(),
                        s.iter_time.to_bits(),
                        "{} deadline={deadline}",
                        kind.name()
                    );
                    assert_eq!(f.completed, s.completed);
                }
            }
        }
    }

    #[test]
    fn finish_into_guards_zero_worker_outcome() {
        // Regression: a zero-worker step used to fold compute_time to
        // -inf (`fold(NEG_INFINITY, max)` over no elements). It must be
        // 0.0 — nothing computed for zero seconds — and stay finite
        // with and without DropComm.
        for deadline in [None, Some(1.0)] {
            let mut sim = ClusterSim::with_model(
                0,
                4,
                LatencyModel::from_config(&config(0, 4)),
                CommModel::Fixed(0.2),
                13,
            )
            .with_comm_drop(deadline);
            let out = sim.step(None);
            assert_eq!(out.compute_time, 0.0, "deadline={deadline:?}");
            assert!(out.compute_time.is_finite());
            assert_eq!(out.iter_time, 0.0);
            assert_eq!(out.drop_rate(4), 0.0);
            assert!(!out.drop_rate(4).is_nan());
        }
        // zero accumulations: workers arrive with only their straggle,
        // nothing scheduled, nothing dropped
        let mut sim = ClusterSim::new(&config(3, 0), 13);
        let out = sim.step(None);
        assert_eq!(out.compute_time, 0.0);
        assert_eq!(out.total_completed(), 0);
        assert_eq!(out.drop_rate(0), 0.0);
    }

    #[test]
    fn survivor_cache_drop_path_matches_reference() {
        // a drop on (nearly) every step: the cached survivor collective
        // against the event-queue bounded-wait oracle, bit for bit,
        // while the cache compiles each survivor count at most once
        let mut c = config(16, 4);
        c.noise = NoiseKind::Exponential { mean: 0.6 };
        c.stragglers = crate::config::StragglerKind::Uniform {
            p: 0.4,
            delay: 5.0,
        };
        c.topology = Some(crate::topology::TopologyKind::Torus { rows: 0 });
        c.comm_drop_deadline = 1.0;
        let mut fast = ClusterSim::new(&c, 77);
        let mut slow = ClusterSim::new(&c, 77).with_reference_timing();
        let mut dropped_steps = 0;
        for step in 0..40 {
            let a = fast.step(None);
            let b = slow.step(None);
            assert_eq!(
                a.iter_time.to_bits(),
                b.iter_time.to_bits(),
                "step {step}"
            );
            assert_eq!(a.completed, b.completed);
            if a.total_completed() < 16 * 4 {
                dropped_steps += 1;
            }
        }
        assert!(dropped_steps > 20, "drop-heavy config: {dropped_steps}/40");
        assert!(
            fast.survivors.compiled_count() <= 16,
            "at most one compile per survivor count: {}",
            fast.survivors.compiled_count()
        );
    }

    #[test]
    fn local_sgd_period_into_reuses_buffers_and_matches() {
        // the recycling form against the allocating form, across
        // straggler kinds that do and don't consume rng draws
        for strag in [
            crate::config::StragglerKind::None,
            crate::config::StragglerKind::Uniform { p: 0.3, delay: 1.0 },
            crate::config::StragglerKind::SingleServer {
                p: 0.5,
                delay: 2.0,
                server_size: 2,
            },
            crate::config::StragglerKind::Fatal { worker: 1, from_step: 2 },
        ] {
            let mut c = config(4, 1);
            c.noise = NoiseKind::Exponential { mean: 0.2 };
            c.stragglers = strag.clone();
            let mut a = ClusterSim::new(&c, 19);
            let mut b = ClusterSim::new(&c, 19);
            let mut out = StepOutcome::default();
            for period in 0..6 {
                let fresh = a.local_sgd_period(5, Some(0.9));
                b.local_sgd_period_into(5, Some(0.9), &mut out);
                assert_eq!(fresh.completed, out.completed, "{strag:?} {period}");
                for (x, y) in fresh.worker_compute.iter().zip(&out.worker_compute)
                {
                    assert_eq!(x.to_bits(), y.to_bits(), "{strag:?} {period}");
                }
                assert_eq!(
                    fresh.iter_time.to_bits(),
                    out.iter_time.to_bits(),
                    "{strag:?} {period}"
                );
            }
        }
    }

    #[test]
    fn mean_period_time_matches_manual_loop() {
        let mut c = config(4, 1);
        c.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.2, delay: 1.0 };
        let mut a = ClusterSim::new(&c, 23);
        let mut b = ClusterSim::new(&c, 23);
        let mean = a.mean_period_time(10, 6, Some(0.8));
        let mut sum = 0.0;
        for _ in 0..10 {
            sum += b.local_sgd_period(6, Some(0.8)).iter_time;
        }
        assert_eq!(mean.to_bits(), (sum / 10.0).to_bits());
    }

    #[test]
    fn step_with_policy_matches_legacy_paths_bitwise() {
        // the unified surface against the legacy knobs: tau via the
        // step() argument + deadline via config must equal one composed
        // DropPolicy, bit for bit
        let mut c = config(12, 6);
        c.noise = NoiseKind::Exponential { mean: 0.4 };
        c.topology = Some(crate::topology::TopologyKind::Ring);
        c.comm_drop_deadline = 1.5;
        let mut legacy = ClusterSim::new(&c, 42);
        let mut unified = ClusterSim::new(&c, 42);
        let policy = DropPolicy::compute_tau(3.0)
            .and(DropPolicy::comm_deadline(1.5));
        let mut out = StepOutcome::default();
        for step in 0..15 {
            let a = legacy.step(Some(3.0));
            unified.step_with_into(&policy, &mut out);
            assert_eq!(a.completed, out.completed, "step {step}");
            assert_eq!(a.iter_time.to_bits(), out.iter_time.to_bits());
            assert_eq!(a.compute_time.to_bits(), out.compute_time.to_bits());
        }
    }

    #[test]
    fn step_with_local_sgd_policy_matches_period_call() {
        let mut c = config(4, 1);
        c.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.3, delay: 1.0 };
        let mut a = ClusterSim::new(&c, 7);
        let mut b = ClusterSim::new(&c, 7);
        let policy = DropPolicy::local_sgd(6)
            .and(DropPolicy::compute_tau(0.9));
        for _ in 0..5 {
            let x = a.local_sgd_period(6, Some(0.9));
            let y = b.step_with(&policy);
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.iter_time.to_bits(), y.iter_time.to_bits());
        }
    }

    #[test]
    fn per_phase_lumped_budget_equals_step_deadline() {
        // the acceptance identity: a single lumped budget is the
        // step-level CommDeadline, bitwise, on every topology and the
        // fixed-T^c model, compiled and reference arms
        let topos: Vec<Option<crate::topology::TopologyKind>> =
            std::iter::once(None)
                .chain(crate::topology::TopologyKind::ALL.iter().copied().map(Some))
                .collect();
        for topo in topos {
            for reference in [false, true] {
                let mut c = config(10, 4);
                c.noise = NoiseKind::Exponential { mean: 0.5 };
                c.stragglers = crate::config::StragglerKind::Uniform {
                    p: 0.3,
                    delay: 4.0,
                };
                c.topology = topo;
                let mk = |cfg: &ClusterConfig, reference: bool| {
                    let sim = ClusterSim::new(cfg, 0xFA7E);
                    if reference {
                        sim.with_reference_timing()
                    } else {
                        sim
                    }
                };
                let mut lumped = mk(&c, reference).with_policy(
                    DropPolicy::per_phase_deadline(vec![1.0]),
                );
                let mut step = mk(&c, reference)
                    .with_policy(DropPolicy::comm_deadline(1.0));
                for s in 0..20 {
                    let a = lumped.step(None);
                    let b = step.step(None);
                    assert_eq!(
                        a.completed, b.completed,
                        "{topo:?} ref={reference} step {s}"
                    );
                    assert_eq!(
                        a.iter_time.to_bits(),
                        b.iter_time.to_bits(),
                        "{topo:?} ref={reference} step {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn per_phase_compiled_equals_reference_timing() {
        // multi-budget per-phase cutoffs: the compiled scan against the
        // event-queue oracle, bit for bit, drop-heavy
        for kind in crate::topology::TopologyKind::ALL {
            let mut c = config(12, 4);
            c.noise = NoiseKind::Exponential { mean: 0.6 };
            c.stragglers = crate::config::StragglerKind::Uniform {
                p: 0.4,
                delay: 5.0,
            };
            c.topology = Some(kind);
            let policy =
                DropPolicy::per_phase_deadline(vec![1.0, 0.25, 0.25]);
            let mut fast =
                ClusterSim::new(&c, 99).with_policy(policy.clone());
            let mut slow = ClusterSim::new(&c, 99)
                .with_reference_timing()
                .with_policy(policy);
            let mut dropped_steps = 0;
            for step in 0..25 {
                let a = fast.step(None);
                let b = slow.step(None);
                assert_eq!(
                    a.completed,
                    b.completed,
                    "{} step {step}",
                    kind.name()
                );
                assert_eq!(
                    a.iter_time.to_bits(),
                    b.iter_time.to_bits(),
                    "{} step {step}",
                    kind.name()
                );
                if a.total_completed() < 12 * 4 {
                    dropped_steps += 1;
                }
            }
            assert!(dropped_steps > 5, "{}: {dropped_steps}", kind.name());
        }
    }

    #[test]
    fn policy_install_and_accessor() {
        let c = config(4, 2);
        let policy = DropPolicy::parse("tau=2,between+deadline=1").unwrap();
        let mut sim = ClusterSim::new(&c, 1).with_policy(policy.clone());
        assert_eq!(sim.policy(), &policy);
        assert_eq!(sim.preemption, PreemptionMode::BetweenAccumulations);
        // re-stepping the same policy must not reinstall (observable
        // via the unchanged accessor; the equality check guards it)
        sim.step_with(&policy);
        assert_eq!(sim.policy(), &policy);
        // legacy comm-drop shim replaces the comm side
        let sim2 = ClusterSim::new(&c, 1).with_comm_drop(Some(2.0));
        assert_eq!(sim2.policy(), &DropPolicy::comm_deadline(2.0));
        // ...and the WHOLE installed state: compute/local clauses from
        // an earlier policy must not survive the shim (regression: a
        // stale eff_h/eff_tau made policy() lie about what steps ran)
        let mut sim3 = ClusterSim::new(&c, 1)
            .with_policy(DropPolicy::parse("local-sgd=4+tau=0.9").unwrap())
            .with_comm_drop(Some(2.0));
        assert_eq!(sim3.policy(), &DropPolicy::comm_deadline(2.0));
        let mut plain = ClusterSim::new(&c, 1).with_comm_drop(Some(2.0));
        let a = sim3.step_with(&DropPolicy::comm_deadline(2.0));
        let b = plain.step(None);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
    }

    #[test]
    fn survivor_cache_adoption_is_pure_memoization() {
        // a warm cache hopping between sims must not change a single
        // bit of any outcome
        let mut c = config(8, 4);
        c.noise = NoiseKind::Exponential { mean: 0.6 };
        c.stragglers =
            crate::config::StragglerKind::Uniform { p: 0.4, delay: 5.0 };
        c.topology = Some(crate::topology::TopologyKind::Tree);
        c.comm_drop_deadline = 1.0;
        let mut cold = ClusterSim::new(&c, 3);
        let mut warmer = ClusterSim::new(&c, 3);
        // warm a cache on a different-N sim of the same comm model
        let mut donor_cfg = c.clone();
        donor_cfg.workers = 5;
        let mut donor = ClusterSim::new(&donor_cfg, 9);
        for _ in 0..10 {
            donor.step(None);
        }
        let warm = donor.take_survivor_cache();
        warmer = warmer.with_survivor_cache(warm);
        for _ in 0..20 {
            let a = cold.step(None);
            let b = warmer.step(None);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
        }
        // a mismatched cache is rejected, not adopted
        let mut other = c.clone();
        other.topology = Some(crate::topology::TopologyKind::Ring);
        let mut ring_sim = ClusterSim::new(&other, 1);
        for _ in 0..10 {
            ring_sim.step(None);
        }
        let ring_cache = ring_sim.take_survivor_cache();
        let tree_sim = ClusterSim::new(&c, 3).with_survivor_cache(ring_cache);
        assert_eq!(tree_sim.survivors.compiled_count(), 0);
    }

    #[test]
    fn local_sgd_threshold_drops_steps() {
        let mut c = config(4, 1);
        c.stragglers = crate::config::StragglerKind::Uniform { p: 0.5, delay: 1.0 };
        let mut sim = ClusterSim::new(&c, 11);
        let out = sim.local_sgd_period(20, Some(0.9));
        assert!(out.total_completed() < 4 * 20);
        assert!(out.total_completed() > 0);
    }
}
