//! Compiled (heapless) schedule timing — the hot-path twin of
//! [`super::comm::schedule_completion`].
//!
//! The event-queue reference pays O(T log T) heap work per phase and
//! re-derives every transfer's hop cost (`latency + fraction·bytes /
//! bandwidth`) on every step, even though the schedule and the link
//! parameters are fixed for a simulation's lifetime. But the per-phase
//! recurrence needs no queue at all: within one phase every transfer's
//! delivery time is `ready[src] + hop` where `ready` is frozen at phase
//! entry, and the phase-exit state is a pure max over those deliveries —
//! order-independent, so popping them in time order buys nothing.
//!
//! [`CompiledSchedule`] lowers a [`Schedule`] once into flat
//! phase-offset + src/dst/hop arrays; [`CompiledSchedule::completion_with`]
//! then times one all-reduce with two linear passes per phase over
//! caller-owned scratch buffers (zero allocation in steady state). The
//! result is **bitwise identical** to the event-queue reference: both
//! paths clamp arrivals the same way, compute each hop with the same
//! expression, and reduce the same set of delivery times with the same
//! `>`-guarded max — property-tested in `tests/perf_equivalence.rs`.

use crate::topology::Schedule;

/// Reusable buffers for [`CompiledSchedule::completion_with`]. Keep one
/// per simulation (e.g. in `ClusterSim`) so steady-state stepping never
/// allocates.
#[derive(Debug, Default, Clone)]
pub struct ScheduleScratch {
    ready: Vec<f64>,
    next: Vec<f64>,
}

impl ScheduleScratch {
    /// Scratch pre-sized for an `n`-worker schedule, so even the first
    /// timing pass through it allocates nothing (used by the per-k
    /// survivor cache, which sizes each slot's scratch at compile time).
    pub fn with_capacity(n: usize) -> Self {
        Self {
            ready: Vec::with_capacity(n),
            next: Vec::with_capacity(n),
        }
    }
}

/// A [`Schedule`] lowered to flat arrays with precomputed hop costs for
/// one fixed `(latency, bandwidth, bytes)` triple.
#[derive(Debug, Clone)]
pub struct CompiledSchedule {
    workers: usize,
    /// `offsets[p]..offsets[p + 1]` indexes the transfers of phase `p`.
    offsets: Vec<u32>,
    srcs: Vec<u32>,
    dsts: Vec<u32>,
    /// Per-transfer link occupancy, `latency + fraction·bytes/bandwidth`.
    hops: Vec<f64>,
}

impl CompiledSchedule {
    /// Lower `schedule` once for the given link parameters. O(transfers)
    /// — run it at simulation construction, not per step.
    pub fn compile(
        schedule: &Schedule,
        latency: f64,
        bandwidth: f64,
        bytes: f64,
    ) -> Self {
        let total = schedule.transfer_count();
        let mut offsets = Vec::with_capacity(schedule.phases.len() + 1);
        let mut srcs = Vec::with_capacity(total);
        let mut dsts = Vec::with_capacity(total);
        let mut hops = Vec::with_capacity(total);
        offsets.push(0u32);
        for phase in &schedule.phases {
            for t in &phase.transfers {
                srcs.push(t.src as u32);
                dsts.push(t.dst as u32);
                // exactly the reference's expression, evaluated once
                hops.push(latency + t.chunk.fraction() * bytes / bandwidth);
            }
            offsets.push(srcs.len() as u32);
        }
        Self { workers: schedule.workers, offsets, srcs, dsts, hops }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn phase_count(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn transfer_count(&self) -> usize {
        self.srcs.len()
    }

    /// One-shot completion time (allocates its own scratch; prefer
    /// [`Self::completion_with`] in loops).
    pub fn completion(&self, arrivals: &[f64]) -> f64 {
        let mut scratch = ScheduleScratch::default();
        self.completion_with(arrivals, &mut scratch)
    }

    /// Time until every worker holds the reduced result, bitwise equal
    /// to [`super::comm::schedule_completion`] on the source schedule.
    /// Empty `arrivals` complete instantly at 0.0.
    pub fn completion_with(
        &self,
        arrivals: &[f64],
        scratch: &mut ScheduleScratch,
    ) -> f64 {
        assert_eq!(
            self.workers,
            arrivals.len(),
            "schedule compiled for a different worker count"
        );
        if arrivals.is_empty() {
            return 0.0;
        }
        let ScheduleScratch { ready, next } = scratch;
        ready.clear();
        ready.extend(arrivals.iter().map(|a| a.max(0.0)));
        next.resize(arrivals.len(), 0.0);
        for p in 0..self.phase_count() {
            next.copy_from_slice(ready);
            let (lo, hi) =
                (self.offsets[p] as usize, self.offsets[p + 1] as usize);
            for k in lo..hi {
                let (src, dst) =
                    (self.srcs[k] as usize, self.dsts[k] as usize);
                let done = ready[src] + self.hops[k];
                // data dependency: dst holds the chunk at delivery time
                if done > next[dst] {
                    next[dst] = done;
                }
                // egress occupancy: src's link is busy until delivery
                if done > next[src] {
                    next[src] = done;
                }
            }
            std::mem::swap(ready, next);
        }
        ready.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::comm::schedule_completion;
    use crate::topology::TopologyKind;

    #[test]
    fn flat_layout_matches_schedule_counts() {
        for kind in TopologyKind::ALL {
            for n in [1usize, 2, 5, 8, 12] {
                let s = kind.build(n);
                let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
                assert_eq!(c.workers(), n);
                assert_eq!(c.phase_count(), s.phase_count());
                assert_eq!(c.transfer_count(), s.transfer_count());
            }
        }
    }

    #[test]
    fn uniform_arrivals_match_uniform_cost() {
        let (lat, bw, bytes) = (25e-6, 12.5e9, 1e8);
        for kind in TopologyKind::ALL {
            for n in [2usize, 4, 7, 12] {
                let s = kind.build(n);
                let c = CompiledSchedule::compile(&s, lat, bw, bytes);
                let got = c.completion(&vec![0.0; n]);
                let want = s.uniform_cost(lat, bw, bytes);
                assert!(
                    (got - want).abs() < 1e-9,
                    "{} n={n}: {got} vs {want}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn bitwise_equal_to_event_queue_on_stragglers() {
        let (lat, bw, bytes) = (1e-4, 1e9, 4e6);
        for kind in TopologyKind::ALL {
            let n = 8;
            let s = kind.build(n);
            let c = CompiledSchedule::compile(&s, lat, bw, bytes);
            let mut arrivals = vec![0.25; n];
            arrivals[3] = 7.5;
            arrivals[6] = 0.01;
            let want = schedule_completion(&s, &arrivals, lat, bw, bytes);
            assert_eq!(
                c.completion(&arrivals).to_bits(),
                want.to_bits(),
                "{}",
                kind.name()
            );
        }
    }

    #[test]
    fn empty_and_single_worker_degenerate() {
        let s = Schedule::empty(0);
        let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
        assert_eq!(c.completion(&[]), 0.0);
        let s1 = TopologyKind::Ring.build(1);
        let c1 = CompiledSchedule::compile(&s1, 1e-4, 1e9, 4e6);
        assert_eq!(c1.completion(&[2.0]), 2.0);
    }

    #[test]
    fn scratch_is_reusable_across_sizes() {
        // one scratch serving schedules of different worker counts must
        // resize correctly and keep results exact.
        let mut scratch = ScheduleScratch::default();
        for n in [8usize, 3, 12, 2] {
            let s = TopologyKind::Ring.build(n);
            let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
            let arrivals: Vec<f64> =
                (0..n).map(|i| i as f64 * 0.1).collect();
            let want =
                schedule_completion(&s, &arrivals, 1e-4, 1e9, 4e6);
            let got = c.completion_with(&arrivals, &mut scratch);
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn negative_arrivals_clamp_like_reference() {
        let s = TopologyKind::Tree.build(4);
        let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
        let arrivals = [-3.0, 0.2, -0.5, 0.1];
        let want = schedule_completion(&s, &arrivals, 1e-4, 1e9, 4e6);
        assert_eq!(c.completion(&arrivals).to_bits(), want.to_bits());
    }
}
