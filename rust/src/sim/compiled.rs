//! Compiled (heapless) schedule timing — the hot-path twin of
//! [`super::comm::schedule_completion`].
//!
//! The event-queue reference pays O(T log T) heap work per phase and
//! re-derives every transfer's hop cost (`latency + fraction·bytes /
//! bandwidth`) on every step, even though the schedule and the link
//! parameters are fixed for a simulation's lifetime. But the per-phase
//! recurrence needs no queue at all: within one phase every transfer's
//! delivery time is `ready[src] + hop` where `ready` is frozen at phase
//! entry, and the phase-exit state is a pure max over those deliveries —
//! order-independent, so popping them in time order buys nothing.
//!
//! [`CompiledSchedule`] lowers a [`Schedule`] once into flat
//! phase-offset + src/dst/hop arrays; [`CompiledSchedule::completion_with`]
//! then times one all-reduce with two linear passes per phase over
//! caller-owned scratch buffers (zero allocation in steady state). The
//! result is **bitwise identical** to the event-queue reference: both
//! paths clamp arrivals the same way, compute each hop with the same
//! expression, and reduce the same set of delivery times with the same
//! `>`-guarded max — property-tested in `tests/perf_equivalence.rs`.

use crate::topology::Schedule;

/// Reusable buffers for [`CompiledSchedule::completion_with`]. Keep one
/// per simulation (e.g. in `ClusterSim`) so steady-state stepping never
/// allocates.
#[derive(Debug, Default, Clone)]
pub struct ScheduleScratch {
    ready: Vec<f64>,
    next: Vec<f64>,
}

impl ScheduleScratch {
    /// Scratch pre-sized for an `n`-worker schedule, so even the first
    /// timing pass through it allocates nothing (used by the per-k
    /// survivor cache, which sizes each slot's scratch at compile time).
    pub fn with_capacity(n: usize) -> Self {
        Self {
            ready: Vec::with_capacity(n),
            next: Vec::with_capacity(n),
        }
    }
}

/// Outcome of [`CompiledSchedule::bounded_completion_with`] — the
/// per-phase-deadline ([`crate::policy::DropPolicy::PerPhaseDeadline`])
/// scan over one collective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PhaseBounded {
    /// Every worker made every checkpoint: the full collective ran, and
    /// this is its completion time (bitwise identical to
    /// [`CompiledSchedule::completion_with`] on the same arrivals).
    Complete(f64),
    /// Some worker missed a checkpoint: `survivors` workers remain and
    /// membership was finally known at `close` (the last checkpoint
    /// cutoff that dropped anyone). `checkpoint` is that checkpoint's
    /// index — the recursive restart semantics re-check the survivors'
    /// collective against the budgets *after* it
    /// ([`crate::policy::rebased_offsets`]). The caller times the
    /// survivors' restarted collective from `close` (the per-k cache).
    Dropped { survivors: usize, close: f64, checkpoint: usize },
}

/// A [`Schedule`] lowered to flat arrays with precomputed hop costs for
/// one fixed `(latency, bandwidth, bytes)` triple.
#[derive(Debug, Clone)]
pub struct CompiledSchedule {
    workers: usize,
    /// `offsets[p]..offsets[p + 1]` indexes the transfers of phase `p`.
    offsets: Vec<u32>,
    srcs: Vec<u32>,
    dsts: Vec<u32>,
    /// Per-transfer link occupancy, `latency + fraction·bytes/bandwidth`.
    hops: Vec<f64>,
}

impl CompiledSchedule {
    /// Lower `schedule` once for the given link parameters. O(transfers)
    /// — run it at simulation construction, not per step.
    pub fn compile(
        schedule: &Schedule,
        latency: f64,
        bandwidth: f64,
        bytes: f64,
    ) -> Self {
        let total = schedule.transfer_count();
        let mut offsets = Vec::with_capacity(schedule.phases.len() + 1);
        let mut srcs = Vec::with_capacity(total);
        let mut dsts = Vec::with_capacity(total);
        let mut hops = Vec::with_capacity(total);
        offsets.push(0u32);
        for phase in &schedule.phases {
            for t in &phase.transfers {
                srcs.push(t.src as u32);
                dsts.push(t.dst as u32);
                // exactly the reference's expression, evaluated once
                hops.push(latency + t.chunk.fraction() * bytes / bandwidth);
            }
            offsets.push(srcs.len() as u32);
        }
        Self { workers: schedule.workers, offsets, srcs, dsts, hops }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn phase_count(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn transfer_count(&self) -> usize {
        self.srcs.len()
    }

    /// Transfer index range of phase `p` — the batched SoA pass
    /// ([`super::batch::ReplicaBatch`]) walks the same flat arrays as
    /// [`Self::completion_with_phases`], lane-parallel.
    pub(crate) fn phase_bounds(&self, p: usize) -> (usize, usize) {
        (self.offsets[p] as usize, self.offsets[p + 1] as usize)
    }

    /// The flat `(srcs, dsts, hops)` transfer arrays, for the batched
    /// pass. Read-only: the per-edge update order these arrays encode
    /// is what makes batched results bitwise equal to the scalar scan.
    pub(crate) fn edges(&self) -> (&[u32], &[u32], &[f64]) {
        (&self.srcs, &self.dsts, &self.hops)
    }

    /// One-shot completion time (allocates its own scratch; prefer
    /// [`Self::completion_with`] in loops).
    pub fn completion(&self, arrivals: &[f64]) -> f64 {
        let mut scratch = ScheduleScratch::default();
        self.completion_with(arrivals, &mut scratch)
    }

    /// Time until every worker holds the reduced result, bitwise equal
    /// to [`super::comm::schedule_completion`] on the source schedule.
    /// Empty `arrivals` complete instantly at 0.0.
    pub fn completion_with(
        &self,
        arrivals: &[f64],
        scratch: &mut ScheduleScratch,
    ) -> f64 {
        self.completion_with_phases(arrivals, scratch, |_, _| {})
    }

    /// [`Self::completion_with`] with an observation hook: `on_phase`
    /// receives `(phase_index, post-phase readiness slice)` after each
    /// phase's transfer pass. The hook gets the *raw* slice (no fold
    /// precomputed) so the no-op closure — which `completion_with`
    /// passes — monomorphizes to exactly the unhooked loop: disabled
    /// observation is bitwise and perf-identical.
    pub fn completion_with_phases<F: FnMut(usize, &[f64])>(
        &self,
        arrivals: &[f64],
        scratch: &mut ScheduleScratch,
        mut on_phase: F,
    ) -> f64 {
        assert_eq!(
            self.workers,
            arrivals.len(),
            "schedule compiled for a different worker count"
        );
        if arrivals.is_empty() {
            return 0.0;
        }
        let ScheduleScratch { ready, next } = scratch;
        ready.clear();
        ready.extend(arrivals.iter().map(|a| a.max(0.0)));
        next.resize(arrivals.len(), 0.0);
        for p in 0..self.phase_count() {
            next.copy_from_slice(ready);
            let (lo, hi) =
                (self.offsets[p] as usize, self.offsets[p + 1] as usize);
            for k in lo..hi {
                let (src, dst) =
                    (self.srcs[k] as usize, self.dsts[k] as usize);
                let done = ready[src] + self.hops[k];
                // data dependency: dst holds the chunk at delivery time
                if done > next[dst] {
                    next[dst] = done;
                }
                // egress occupancy: src's link is busy until delivery
                if done > next[src] {
                    next[src] = done;
                }
            }
            std::mem::swap(ready, next);
            on_phase(p, ready);
        }
        ready.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// The phase pass with per-phase membership checkpoints — the
    /// compiled arm of the per-phase DropComm policy
    /// ([`crate::policy::DropPolicy::PerPhaseDeadline`]).
    ///
    /// `budget_offsets[p]` is the *cumulative* cutoff offset of phase
    /// `p`'s entry checkpoint (see [`crate::policy::cumulative_offsets`]):
    /// a worker not ready to enter phase `p` by
    /// `first_arrival + budget_offsets[p]` is dropped. Checkpoint 0 is
    /// the step-level membership rule evaluated on *raw* arrivals (so a
    /// single lumped budget is bitwise the step-level `CommDeadline`,
    /// and the first arrival always survives it); later checkpoints see
    /// the readiness the pass itself produced, which is how a worker
    /// stalled by a slow dependency chain gets caught mid-collective.
    /// Checkpoints past the last phase apply to the final readiness.
    ///
    /// Non-clairvoyance: transfers already scheduled from a
    /// subsequently-dropped worker still land in the scan, and the
    /// survivors' restarted collective (timed by the caller from
    /// `close`) is not re-checked against later budgets — mirroring the
    /// step-level rule, whose survivor collective is also unchecked.
    ///
    /// `dropped` is a reusable out-mask (`true` = dropped). Bitwise
    /// identical to the event-queue oracle
    /// ([`crate::sim::CommModel::per_phase_bounded_completion`]) —
    /// property-tested in `tests/policy_equivalence.rs`.
    pub fn bounded_completion_with(
        &self,
        arrivals: &[f64],
        budget_offsets: &[f64],
        scratch: &mut ScheduleScratch,
        dropped: &mut Vec<bool>,
    ) -> PhaseBounded {
        assert_eq!(
            self.workers,
            arrivals.len(),
            "schedule compiled for a different worker count"
        );
        dropped.clear();
        dropped.resize(arrivals.len(), false);
        if arrivals.is_empty() {
            return PhaseBounded::Complete(0.0);
        }
        let first = arrivals.iter().cloned().fold(f64::INFINITY, f64::min);
        let ScheduleScratch { ready, next } = scratch;
        ready.clear();
        ready.extend(arrivals.iter().map(|a| a.max(0.0)));
        next.resize(arrivals.len(), 0.0);
        let mut survivors = arrivals.len();
        let mut close = f64::NEG_INFINITY;
        let mut last_checkpoint = 0usize;
        let phases = self.phase_count();
        for p in 0..phases.max(budget_offsets.len()) {
            if p < budget_offsets.len() {
                let cutoff = first + budget_offsets[p];
                for (n, d) in dropped.iter_mut().enumerate() {
                    if *d {
                        continue;
                    }
                    // checkpoint 0: the raw-arrival membership rule
                    let v = if p == 0 { arrivals[n] } else { ready[n] };
                    if v > cutoff {
                        *d = true;
                        survivors -= 1;
                        close = cutoff;
                        last_checkpoint = p;
                    }
                }
            }
            if p < phases {
                next.copy_from_slice(ready);
                let (lo, hi) =
                    (self.offsets[p] as usize, self.offsets[p + 1] as usize);
                for k in lo..hi {
                    let (src, dst) =
                        (self.srcs[k] as usize, self.dsts[k] as usize);
                    let done = ready[src] + self.hops[k];
                    if done > next[dst] {
                        next[dst] = done;
                    }
                    if done > next[src] {
                        next[src] = done;
                    }
                }
                std::mem::swap(ready, next);
            }
        }
        if survivors == arrivals.len() {
            PhaseBounded::Complete(
                ready.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            )
        } else {
            PhaseBounded::Dropped { survivors, close, checkpoint: last_checkpoint }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::comm::schedule_completion;
    use crate::topology::TopologyKind;

    #[test]
    fn flat_layout_matches_schedule_counts() {
        for kind in TopologyKind::ALL {
            for n in [1usize, 2, 5, 8, 12] {
                let s = kind.build(n);
                let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
                assert_eq!(c.workers(), n);
                assert_eq!(c.phase_count(), s.phase_count());
                assert_eq!(c.transfer_count(), s.transfer_count());
            }
        }
    }

    #[test]
    fn uniform_arrivals_match_uniform_cost() {
        let (lat, bw, bytes) = (25e-6, 12.5e9, 1e8);
        for kind in TopologyKind::ALL {
            for n in [2usize, 4, 7, 12] {
                let s = kind.build(n);
                let c = CompiledSchedule::compile(&s, lat, bw, bytes);
                let got = c.completion(&vec![0.0; n]);
                let want = s.uniform_cost(lat, bw, bytes);
                assert!(
                    (got - want).abs() < 1e-9,
                    "{} n={n}: {got} vs {want}",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn bitwise_equal_to_event_queue_on_stragglers() {
        let (lat, bw, bytes) = (1e-4, 1e9, 4e6);
        for kind in TopologyKind::ALL {
            let n = 8;
            let s = kind.build(n);
            let c = CompiledSchedule::compile(&s, lat, bw, bytes);
            let mut arrivals = vec![0.25; n];
            arrivals[3] = 7.5;
            arrivals[6] = 0.01;
            let want = schedule_completion(&s, &arrivals, lat, bw, bytes);
            assert_eq!(
                c.completion(&arrivals).to_bits(),
                want.to_bits(),
                "{}",
                kind.name()
            );
        }
    }

    #[test]
    fn empty_and_single_worker_degenerate() {
        let s = Schedule::empty(0);
        let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
        assert_eq!(c.completion(&[]), 0.0);
        let s1 = TopologyKind::Ring.build(1);
        let c1 = CompiledSchedule::compile(&s1, 1e-4, 1e9, 4e6);
        assert_eq!(c1.completion(&[2.0]), 2.0);
    }

    #[test]
    fn scratch_is_reusable_across_sizes() {
        // one scratch serving schedules of different worker counts must
        // resize correctly and keep results exact.
        let mut scratch = ScheduleScratch::default();
        for n in [8usize, 3, 12, 2] {
            let s = TopologyKind::Ring.build(n);
            let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
            let arrivals: Vec<f64> =
                (0..n).map(|i| i as f64 * 0.1).collect();
            let want =
                schedule_completion(&s, &arrivals, 1e-4, 1e9, 4e6);
            let got = c.completion_with(&arrivals, &mut scratch);
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn negative_arrivals_clamp_like_reference() {
        let s = TopologyKind::Tree.build(4);
        let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
        let arrivals = [-3.0, 0.2, -0.5, 0.1];
        let want = schedule_completion(&s, &arrivals, 1e-4, 1e9, 4e6);
        assert_eq!(c.completion(&arrivals).to_bits(), want.to_bits());
    }

    #[test]
    fn bounded_scan_loose_budgets_complete_like_plain_pass() {
        // budgets nobody can miss: the scan must return Complete with
        // the exact bits of the unbounded pass, and drop no one.
        let mut scratch = ScheduleScratch::default();
        let mut dropped = Vec::new();
        for kind in TopologyKind::ALL {
            let s = kind.build(9);
            let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
            let arrivals: Vec<f64> =
                (0..9).map(|i| i as f64 * 0.3).collect();
            let want = c.completion(&arrivals);
            let got = c.bounded_completion_with(
                &arrivals,
                &[1e6, 2e6, 3e6],
                &mut scratch,
                &mut dropped,
            );
            assert_eq!(
                got,
                PhaseBounded::Complete(want),
                "{}",
                kind.name()
            );
            assert!(dropped.iter().all(|&d| !d));
        }
    }

    #[test]
    fn bounded_scan_entry_checkpoint_is_the_membership_rule() {
        // a single lumped budget: checkpoint 0 on raw arrivals must
        // reproduce bounded_wait_survivors exactly, close at the
        // bounded_wait_cutoff.
        use crate::sim::comm::{bounded_wait_cutoff, bounded_wait_survivors};
        let s = TopologyKind::Ring.build(5);
        let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
        let arrivals = [0.2, 5.0, 0.1, -0.5, 9.0];
        let budget = 1.0;
        let mut scratch = ScheduleScratch::default();
        let mut dropped = Vec::new();
        let got = c.bounded_completion_with(
            &arrivals,
            &[budget],
            &mut scratch,
            &mut dropped,
        );
        let want_mask = bounded_wait_survivors(&arrivals, budget);
        for (d, s) in dropped.iter().zip(&want_mask) {
            assert_eq!(*d, !*s);
        }
        let close = bounded_wait_cutoff(&arrivals, budget);
        assert_eq!(
            got,
            PhaseBounded::Dropped { survivors: 3, close, checkpoint: 0 }
        );
    }

    #[test]
    fn bounded_scan_catches_chain_stalled_worker_mid_collective() {
        // worker 3 arrives on time but its ring neighbors' chunks route
        // through a straggler, stalling its readiness; a deep
        // checkpoint catches what the entry membership rule cannot.
        let s = TopologyKind::Ring.build(4);
        let c = CompiledSchedule::compile(&s, 0.05, 1e9, 4e6);
        // worker 1 is late but inside the entry budget; its delay
        // propagates around the ring
        let arrivals = [0.0, 0.9, 0.0, 0.0];
        let mut scratch = ScheduleScratch::default();
        let mut dropped = Vec::new();
        // entry budget 1.0 admits everyone; the zero follow-on budgets
        // hold the cutoff flat at 1.0 while worker 1's 0.9s delay plus
        // two 0.051s hops pushes the stalled chain's readiness past it
        let got = c.bounded_completion_with(
            &arrivals,
            &[1.0, 0.0, 0.0],
            &mut scratch,
            &mut dropped,
        );
        match got {
            PhaseBounded::Dropped { survivors, close, checkpoint } => {
                assert!(survivors < 4, "someone must drop");
                assert!(survivors > 0, "not everyone");
                assert_eq!(close, 1.0, "last triggered checkpoint");
                assert!(checkpoint > 0, "a deep checkpoint triggered");
            }
            PhaseBounded::Complete(_) => {
                panic!("deep checkpoints should have dropped the chain")
            }
        }
        // step-level membership (single budget 1.0) admits everyone
        let step = c.bounded_completion_with(
            &arrivals,
            &[1.0],
            &mut scratch,
            &mut dropped,
        );
        assert!(matches!(step, PhaseBounded::Complete(_)));
    }

    #[test]
    fn bounded_scan_degenerate_empty_and_tiny() {
        let s = Schedule::empty(0);
        let c = CompiledSchedule::compile(&s, 1e-4, 1e9, 4e6);
        let mut scratch = ScheduleScratch::default();
        let mut dropped = vec![true; 3]; // stale contents must be cleared
        assert_eq!(
            c.bounded_completion_with(&[], &[1.0], &mut scratch, &mut dropped),
            PhaseBounded::Complete(0.0)
        );
        assert!(dropped.is_empty());
        // single worker, zero phases: trailing checkpoint 0 applies the
        // raw-arrival rule — the lone (first) arrival always survives
        let s1 = TopologyKind::Ring.build(1);
        let c1 = CompiledSchedule::compile(&s1, 1e-4, 1e9, 4e6);
        assert_eq!(
            c1.bounded_completion_with(
                &[2.0],
                &[0.0],
                &mut scratch,
                &mut dropped
            ),
            PhaseBounded::Complete(2.0)
        );
        assert_eq!(dropped, vec![false]);
    }
}
