//! Micro-batch latency traces: `t_{i,n}^{(m)}` tensors.
//!
//! Algorithm 2 (App. C.1) chooses the threshold from exactly this data;
//! the Fig 4 "post-analysis" benches replay recorded traces through the
//! DropCompute timing rule at many thresholds. CSV on disk so runs can
//! be archived and re-analyzed.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::util::{Error, Result};

/// Dense `[iters][workers][accums]` latency tensor (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub iters: usize,
    pub workers: usize,
    pub accums: usize,
    data: Vec<f64>,
    /// Per-iteration communication time `T^c_i` (may be empty = zeros).
    pub comm: Vec<f64>,
}

impl Trace {
    pub fn new(iters: usize, workers: usize, accums: usize) -> Self {
        Self {
            iters,
            workers,
            accums,
            data: vec![0.0; iters * workers * accums],
            comm: vec![0.0; iters],
        }
    }

    #[inline]
    fn idx(&self, i: usize, n: usize, m: usize) -> usize {
        debug_assert!(i < self.iters && n < self.workers && m < self.accums);
        (i * self.workers + n) * self.accums + m
    }

    #[inline]
    pub fn get(&self, i: usize, n: usize, m: usize) -> f64 {
        self.data[self.idx(i, n, m)]
    }

    #[inline]
    pub fn set(&mut self, i: usize, n: usize, m: usize, v: f64) {
        let idx = self.idx(i, n, m);
        self.data[idx] = v;
    }

    /// Cumulative compute time of worker `n` through micro-batch `m`
    /// (inclusive) at iteration `i`: `T_n^{(m+1)}` in paper notation.
    pub fn cumsum(&self, i: usize, n: usize, m: usize) -> f64 {
        (0..=m).map(|j| self.get(i, n, j)).sum()
    }

    /// Full step compute time `T_{i,n}` of worker n.
    pub fn worker_step_time(&self, i: usize, n: usize) -> f64 {
        self.cumsum(i, n, self.accums - 1)
    }

    /// Max-over-workers step compute time `T_i`.
    pub fn step_time(&self, i: usize) -> f64 {
        (0..self.workers)
            .map(|n| self.worker_step_time(i, n))
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// All micro-batch samples flattened (the distribution workers
    /// synchronize in Algorithm 2).
    pub fn all_samples(&self) -> &[f64] {
        &self.data
    }

    /// Mean/variance of the micro-batch latency across everything.
    pub fn microbatch_moments(&self) -> (f64, f64) {
        let n = self.data.len() as f64;
        let mean = self.data.iter().sum::<f64>() / n;
        let var =
            self.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    pub fn mean_comm(&self) -> f64 {
        if self.comm.is_empty() {
            0.0
        } else {
            self.comm.iter().sum::<f64>() / self.comm.len() as f64
        }
    }

    /// CSV: header then one row per (iter, worker): i,n,tc,m0,m1,...
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "# trace iters={} workers={} accums={}", self.iters,
                 self.workers, self.accums)?;
        for i in 0..self.iters {
            for n in 0..self.workers {
                let mut row = format!("{i},{n},{:.9}", self.comm[i]);
                for m in 0..self.accums {
                    row.push_str(&format!(",{:.9}", self.get(i, n, m)));
                }
                writeln!(f, "{row}")?;
            }
        }
        Ok(())
    }

    pub fn load_csv(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        let mut lines = BufReader::new(f).lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::Data("empty trace file".into()))??;
        let dims: Vec<usize> = header
            .split(|c: char| !c.is_ascii_digit())
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().unwrap())
            .collect();
        if dims.len() != 3 {
            return Err(Error::Data(format!("bad trace header `{header}`")));
        }
        let mut trace = Trace::new(dims[0], dims[1], dims[2]);
        for line in lines {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split(',').collect();
            if parts.len() != 3 + trace.accums {
                return Err(Error::Data(format!("bad trace row `{line}`")));
            }
            let i: usize = parts[0]
                .parse()
                .map_err(|_| Error::Data("bad iter index".into()))?;
            let n: usize = parts[1]
                .parse()
                .map_err(|_| Error::Data("bad worker index".into()))?;
            trace.comm[i] = parts[2]
                .parse()
                .map_err(|_| Error::Data("bad comm value".into()))?;
            for m in 0..trace.accums {
                trace.set(
                    i,
                    n,
                    m,
                    parts[3 + m]
                        .parse()
                        .map_err(|_| Error::Data("bad latency value".into()))?,
                );
            }
        }
        Ok(trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new(2, 3, 4);
        for i in 0..2 {
            t.comm[i] = 0.1 * (i + 1) as f64;
            for n in 0..3 {
                for m in 0..4 {
                    t.set(i, n, m, (i + n + m) as f64 * 0.01 + 0.1);
                }
            }
        }
        t
    }

    #[test]
    fn cumsum_and_step_time() {
        let t = sample();
        assert!((t.cumsum(0, 0, 1) - (0.1 + 0.11)).abs() < 1e-12);
        // worker 2 is slowest at iter 0
        assert!((t.step_time(0) - t.worker_step_time(0, 2)).abs() < 1e-12);
    }

    #[test]
    fn moments() {
        let t = sample();
        let (mean, var) = t.microbatch_moments();
        assert!(mean > 0.1 && var > 0.0);
        assert!((t.mean_comm() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let dir = std::env::temp_dir().join("dc_trace_test");
        let path = dir.join("t.csv");
        t.save_csv(&path).unwrap();
        let loaded = Trace::load_csv(&path).unwrap();
        assert_eq!(t.iters, loaded.iters);
        for i in 0..t.iters {
            for n in 0..t.workers {
                for m in 0..t.accums {
                    assert!((t.get(i, n, m) - loaded.get(i, n, m)).abs() < 1e-8);
                }
            }
            assert!((t.comm[i] - loaded.comm[i]).abs() < 1e-8);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("dc_trace_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "nonsense\n1,2,3\n").unwrap();
        assert!(Trace::load_csv(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
