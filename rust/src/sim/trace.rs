//! Latency traces — the crate's `TraceSource`.
//!
//! Two layers:
//!
//! * [`Trace`] — the dense `[iters][workers][accums]` tensor Algorithm 2
//!   (App. C.1) consumes and the Fig 4 post-analysis benches sweep
//!   (CSV on disk, no-drop recordings only);
//! * [`TraceRecord`] — the versioned-JSON *replayable* trace: per step,
//!   each worker's straggler delay and the micro-batch latencies its
//!   live run actually drew, plus the run's metadata (cluster shape,
//!   comm model, installed [`crate::policy::DropPolicy`] spec, seed)
//!   and the recorded [`super::StepOutcome`]s. Replaying a record
//!   through [`super::ClusterSim::from_trace`] reproduces the recorded
//!   outcomes **bitwise**, on both the compiled and event-queue timing
//!   paths — which is what makes checked-in golden traces a permanent
//!   conformance harness (`rust/tests/trace_conformance.rs`), and what
//!   lets [`crate::analysis::budget_fit`] evaluate candidate drop
//!   policies against recorded reality instead of synthetic noise
//!   (OptiReduce derives its per-phase deadlines from measured tails
//!   the same way).
//!
//! JSON schema (version 2):
//!
//! ```json
//! {
//!   "format": "dropcompute-trace",
//!   "version": 2,
//!   "mode": "step",                    // or "period" (Local-SGD)
//!   "workers": 6, "accums": 3, "seed": 42,
//!   "policy": "deadline=0.75",         // DropPolicy spec grammar
//!   "scenario": "fail@100:w3,rejoin+50",  // optional: FaultPlan spec (v2)
//!   "comm": {"kind": "ring", "latency": 1e-3,
//!            "bandwidth": 1e9, "bytes": 4e6},   // or {"kind": "fixed", "latency": 0.5}
//!   "steps":    [{"straggle": [..N..], "samples": [[..],..N..]}, ..],
//!   "outcomes": [{"iter_time": t, "compute_time": c,
//!                 "worker_compute": [..N..], "completed": [..N..]}, ..]
//! }
//! ```
//!
//! Floats are written in Rust's shortest round-trip form and parsed by
//! the std `f64` parser, so every value survives the JSON round trip
//! bit for bit. Malformed, short, non-finite or mis-shaped records
//! produce typed [`Error`]s, never panics.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::runtime::json::Json;
use crate::topology::TopologyKind;
use crate::util::{Error, Result};

use super::cluster::StepOutcome;
use super::comm::CommModel;

/// Dense `[iters][workers][accums]` latency tensor (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub iters: usize,
    pub workers: usize,
    pub accums: usize,
    data: Vec<f64>,
    /// Per-iteration communication time `T^c_i` (may be empty = zeros).
    pub comm: Vec<f64>,
}

impl Trace {
    pub fn new(iters: usize, workers: usize, accums: usize) -> Self {
        Self {
            iters,
            workers,
            accums,
            data: vec![0.0; iters * workers * accums],
            comm: vec![0.0; iters],
        }
    }

    #[inline]
    fn idx(&self, i: usize, n: usize, m: usize) -> usize {
        debug_assert!(i < self.iters && n < self.workers && m < self.accums);
        (i * self.workers + n) * self.accums + m
    }

    #[inline]
    pub fn get(&self, i: usize, n: usize, m: usize) -> f64 {
        self.data[self.idx(i, n, m)]
    }

    #[inline]
    pub fn set(&mut self, i: usize, n: usize, m: usize, v: f64) {
        let idx = self.idx(i, n, m);
        self.data[idx] = v;
    }

    /// Cumulative compute time of worker `n` through micro-batch `m`
    /// (inclusive) at iteration `i`: `T_n^{(m+1)}` in paper notation.
    pub fn cumsum(&self, i: usize, n: usize, m: usize) -> f64 {
        (0..=m).map(|j| self.get(i, n, j)).sum()
    }

    /// Full step compute time `T_{i,n}` of worker n.
    pub fn worker_step_time(&self, i: usize, n: usize) -> f64 {
        self.cumsum(i, n, self.accums - 1)
    }

    /// Max-over-workers step compute time `T_i`.
    pub fn step_time(&self, i: usize) -> f64 {
        (0..self.workers)
            .map(|n| self.worker_step_time(i, n))
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// All micro-batch samples flattened (the distribution workers
    /// synchronize in Algorithm 2).
    pub fn all_samples(&self) -> &[f64] {
        &self.data
    }

    /// Mean/variance of the micro-batch latency across everything.
    pub fn microbatch_moments(&self) -> (f64, f64) {
        let n = self.data.len() as f64;
        let mean = self.data.iter().sum::<f64>() / n;
        let var =
            self.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    pub fn mean_comm(&self) -> f64 {
        if self.comm.is_empty() {
            0.0
        } else {
            self.comm.iter().sum::<f64>() / self.comm.len() as f64
        }
    }

    /// CSV: header then one row per (iter, worker): i,n,tc,m0,m1,...
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "# trace iters={} workers={} accums={}", self.iters,
                 self.workers, self.accums)?;
        for i in 0..self.iters {
            for n in 0..self.workers {
                let mut row = format!("{i},{n},{:.9}", self.comm[i]);
                for m in 0..self.accums {
                    row.push_str(&format!(",{:.9}", self.get(i, n, m)));
                }
                writeln!(f, "{row}")?;
            }
        }
        Ok(())
    }

    pub fn load_csv(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        let mut lines = BufReader::new(f).lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::Data("empty trace file".into()))??;
        let dims: Vec<usize> = header
            .split(|c: char| !c.is_ascii_digit())
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse().map_err(|_| {
                    Error::Data(format!("bad trace header `{header}`"))
                })
            })
            .collect::<Result<_>>()?;
        if dims.len() != 3 {
            return Err(Error::Data(format!("bad trace header `{header}`")));
        }
        let mut trace = Trace::new(dims[0], dims[1], dims[2]);
        for line in lines {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split(',').collect();
            if parts.len() != 3 + trace.accums {
                return Err(Error::Data(format!("bad trace row `{line}`")));
            }
            let i: usize = parts[0]
                .parse()
                .map_err(|_| Error::Data("bad iter index".into()))?;
            let n: usize = parts[1]
                .parse()
                .map_err(|_| Error::Data("bad worker index".into()))?;
            trace.comm[i] = parts[2]
                .parse()
                .map_err(|_| Error::Data("bad comm value".into()))?;
            for m in 0..trace.accums {
                trace.set(
                    i,
                    n,
                    m,
                    parts[3 + m]
                        .parse()
                        .map_err(|_| Error::Data("bad latency value".into()))?,
                );
            }
        }
        Ok(trace)
    }
}

/// Version of the replayable-trace JSON format this build writes.
/// Version 2 added the optional `scenario` field (the recorded run's
/// [`crate::sim::FaultPlan`] spec); version-1 documents still read —
/// they simply carry no scenario. Forward versions are a typed error,
/// not a guess.
pub const TRACE_FORMAT_VERSION: u64 = 2;

/// What one recorded entry of a [`TraceRecord`] is: a synchronous step
/// (per-worker straggle + micro-batch latency draws) or a Local-SGD
/// period (per-worker local-step compute times, straggle folded in).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    Step,
    Period,
}

impl TraceMode {
    pub fn name(&self) -> &'static str {
        match self {
            TraceMode::Step => "step",
            TraceMode::Period => "period",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "step" => Ok(TraceMode::Step),
            "period" => Ok(TraceMode::Period),
            other => Err(Error::Data(format!(
                "trace: unknown mode `{other}` (want step or period)"
            ))),
        }
    }
}

/// The comm model a trace was recorded under — enough to rebuild the
/// exact [`CommModel`] (and therefore the exact collective timing) at
/// replay time.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceComm {
    /// The paper's fixed serial constant `T^c`.
    Fixed { latency: f64 },
    /// Schedule-driven topology with its link parameters.
    Topology { kind: TopologyKind, latency: f64, bandwidth: f64, bytes: f64 },
}

impl TraceComm {
    pub fn from_model(m: &CommModel) -> Self {
        match *m {
            CommModel::Fixed(latency) => TraceComm::Fixed { latency },
            CommModel::Ring { latency, bandwidth, bytes } => {
                TraceComm::Topology {
                    kind: TopologyKind::Ring,
                    latency,
                    bandwidth,
                    bytes,
                }
            }
            CommModel::Topology { kind, latency, bandwidth, bytes } => {
                TraceComm::Topology { kind, latency, bandwidth, bytes }
            }
        }
    }

    pub fn to_model(&self) -> CommModel {
        match *self {
            TraceComm::Fixed { latency } => CommModel::Fixed(latency),
            TraceComm::Topology { kind, latency, bandwidth, bytes } => {
                CommModel::Topology { kind, latency, bandwidth, bytes }
            }
        }
    }

    /// The `kind` string of the JSON schema (`fixed`, or the
    /// [`TopologyKind::parse`] grammar: `ring`, `torus:2`, ...).
    fn kind_spec(&self) -> String {
        match self {
            TraceComm::Fixed { .. } => "fixed".into(),
            TraceComm::Topology { kind, .. } => match kind {
                TopologyKind::Ring => "ring".into(),
                TopologyKind::Tree => "tree".into(),
                TopologyKind::Hierarchical { group } => {
                    format!("hierarchical:{group}")
                }
                TopologyKind::Torus { rows } => format!("torus:{rows}"),
            },
        }
    }
}

/// Transport provenance of a trace recorded by a *real* loopback run
/// ([`crate::transport::run_loopback`]): which socket family carried
/// the collective and the retry/backoff/deadline knobs in force.
/// Replay never consumes these (the recorded samples already embed
/// every real-world effect), but `budget_fit` and audits need to know
/// what produced the data. Optional v2 field: v1 traces and
/// sim-recorded v2 traces simply omit it.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceTransport {
    pub kind: crate::transport::TransportKind,
    /// Failure-detection receive deadline, seconds.
    pub recv_deadline: f64,
    /// Bounded connect/send retry attempts.
    pub connect_attempts: u32,
    /// Exponential backoff base, seconds.
    pub backoff_base: f64,
    /// Backoff ceiling, seconds.
    pub backoff_max: f64,
    /// Jitter fraction in `[0, 1)`.
    pub jitter: f64,
}

impl TraceTransport {
    fn validate(&self) -> Result<()> {
        if !(self.recv_deadline > 0.0) || !self.recv_deadline.is_finite() {
            return Err(Error::Data(
                "trace: transport.recv_deadline must be finite and > 0".into(),
            ));
        }
        if self.connect_attempts == 0 {
            return Err(Error::Data(
                "trace: transport.connect_attempts must be >= 1".into(),
            ));
        }
        if !self.backoff_base.is_finite()
            || !self.backoff_max.is_finite()
            || self.backoff_base < 0.0
            || self.backoff_max < self.backoff_base
        {
            return Err(Error::Data(
                "trace: transport backoff must satisfy 0 <= base <= max"
                    .into(),
            ));
        }
        if !(0.0..1.0).contains(&self.jitter) {
            return Err(Error::Data(
                "trace: transport.jitter must be in [0, 1)".into(),
            ));
        }
        Ok(())
    }
}

/// Run metadata of a [`TraceRecord`]: everything needed to rebuild the
/// recorded sim (minus the latency model, which replay never samples).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceMeta {
    pub version: u64,
    pub mode: TraceMode,
    pub workers: usize,
    pub accums: usize,
    pub seed: u64,
    /// Spec string of the installed [`crate::policy::DropPolicy`].
    pub policy: String,
    pub comm: TraceComm,
    /// The run used the legacy single-restart per-phase semantics
    /// ([`super::ClusterSim::with_single_restart`]). Recorded so replay
    /// restores the exact semantics — otherwise a trace recorded under
    /// the flag would not reproduce bitwise. Serialized only when true
    /// (absent = recursive default).
    pub single_restart: bool,
    /// [`crate::sim::FaultPlan`] spec the run was recorded under
    /// (format v2; `None` = fault-free). Recorded so churn traces
    /// replay under the same membership schedule — the dead seats are
    /// part of the collective's timing. Serialized only when present.
    pub scenario: Option<String>,
    /// Real-socket transport provenance (see [`TraceTransport`]).
    /// `None` for sim-recorded traces. Serialized only when present.
    pub transport: Option<TraceTransport>,
}

/// One recorded step (or Local-SGD period): per worker, the straggler
/// delay and the latency samples the live run drew. In `Period` mode
/// each sample is a whole local step's compute time (straggle folded
/// in) and the straggle column is zero.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepTrace {
    pub straggle: Vec<f64>,
    pub samples: Vec<Vec<f64>>,
}

/// The [`StepOutcome`] the live run produced for one recorded step —
/// the golden values replay must reproduce bitwise.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceOutcome {
    pub iter_time: f64,
    pub compute_time: f64,
    pub worker_compute: Vec<f64>,
    pub completed: Vec<usize>,
}

impl TraceOutcome {
    pub fn from_outcome(out: &StepOutcome) -> Self {
        Self {
            iter_time: out.iter_time,
            compute_time: out.compute_time,
            worker_compute: out.worker_compute.clone(),
            completed: out.completed.clone(),
        }
    }

    /// Bitwise equality against a replayed outcome (floats compared by
    /// bits, not tolerance — this is the conformance contract).
    pub fn matches(&self, out: &StepOutcome) -> bool {
        self.iter_time.to_bits() == out.iter_time.to_bits()
            && self.compute_time.to_bits() == out.compute_time.to_bits()
            && self.completed == out.completed
            && self.worker_compute.len() == out.worker_compute.len()
            && self
                .worker_compute
                .iter()
                .zip(&out.worker_compute)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

/// A replayable recorded run: metadata + per-step draws + the recorded
/// outcomes (see the module docs for the JSON schema).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    pub meta: TraceMeta,
    pub steps: Vec<StepTrace>,
    /// One entry per step when recorded by [`TraceWriter`]; may be
    /// empty in hand-authored records (then only replay-vs-replay
    /// conformance is checkable, not replay-vs-recorded).
    pub outcomes: Vec<TraceOutcome>,
}

fn json_f64_list(vals: &[f64]) -> String {
    let parts: Vec<String> = vals.iter().map(|v| format!("{v:?}")).collect();
    format!("[{}]", parts.join(", "))
}

fn json_usize_list(vals: &[usize]) -> String {
    let parts: Vec<String> = vals.iter().map(|v| v.to_string()).collect();
    format!("[{}]", parts.join(", "))
}

fn req<'a>(obj: &'a Json, key: &str) -> Result<&'a Json> {
    obj.get(key)
        .ok_or_else(|| Error::Data(format!("trace: missing field `{key}`")))
}

fn req_str(obj: &Json, key: &str) -> Result<String> {
    req(obj, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::Data(format!("trace: `{key}` must be a string")))
}

fn req_f64(obj: &Json, key: &str) -> Result<f64> {
    req(obj, key)?
        .as_f64()
        .ok_or_else(|| Error::Data(format!("trace: `{key}` must be a number")))
}

fn req_uint(obj: &Json, key: &str) -> Result<u64> {
    let f = req_f64(obj, key)?;
    if f < 0.0 || f.fract() != 0.0 || !f.is_finite() {
        return Err(Error::Data(format!(
            "trace: `{key}` must be a non-negative integer, got {f}"
        )));
    }
    Ok(f as u64)
}

fn f64_list(v: &Json, what: &str) -> Result<Vec<f64>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| Error::Data(format!("trace: {what} must be an array")))?;
    arr.iter()
        .map(|x| {
            x.as_f64().ok_or_else(|| {
                Error::Data(format!("trace: {what} must hold numbers"))
            })
        })
        .collect()
}

fn usize_list(v: &Json, what: &str) -> Result<Vec<usize>> {
    f64_list(v, what)?
        .into_iter()
        .map(|f| {
            if f < 0.0 || f.fract() != 0.0 {
                Err(Error::Data(format!(
                    "trace: {what} must hold non-negative integers"
                )))
            } else {
                Ok(f as usize)
            }
        })
        .collect()
}

impl TraceRecord {
    /// Recorded steps (or periods).
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Render as the versioned JSON document. Floats use Rust's
    /// shortest round-trip formatting, so `parse(to_json())` is
    /// bitwise-lossless (asserted by the unit tests and the conformance
    /// suite).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str("  \"format\": \"dropcompute-trace\",\n");
        s.push_str(&format!("  \"version\": {},\n", self.meta.version));
        s.push_str(&format!("  \"mode\": \"{}\",\n", self.meta.mode.name()));
        s.push_str(&format!("  \"workers\": {},\n", self.meta.workers));
        s.push_str(&format!("  \"accums\": {},\n", self.meta.accums));
        s.push_str(&format!("  \"seed\": {},\n", self.meta.seed));
        s.push_str(&format!("  \"policy\": \"{}\",\n", self.meta.policy));
        if self.meta.single_restart {
            s.push_str("  \"single_restart\": true,\n");
        }
        if let Some(sc) = &self.meta.scenario {
            s.push_str(&format!("  \"scenario\": \"{sc}\",\n"));
        }
        if let Some(t) = &self.meta.transport {
            s.push_str(&format!(
                "  \"transport\": {{\"kind\": \"{}\", \"recv_deadline\": \
                 {:?}, \"connect_attempts\": {}, \"backoff_base\": {:?}, \
                 \"backoff_max\": {:?}, \"jitter\": {:?}}},\n",
                t.kind.name(),
                t.recv_deadline,
                t.connect_attempts,
                t.backoff_base,
                t.backoff_max,
                t.jitter,
            ));
        }
        match &self.meta.comm {
            TraceComm::Fixed { latency } => {
                s.push_str(&format!(
                    "  \"comm\": {{\"kind\": \"fixed\", \"latency\": {latency:?}}},\n"
                ));
            }
            TraceComm::Topology { latency, bandwidth, bytes, .. } => {
                s.push_str(&format!(
                    "  \"comm\": {{\"kind\": \"{}\", \"latency\": {latency:?}, \
                     \"bandwidth\": {bandwidth:?}, \"bytes\": {bytes:?}}},\n",
                    self.meta.comm.kind_spec()
                ));
            }
        }
        s.push_str("  \"steps\": [\n");
        for (i, st) in self.steps.iter().enumerate() {
            let samples: Vec<String> =
                st.samples.iter().map(|row| json_f64_list(row)).collect();
            s.push_str(&format!(
                "    {{\"straggle\": {}, \"samples\": [{}]}}{}\n",
                json_f64_list(&st.straggle),
                samples.join(", "),
                if i + 1 < self.steps.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n  \"outcomes\": [\n");
        for (i, o) in self.outcomes.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"iter_time\": {:?}, \"compute_time\": {:?}, \
                 \"worker_compute\": {}, \"completed\": {}}}{}\n",
                o.iter_time,
                o.compute_time,
                json_f64_list(&o.worker_compute),
                json_usize_list(&o.completed),
                if i + 1 < self.outcomes.len() { "," } else { "" },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Parse and validate a trace document. Every failure mode —
    /// malformed JSON, missing/mistyped fields, unknown version or
    /// mode, non-finite or negative values, mis-shaped steps — is a
    /// typed [`Error`], never a panic.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = Json::parse(text)?;
        let format = req_str(&doc, "format")?;
        if format != "dropcompute-trace" {
            return Err(Error::Data(format!(
                "trace: not a dropcompute trace (format `{format}`)"
            )));
        }
        let version = req_uint(&doc, "version")?;
        let mode = TraceMode::parse(&req_str(&doc, "mode")?)?;
        let workers = req_uint(&doc, "workers")? as usize;
        let accums = req_uint(&doc, "accums")? as usize;
        let seed = req_uint(&doc, "seed")?;
        let policy = req_str(&doc, "policy")?;
        let single_restart = match doc.get("single_restart") {
            None => false,
            Some(Json::Bool(b)) => *b,
            Some(_) => {
                return Err(Error::Data(
                    "trace: `single_restart` must be a boolean".into(),
                ))
            }
        };
        let scenario = match doc.get("scenario") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| {
                        Error::Data(
                            "trace: `scenario` must be a string".into(),
                        )
                    })?,
            ),
        };
        let transport = match doc.get("transport") {
            None => None,
            Some(t) => Some(TraceTransport {
                kind: crate::transport::TransportKind::parse(&req_str(
                    t, "kind",
                )?)?,
                recv_deadline: req_f64(t, "recv_deadline")?,
                connect_attempts: req_uint(t, "connect_attempts")? as u32,
                backoff_base: req_f64(t, "backoff_base")?,
                backoff_max: req_f64(t, "backoff_max")?,
                jitter: req_f64(t, "jitter")?,
            }),
        };
        let comm_obj = req(&doc, "comm")?;
        let kind = req_str(comm_obj, "kind")?;
        let comm = if kind == "fixed" {
            TraceComm::Fixed { latency: req_f64(comm_obj, "latency")? }
        } else {
            TraceComm::Topology {
                kind: TopologyKind::parse(&kind)?,
                latency: req_f64(comm_obj, "latency")?,
                bandwidth: req_f64(comm_obj, "bandwidth")?,
                bytes: req_f64(comm_obj, "bytes")?,
            }
        };
        let steps_json = req(&doc, "steps")?
            .as_arr()
            .ok_or_else(|| Error::Data("trace: `steps` must be an array".into()))?;
        let mut steps = Vec::with_capacity(steps_json.len());
        for (i, st) in steps_json.iter().enumerate() {
            let straggle =
                f64_list(req(st, "straggle")?, &format!("steps[{i}].straggle"))?;
            let rows = req(st, "samples")?.as_arr().ok_or_else(|| {
                Error::Data(format!("trace: steps[{i}].samples must be an array"))
            })?;
            let samples = rows
                .iter()
                .map(|row| f64_list(row, &format!("steps[{i}].samples")))
                .collect::<Result<Vec<_>>>()?;
            steps.push(StepTrace { straggle, samples });
        }
        let mut outcomes = Vec::new();
        if let Some(outs) = doc.get("outcomes") {
            let outs = outs.as_arr().ok_or_else(|| {
                Error::Data("trace: `outcomes` must be an array".into())
            })?;
            for (i, o) in outs.iter().enumerate() {
                outcomes.push(TraceOutcome {
                    iter_time: req_f64(o, "iter_time")?,
                    compute_time: req_f64(o, "compute_time")?,
                    worker_compute: f64_list(
                        req(o, "worker_compute")?,
                        &format!("outcomes[{i}].worker_compute"),
                    )?,
                    completed: usize_list(
                        req(o, "completed")?,
                        &format!("outcomes[{i}].completed"),
                    )?,
                });
            }
        }
        let record = TraceRecord {
            meta: TraceMeta {
                version,
                mode,
                workers,
                accums,
                seed,
                policy,
                comm,
                single_restart,
                scenario,
                transport,
            },
            steps,
            outcomes,
        };
        record.validate()?;
        Ok(record)
    }

    /// Structural validation (see [`Self::parse`]): version, shapes,
    /// finiteness, and mode-vs-policy consistency.
    pub fn validate(&self) -> Result<()> {
        if !(1..=TRACE_FORMAT_VERSION).contains(&self.meta.version) {
            return Err(Error::Data(format!(
                "trace: unsupported format version {} (this build reads \
                 1..={})",
                self.meta.version, TRACE_FORMAT_VERSION
            )));
        }
        if let Some(spec) = &self.meta.scenario {
            // the recorded fault plan must parse and fit the recorded
            // cluster, or replay could never honor it
            let plan = crate::sim::FaultPlan::parse(spec)?;
            plan.validate_for(self.meta.workers)?;
        }
        if let Some(t) = &self.meta.transport {
            t.validate()?;
        }
        let policy = crate::policy::DropPolicy::parse(&self.meta.policy)?;
        let eff_h = policy.local_sgd_h();
        // one decision, one binding: the same match that rejects the
        // inconsistent mode/policy pairs yields the per-row sample
        // limit, so no later `expect` has to re-derive "checked above"
        let per_row_limit = match (self.meta.mode, eff_h) {
            (TraceMode::Period, None) => {
                return Err(Error::Data(
                    "trace: period mode requires a local-sgd policy clause"
                        .into(),
                ))
            }
            (TraceMode::Step, Some(_)) => {
                return Err(Error::Data(
                    "trace: step mode is inconsistent with a local-sgd policy"
                        .into(),
                ))
            }
            (TraceMode::Period, Some(h)) => h,
            (TraceMode::Step, None) => self.meta.accums,
        };
        let n = self.meta.workers;
        for (i, st) in self.steps.iter().enumerate() {
            if st.straggle.len() != n || st.samples.len() != n {
                return Err(Error::Data(format!(
                    "trace: step {i} is shaped for {}x{} workers, meta says {n}",
                    st.straggle.len(),
                    st.samples.len(),
                )));
            }
            for (w, &v) in st.straggle.iter().enumerate() {
                if !v.is_finite() || v < 0.0 {
                    return Err(Error::Data(format!(
                        "trace: step {i} worker {w}: bad straggle {v}"
                    )));
                }
            }
            for (w, row) in st.samples.iter().enumerate() {
                if row.len() > per_row_limit {
                    return Err(Error::Data(format!(
                        "trace: step {i} worker {w}: {} samples exceed the \
                         {} scheduled per {}",
                        row.len(),
                        per_row_limit,
                        self.meta.mode.name(),
                    )));
                }
                for &v in row {
                    if !v.is_finite() || v < 0.0 {
                        return Err(Error::Data(format!(
                            "trace: step {i} worker {w}: bad sample {v}"
                        )));
                    }
                }
            }
        }
        if !self.outcomes.is_empty() && self.outcomes.len() != self.steps.len()
        {
            return Err(Error::Data(format!(
                "trace: {} outcomes for {} steps",
                self.outcomes.len(),
                self.steps.len()
            )));
        }
        for (i, o) in self.outcomes.iter().enumerate() {
            if o.worker_compute.len() != n || o.completed.len() != n {
                return Err(Error::Data(format!(
                    "trace: outcome {i} is mis-shaped for {n} workers"
                )));
            }
            if !o.iter_time.is_finite() || !o.compute_time.is_finite() {
                return Err(Error::Data(format!(
                    "trace: outcome {i} has non-finite times"
                )));
            }
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Data(format!("trace: cannot read {}: {e}", path.display()))
        })?;
        Self::parse(&text)
    }

    /// Bridge to the dense Algorithm-2 tensor: requires a full `step`
    /// record (every worker drew all `accums` micro-batches, i.e. a
    /// no-drop-policy recording); the straggle folds into each worker's
    /// first micro-batch and the comm column is the model's serial
    /// latency — exactly [`super::ClusterSim::record_trace`]'s
    /// convention.
    pub fn to_trace(&self) -> Result<Trace> {
        if self.meta.mode != TraceMode::Step {
            return Err(Error::Data(
                "trace: only step-mode records convert to the dense tensor"
                    .into(),
            ));
        }
        let (iters, n, m) = (self.steps.len(), self.meta.workers, self.meta.accums);
        let mut dense = Trace::new(iters, n, m);
        let tc = self.meta.comm.to_model().serial_latency(n);
        for (i, st) in self.steps.iter().enumerate() {
            for w in 0..n {
                if st.samples[w].len() != m {
                    return Err(Error::Data(format!(
                        "trace: step {i} worker {w} drew {} of {m} \
                         micro-batches; the dense tensor needs a full \
                         (no-drop) recording",
                        st.samples[w].len()
                    )));
                }
                for (j, &s) in st.samples[w].iter().enumerate() {
                    let t = if j == 0 { s + st.straggle[w] } else { s };
                    dense.set(i, w, j, t);
                }
            }
            dense.comm[i] = tc;
        }
        Ok(dense)
    }
}

/// Incremental [`TraceRecord`] builder owned by a recording
/// [`super::ClusterSim`] (see `ClusterSim::start_recording`). Collects
/// per-worker draws and per-step outcomes; [`TraceWriter::finish`]
/// returns a typed error if the recorded steps diverged from the
/// installed policy (per-call thresholds, mode changes, mid-recording
/// policy swaps) — the metadata would otherwise lie about what the
/// steps ran under.
#[derive(Debug)]
pub struct TraceWriter {
    meta: TraceMeta,
    steps: Vec<StepTrace>,
    outcomes: Vec<TraceOutcome>,
    cur: StepTrace,
    problem: Option<String>,
}

impl TraceWriter {
    pub fn new(meta: TraceMeta) -> Self {
        Self {
            meta,
            steps: Vec::new(),
            outcomes: Vec::new(),
            cur: StepTrace::default(),
            problem: None,
        }
    }

    /// Open a new step. `matches_installed` is the sim's check that the
    /// per-call knobs (threshold, period) equal the installed policy's.
    pub fn begin_step(&mut self, mode: TraceMode, matches_installed: bool) {
        if !matches_installed && self.problem.is_none() {
            self.problem = Some(
                "a step ran with per-call knobs diverging from the installed \
                 policy; install the full DropPolicy before recording"
                    .into(),
            );
        }
        if mode != self.meta.mode && self.problem.is_none() {
            self.problem = Some(format!(
                "a {} was recorded into a {} trace",
                mode.name(),
                self.meta.mode.name()
            ));
        }
        self.cur = StepTrace::default();
    }

    pub fn push_worker(&mut self, straggle: f64, samples: &[f64]) {
        self.cur.straggle.push(straggle);
        self.cur.samples.push(samples.to_vec());
    }

    pub fn push_outcome(&mut self, out: &StepOutcome) {
        self.steps.push(std::mem::take(&mut self.cur));
        self.outcomes.push(TraceOutcome::from_outcome(out));
    }

    /// The sim's policy changed mid-recording.
    pub fn mark_policy_changed(&mut self) {
        if self.problem.is_none() {
            self.problem =
                Some("the drop policy changed mid-recording".into());
        }
    }

    pub fn finish(self) -> Result<TraceRecord> {
        if let Some(p) = self.problem {
            return Err(Error::Runtime(format!(
                "trace recording inconsistent: {p}"
            )));
        }
        let record = TraceRecord {
            meta: self.meta,
            steps: self.steps,
            outcomes: self.outcomes,
        };
        record.validate()?;
        Ok(record)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new(2, 3, 4);
        for i in 0..2 {
            t.comm[i] = 0.1 * (i + 1) as f64;
            for n in 0..3 {
                for m in 0..4 {
                    t.set(i, n, m, (i + n + m) as f64 * 0.01 + 0.1);
                }
            }
        }
        t
    }

    #[test]
    fn cumsum_and_step_time() {
        let t = sample();
        assert!((t.cumsum(0, 0, 1) - (0.1 + 0.11)).abs() < 1e-12);
        // worker 2 is slowest at iter 0
        assert!((t.step_time(0) - t.worker_step_time(0, 2)).abs() < 1e-12);
    }

    #[test]
    fn moments() {
        let t = sample();
        let (mean, var) = t.microbatch_moments();
        assert!(mean > 0.1 && var > 0.0);
        assert!((t.mean_comm() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let dir = std::env::temp_dir().join("dc_trace_test");
        let path = dir.join("t.csv");
        t.save_csv(&path).unwrap();
        let loaded = Trace::load_csv(&path).unwrap();
        assert_eq!(t.iters, loaded.iters);
        for i in 0..t.iters {
            for n in 0..t.workers {
                for m in 0..t.accums {
                    assert!((t.get(i, n, m) - loaded.get(i, n, m)).abs() < 1e-8);
                }
            }
            assert!((t.comm[i] - loaded.comm[i]).abs() < 1e-8);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("dc_trace_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "nonsense\n1,2,3\n").unwrap();
        assert!(Trace::load_csv(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn sample_record() -> TraceRecord {
        TraceRecord {
            meta: TraceMeta {
                version: TRACE_FORMAT_VERSION,
                mode: TraceMode::Step,
                workers: 2,
                accums: 3,
                seed: 7,
                policy: "deadline=0.75".into(),
                comm: TraceComm::Topology {
                    kind: TopologyKind::Ring,
                    latency: 1e-3,
                    bandwidth: 1e9,
                    bytes: 4e6,
                },
                single_restart: false,
                scenario: None,
                transport: None,
            },
            steps: vec![
                StepTrace {
                    straggle: vec![0.0, 2.5],
                    samples: vec![vec![0.4, 0.45, 0.5], vec![0.4, 0.6, 0.41]],
                },
                StepTrace {
                    straggle: vec![0.1, 0.0],
                    // third root of two etc: values with no short
                    // decimal form must still round-trip bitwise
                    samples: vec![
                        vec![2f64.sqrt(), 0.1 + 0.2, 1.0 / 3.0],
                        vec![0.45, 0.45, 0.45],
                    ],
                },
            ],
            outcomes: vec![
                TraceOutcome {
                    iter_time: 4.125,
                    compute_time: 3.9099999999,
                    worker_compute: vec![1.35, 3.9099999999],
                    completed: vec![3, 3],
                },
                TraceOutcome {
                    iter_time: 2.0,
                    compute_time: 1.9,
                    worker_compute: vec![1.9, 1.35],
                    completed: vec![3, 3],
                },
            ],
        }
    }

    #[test]
    fn record_json_roundtrip_is_bitwise() {
        let r = sample_record();
        let parsed = TraceRecord::parse(&r.to_json()).unwrap();
        assert_eq!(parsed.meta, r.meta);
        assert_eq!(parsed.steps.len(), r.steps.len());
        for (a, b) in r.steps.iter().zip(&parsed.steps) {
            for (x, y) in a.straggle.iter().zip(&b.straggle) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (ra, rb) in a.samples.iter().zip(&b.samples) {
                assert_eq!(ra.len(), rb.len());
                for (x, y) in ra.iter().zip(rb) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
        for (a, b) in r.outcomes.iter().zip(&parsed.outcomes) {
            assert_eq!(a.iter_time.to_bits(), b.iter_time.to_bits());
            assert_eq!(a.compute_time.to_bits(), b.compute_time.to_bits());
            assert_eq!(a.completed, b.completed);
        }
        // save/load through disk too
        let dir = std::env::temp_dir().join("dc_trace_record");
        let path = dir.join("r.trace.json");
        r.save(&path).unwrap();
        let loaded = TraceRecord::load(&path).unwrap();
        assert_eq!(loaded, parsed);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_parse_rejects_malformed_documents_with_typed_errors() {
        let good = sample_record().to_json();
        // each mutation must fail with an Err, never panic
        let cases: Vec<String> = vec![
            "not json at all".into(),
            "{}".into(),
            good.replace("dropcompute-trace", "other-format"),
            good.replace("\"version\": 2", "\"version\": 99"),
            good.replace("\"mode\": \"step\"", "\"mode\": \"sideways\""),
            good.replace("\"kind\": \"ring\"", "\"kind\": \"moebius\""),
            good.replace("\"workers\": 2", "\"workers\": 5"), // shape lie
            good.replace("2.5", "-2.5"),                      // negative straggle
            good.replace("0.45, 0.45, 0.45", "0.45, 1e999, 0.45"), // inf sample
            good.replace(
                "\"policy\": \"deadline=0.75\"",
                "\"policy\": \"wat=1\"",
            ),
            good.replace(
                "\"policy\": \"deadline=0.75\"",
                "\"policy\": \"local-sgd=3\"",
            ), // period policy on a step trace
            good.replace("\"completed\": [3, 3]", "\"completed\": [3, -1]"),
        ];
        for (i, text) in cases.iter().enumerate() {
            assert!(
                TraceRecord::parse(text).is_err(),
                "case {i} should be rejected"
            );
        }
        // a trace with too many samples per worker is rejected
        let mut fat = sample_record();
        fat.steps[0].samples[0].push(0.5);
        assert!(fat.validate().is_err());
        // mismatched outcome count is rejected
        let mut odd = sample_record();
        odd.outcomes.pop();
        assert!(odd.validate().is_err());
    }

    #[test]
    fn version_1_documents_still_parse() {
        let v1 = sample_record()
            .to_json()
            .replace("\"version\": 2", "\"version\": 1");
        let rec = TraceRecord::parse(&v1).unwrap();
        assert_eq!(rec.meta.version, 1);
        assert_eq!(rec.meta.scenario, None);
    }

    #[test]
    fn scenario_meta_roundtrips_and_is_validated() {
        let mut r = sample_record();
        r.meta.scenario = Some("fail@1:w0,rejoin+3;slow@0:w1,x2".into());
        let parsed = TraceRecord::parse(&r.to_json()).unwrap();
        assert_eq!(parsed.meta.scenario, r.meta.scenario);
        assert_eq!(parsed, r);
        // a scenario that does not parse is rejected
        let mut bad = sample_record();
        bad.meta.scenario = Some("explode@3".into());
        assert!(bad.validate().is_err());
        // so is one naming a worker outside the recorded cluster
        let mut oob = sample_record();
        oob.meta.scenario = Some("fail@1:w9".into());
        assert!(oob.validate().is_err());
        // and a non-string field in the document
        let doc = sample_record()
            .to_json()
            .replace("\"seed\": 7,", "\"seed\": 7,\n  \"scenario\": 3,");
        assert!(TraceRecord::parse(&doc).is_err());
    }

    #[test]
    fn transport_meta_roundtrips_and_is_validated() {
        let mut r = sample_record();
        r.meta.transport = Some(TraceTransport {
            kind: crate::transport::TransportKind::Uds,
            recv_deadline: 30.0,
            connect_attempts: 5,
            backoff_base: 0.005,
            backoff_max: 0.25,
            jitter: 0.2,
        });
        let text = r.to_json();
        assert!(text.contains("\"transport\""));
        let parsed = TraceRecord::parse(&text).unwrap();
        assert_eq!(parsed.meta.transport, r.meta.transport);
        assert_eq!(parsed, r);
        // sim-recorded traces omit the block entirely, and still parse
        let sim_only = sample_record();
        assert!(!sim_only.to_json().contains("transport"));
        assert_eq!(
            TraceRecord::parse(&sim_only.to_json()).unwrap().meta.transport,
            None
        );
        // bad knob values are typed errors
        for mutate in [
            |t: &mut TraceTransport| t.recv_deadline = 0.0,
            |t: &mut TraceTransport| t.recv_deadline = f64::NAN,
            |t: &mut TraceTransport| t.connect_attempts = 0,
            |t: &mut TraceTransport| t.backoff_max = 0.001, // < base
            |t: &mut TraceTransport| t.jitter = 1.0,
        ] {
            let mut bad = r.clone();
            mutate(bad.meta.transport.as_mut().unwrap());
            assert!(bad.validate().is_err());
        }
        // unknown transport kinds in the document are rejected
        let doc = text.replace("\"kind\": \"uds\"", "\"kind\": \"pigeon\"");
        assert!(TraceRecord::parse(&doc).is_err());
    }

    #[test]
    fn record_to_dense_trace_bridges_full_recordings() {
        let mut r = sample_record();
        r.meta.policy = "none".into();
        let dense = r.to_trace().unwrap();
        assert_eq!((dense.iters, dense.workers, dense.accums), (2, 2, 3));
        // straggle folds into the first micro-batch
        assert_eq!(dense.get(0, 1, 0).to_bits(), (0.4f64 + 2.5).to_bits());
        assert_eq!(dense.get(0, 1, 1).to_bits(), 0.6f64.to_bits());
        // comm column is the model's serial latency
        let want = r.meta.comm.to_model().serial_latency(2);
        assert_eq!(dense.comm[0].to_bits(), want.to_bits());
        // a truncated (dropped) recording cannot bridge
        let mut short = r.clone();
        short.steps[0].samples[0].pop();
        assert!(short.to_trace().is_err());
        // nor can a period recording
        let mut period = r;
        period.meta.mode = TraceMode::Period;
        period.meta.policy = "local-sgd=3".into();
        assert!(period.to_trace().is_err());
    }

    #[test]
    fn writer_collects_steps_and_flags_inconsistency() {
        let meta = sample_record().meta;
        let mut w = TraceWriter::new(meta.clone());
        w.begin_step(TraceMode::Step, true);
        w.push_worker(0.0, &[0.4, 0.45, 0.5]);
        w.push_worker(2.5, &[0.4, 0.6, 0.41]);
        let out = StepOutcome {
            worker_compute: vec![1.35, 3.41],
            completed: vec![3, 3],
            compute_time: 3.41,
            iter_time: 4.0,
        };
        w.push_outcome(&out);
        let rec = w.finish().unwrap();
        assert_eq!(rec.len(), 1);
        assert!(rec.outcomes[0].matches(&out));
        // a diverging per-call threshold poisons the recording
        let mut w = TraceWriter::new(meta.clone());
        w.begin_step(TraceMode::Step, false);
        w.push_worker(0.0, &[0.4, 0.45, 0.5]);
        w.push_worker(0.0, &[0.4, 0.6, 0.41]);
        w.push_outcome(&out);
        assert!(w.finish().is_err());
        // so does a mode flip
        let mut w = TraceWriter::new(meta);
        w.begin_step(TraceMode::Period, true);
        w.push_worker(0.0, &[0.4, 0.45, 0.5]);
        w.push_worker(0.0, &[0.4, 0.6, 0.41]);
        w.push_outcome(&out);
        assert!(w.finish().is_err());
    }
}
