//! [`FaultPlan`] — deterministic fault injection for the scenario lab.
//!
//! A plan is a scripted list of membership / speed events, each pinned
//! to a virtual step index:
//!
//! * **fail** — the worker leaves the cluster at step `s`, either
//!   permanently or rejoining `r` steps later;
//! * **slow** — a transient (or permanent) multiplicative slowdown
//!   window starting at step `s`;
//! * **drift** — a permanent slow-drift: the worker's latency scale
//!   grows linearly from step `s` on (the scripted stand-in for a
//!   per-worker mean that walks away from the fleet).
//!
//! Plans are *pure functions of `(worker, step)`*: [`FaultPlan::alive`]
//! and [`FaultPlan::scale`] consult only the event list, so the same
//! seed + the same plan reproduce the same run bit for bit on both
//! timing paths, and replay needs nothing beyond the plan itself
//! (carried in the v2 [`crate::sim::TraceRecord`] meta).
//!
//! Plans round-trip through a spec-string grammar shared by the CLI
//! (`--scenario`), the `[scenario]` config section, the sweep axis and
//! the trace meta:
//!
//! ```text
//! spec   := "none" | clause (';' clause)*
//! clause := "fail@" step ":w" worker ["," "rejoin+" steps]
//!         | "kill@" step ":w" worker
//!         | "slow@" step ":w" worker ",x" factor ["," "for" steps]
//!         | "drift@" step ":w" worker ",+" rate
//! ```
//!
//! e.g. `fail@100:w3,rejoin+50`, `slow@20:w1,x2.5,for30`,
//! `drift@0:w2,+0.05`, or several joined with `;`. The separator is
//! `;` (not the policy grammar's `+`) because clauses themselves
//! contain `+`. `kill@S:wN` is sugar for a permanent `fail@S:wN` —
//! the transport fault injector's vocabulary for "this worker dies
//! and never rejoins" — and renders back as `fail@S:wN` (the two are
//! the same event; `spec()` picks the canonical form).

use crate::rng::SplitMix64;
use crate::util::{Error, Result};

/// One scripted fault event (see the module docs for the grammar).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// Worker `worker` fails at `step`; with `rejoin = Some(r)` it is
    /// live again from `step + r` on, with `None` it never returns.
    Fail { step: u64, worker: usize, rejoin: Option<u64> },
    /// Worker `worker`'s per-micro-batch latency is multiplied by
    /// `factor` from `step` on; `duration = Some(d)` limits the window
    /// to steps `[step, step + d)`, `None` makes it permanent.
    Slow { step: u64, worker: usize, factor: f64, duration: Option<u64> },
    /// Permanent slow-drift: from `step` on the worker's latency scale
    /// is multiplied by `1 + rate * (current_step - step)`.
    Drift { step: u64, worker: usize, rate: f64 },
}

impl FaultEvent {
    /// The worker this event targets.
    pub fn worker(&self) -> usize {
        match self {
            FaultEvent::Fail { worker, .. }
            | FaultEvent::Slow { worker, .. }
            | FaultEvent::Drift { worker, .. } => *worker,
        }
    }

    /// The step this event activates at.
    pub fn step(&self) -> u64 {
        match self {
            FaultEvent::Fail { step, .. }
            | FaultEvent::Slow { step, .. }
            | FaultEvent::Drift { step, .. } => *step,
        }
    }

    fn spec(&self) -> String {
        match self {
            FaultEvent::Fail { step, worker, rejoin } => match rejoin {
                Some(r) => format!("fail@{step}:w{worker},rejoin+{r}"),
                None => format!("fail@{step}:w{worker}"),
            },
            FaultEvent::Slow { step, worker, factor, duration } => {
                match duration {
                    Some(d) => {
                        format!("slow@{step}:w{worker},x{factor},for{d}")
                    }
                    None => format!("slow@{step}:w{worker},x{factor}"),
                }
            }
            FaultEvent::Drift { step, worker, rate } => {
                format!("drift@{step}:w{worker},+{rate}")
            }
        }
    }
}

/// A deterministic fault-injection plan: a validated list of
/// [`FaultEvent`]s. The empty plan (`FaultPlan::default()`, spec
/// `none`) injects nothing and leaves every consumer on its exact
/// pre-scenario code path.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build from an explicit event list. Validates.
    pub fn new(events: Vec<FaultEvent>) -> Result<Self> {
        let plan = FaultPlan { events };
        plan.validate()?;
        Ok(plan)
    }

    /// The scripted events, in spec order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// No events at all?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Is worker `worker` live at `step`? A worker inside a fail
    /// interval (`[s, s + r)`, or `[s, inf)` when permanent) is dead:
    /// it draws nothing, computes nothing, and is excluded from the
    /// collective. Workers the plan never mentions are always live, so
    /// a plan written for a big cluster is inert on a small one.
    pub fn alive(&self, worker: usize, step: u64) -> bool {
        for e in &self.events {
            if let FaultEvent::Fail { step: s, worker: w, rejoin } = e {
                if *w == worker && step >= *s {
                    match rejoin {
                        None => return false,
                        Some(r) => {
                            if step < s.saturating_add(*r) {
                                return false;
                            }
                        }
                    }
                }
            }
        }
        true
    }

    /// The latency scale multiplier for worker `worker` at `step`: the
    /// product of every active slow window's factor and every active
    /// drift's `1 + rate * (step - start)`. Exactly `1.0` when nothing
    /// is active (multiplying a draw by `1.0` is a bitwise no-op, so
    /// inert plans perturb nothing).
    pub fn scale(&self, worker: usize, step: u64) -> f64 {
        let mut scale = 1.0f64;
        for e in &self.events {
            match e {
                FaultEvent::Slow { step: s, worker: w, factor, duration }
                    if *w == worker && step >= *s =>
                {
                    let active = match duration {
                        None => true,
                        Some(d) => step < s.saturating_add(*d),
                    };
                    if active {
                        scale *= factor;
                    }
                }
                FaultEvent::Drift { step: s, worker: w, rate }
                    if *w == worker && step >= *s =>
                {
                    scale *= 1.0 + rate * (step - s) as f64;
                }
                // inactive windows / other workers: guards above failed
                FaultEvent::Fail { .. }
                | FaultEvent::Slow { .. }
                | FaultEvent::Drift { .. } => {}
            }
        }
        scale
    }

    /// Does any event rescale latency (vs pure membership churn)?
    pub fn has_scaling(&self) -> bool {
        self.events.iter().any(|e| {
            matches!(e, FaultEvent::Slow { .. } | FaultEvent::Drift { .. })
        })
    }

    /// Any of the first `workers` workers dead at `step`?
    pub fn any_dead(&self, workers: usize, step: u64) -> bool {
        (0..workers).any(|n| !self.alive(n, step))
    }

    /// Live workers among the first `workers` at `step`.
    pub fn live_count(&self, workers: usize, step: u64) -> usize {
        (0..workers).filter(|&n| self.alive(n, step)).count()
    }

    /// The largest worker id any event targets.
    pub fn max_worker(&self) -> Option<usize> {
        self.events.iter().map(FaultEvent::worker).max()
    }

    /// Structural validation (grammar-level; worker-range checks need a
    /// cluster size — see [`Self::validate_for`]): rejoin/for spans
    /// must be >= 1 step, slow factors finite and > 0, drift rates
    /// finite and >= 0, per-worker fail intervals and slow windows must
    /// not overlap, and at most one drift per worker.
    pub fn validate(&self) -> Result<()> {
        for e in &self.events {
            match e {
                FaultEvent::Fail { rejoin: Some(0), .. } => {
                    return Err(Error::Config(format!(
                        "scenario: `{}`: rejoin span must be >= 1 step \
                         (a rejoin cannot precede its fail)",
                        e.spec()
                    )));
                }
                FaultEvent::Slow { factor, duration, .. } => {
                    if !(factor.is_finite() && *factor > 0.0) {
                        return Err(Error::Config(format!(
                            "scenario: `{}`: slow factor must be finite \
                             and > 0",
                            e.spec()
                        )));
                    }
                    if *duration == Some(0) {
                        return Err(Error::Config(format!(
                            "scenario: `{}`: slow window must be >= 1 step",
                            e.spec()
                        )));
                    }
                }
                FaultEvent::Drift { rate, .. } => {
                    if !(rate.is_finite() && *rate >= 0.0) {
                        return Err(Error::Config(format!(
                            "scenario: `{}`: drift rate must be finite \
                             and >= 0",
                            e.spec()
                        )));
                    }
                }
                // remaining Fail shapes: the Some(0) arm above is the
                // only structurally invalid one
                FaultEvent::Fail { .. } => {}
            }
        }
        // Per-worker interval overlap checks. Intervals are
        // `[start, end)` with `end = None` meaning unbounded.
        let overlaps = |a: (u64, Option<u64>), b: (u64, Option<u64>)| {
            let a_before_b = a.1.is_some_and(|end| end <= b.0);
            let b_before_a = b.1.is_some_and(|end| end <= a.0);
            !(a_before_b || b_before_a)
        };
        let span = |start: u64, len: Option<u64>| {
            (start, len.map(|l| start.saturating_add(l)))
        };
        for (i, a) in self.events.iter().enumerate() {
            for b in &self.events[i + 1..] {
                if a.worker() != b.worker() {
                    continue;
                }
                let clash = match (a, b) {
                    (
                        FaultEvent::Fail { step: s1, rejoin: r1, .. },
                        FaultEvent::Fail { step: s2, rejoin: r2, .. },
                    ) => overlaps(span(*s1, *r1), span(*s2, *r2)),
                    (
                        FaultEvent::Slow { step: s1, duration: d1, .. },
                        FaultEvent::Slow { step: s2, duration: d2, .. },
                    ) => overlaps(span(*s1, *d1), span(*s2, *d2)),
                    (
                        FaultEvent::Drift { .. },
                        FaultEvent::Drift { .. },
                    ) => true,
                    // mixed kinds never clash: each pair rule above is
                    // same-kind, and fail/slow/drift windows coexist
                    (FaultEvent::Fail { .. }, _)
                    | (FaultEvent::Slow { .. }, _)
                    | (FaultEvent::Drift { .. }, _) => false,
                };
                if clash {
                    return Err(Error::Config(format!(
                        "scenario: `{}` overlaps `{}` on worker {}",
                        a.spec(),
                        b.spec(),
                        a.worker()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Validate against a concrete cluster size: every targeted worker
    /// id must be `< workers`. The single-run CLI/config boundary calls
    /// this; the sweep's worker axis deliberately does not (events
    /// beyond the current point's cluster are inert, see
    /// [`Self::alive`]).
    pub fn validate_for(&self, workers: usize) -> Result<()> {
        if let Some(w) = self.max_worker() {
            if w >= workers {
                return Err(Error::Config(format!(
                    "scenario: worker id w{w} out of range for a \
                     {workers}-worker cluster"
                )));
            }
        }
        Ok(())
    }

    /// Validate against a run horizon of `horizon` steps (steps
    /// `0..horizon`): a `rejoin` that lands at or beyond the horizon
    /// can never fire — the worker is dead for the rest of the run and
    /// the spec's `rejoin+R` is silently inert, which is almost always
    /// a typo'd span or a too-short run. Rejected with a typed error
    /// instead (write `fail@S:wN` / `kill@S:wN` for a permanent loss).
    /// Events *starting* at or beyond the horizon stay legal: plans are
    /// written to be inert on shorter runs (see [`Self::alive`]).
    pub fn validate_horizon(&self, horizon: u64) -> Result<()> {
        for e in &self.events {
            if let FaultEvent::Fail { step, rejoin: Some(r), .. } = e {
                if *step < horizon && step.saturating_add(*r) >= horizon {
                    return Err(Error::Config(format!(
                        "scenario: `{}`: rejoin at step {} is at/beyond \
                         the {horizon}-step run horizon and would never \
                         fire — use fail@{step}:w{} (or kill@) for a \
                         permanent failure, or extend the run",
                        e.spec(),
                        step.saturating_add(*r),
                        e.worker(),
                    )));
                }
            }
        }
        Ok(())
    }

    /// Parse a spec string (see the module-docs grammar). Validates.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(Error::Config("scenario: empty spec".into()));
        }
        if spec.eq_ignore_ascii_case("none") {
            return Ok(FaultPlan::default());
        }
        let mut events = Vec::new();
        for clause in spec.split(';') {
            events.push(Self::parse_clause(clause.trim())?);
        }
        Self::new(events)
    }

    fn parse_clause(clause: &str) -> Result<FaultEvent> {
        let bad = |why: &str| {
            Error::Config(format!(
                "scenario: bad clause `{clause}`: {why} (want \
                 fail@S:wN[,rejoin+R], kill@S:wN, slow@S:wN,xF[,forD] \
                 or drift@S:wN,+R)"
            ))
        };
        let (kind, rest) =
            clause.split_once('@').ok_or_else(|| bad("missing `@`"))?;
        let (step_str, tail) =
            rest.split_once(':').ok_or_else(|| bad("missing `:`"))?;
        let step: u64 = step_str
            .trim()
            .parse()
            .map_err(|_| bad(&format!("bad step `{step_str}`")))?;
        let mut parts = tail.split(',').map(str::trim);
        let wtok = parts.next().unwrap_or("");
        let worker: usize = wtok
            .strip_prefix('w')
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| bad(&format!("bad worker `{wtok}` (want wN)")))?;
        let event = match kind.trim() {
            "fail" => {
                let rejoin = match parts.next() {
                    None => None,
                    Some(tok) => {
                        let r: u64 = tok
                            .strip_prefix("rejoin+")
                            .and_then(|s| s.parse().ok())
                            .ok_or_else(|| {
                                bad(&format!(
                                    "bad rejoin `{tok}` (want rejoin+R)"
                                ))
                            })?;
                        Some(r)
                    }
                };
                FaultEvent::Fail { step, worker, rejoin }
            }
            // `kill` is the no-rejoin alias: a permanent fail. A rejoin
            // argument contradicts the word, so it is rejected rather
            // than silently reinterpreted.
            "kill" => {
                if let Some(extra) = parts.next() {
                    return Err(bad(&format!(
                        "kill takes no arguments (got `{extra}`); a \
                         killed worker never rejoins — use \
                         fail@S:wN,rejoin+R for that"
                    )));
                }
                FaultEvent::Fail { step, worker, rejoin: None }
            }
            "slow" => {
                let ftok = parts.next().ok_or_else(|| bad("missing xF"))?;
                let factor: f64 = ftok
                    .strip_prefix('x')
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| {
                        bad(&format!("bad factor `{ftok}` (want xF)"))
                    })?;
                let duration = match parts.next() {
                    None => None,
                    Some(tok) => {
                        let d: u64 = tok
                            .strip_prefix("for")
                            .and_then(|s| s.parse().ok())
                            .ok_or_else(|| {
                                bad(&format!("bad window `{tok}` (want forD)"))
                            })?;
                        Some(d)
                    }
                };
                FaultEvent::Slow { step, worker, factor, duration }
            }
            "drift" => {
                let rtok = parts.next().ok_or_else(|| bad("missing +R"))?;
                let rate: f64 = rtok
                    .strip_prefix('+')
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| {
                        bad(&format!("bad rate `{rtok}` (want +R)"))
                    })?;
                FaultEvent::Drift { step, worker, rate }
            }
            other => return Err(bad(&format!("unknown kind `{other}`"))),
        };
        if let Some(extra) = parts.next() {
            return Err(bad(&format!("trailing `{extra}`")));
        }
        Ok(event)
    }

    /// Render back to the spec-string grammar (round-trips through
    /// [`Self::parse`]; carried in trace metas and sweep JSON).
    pub fn spec(&self) -> String {
        if self.events.is_empty() {
            return "none".into();
        }
        let parts: Vec<String> =
            self.events.iter().map(FaultEvent::spec).collect();
        parts.join(";")
    }

    /// A seeded scripted plan over `workers` workers and `horizon`
    /// steps: each worker independently draws at most one role (fail +
    /// rejoin, transient slow window, or drift) from a SplitMix64
    /// stream, so the event list is deterministic in `seed` and never
    /// self-overlaps. `spec()` of the result round-trips like any
    /// scripted plan.
    pub fn seeded(seed: u64, workers: usize, horizon: u64) -> Self {
        const SEED_DOMAIN: u64 = 0xFA17_7FA7_5EED_0001;
        let mut rng = SplitMix64::new(seed ^ SEED_DOMAIN);
        let horizon = horizon.max(1);
        let mut events = Vec::new();
        for worker in 0..workers {
            let roll = rng.next_u64() % 8;
            let step = rng.next_u64() % horizon;
            let span = 1 + rng.next_u64() % horizon.div_ceil(4).max(1);
            match roll {
                // 2/8 fail + rejoin, 1/8 transient slow, 1/8 drift.
                0 | 1 => events.push(FaultEvent::Fail {
                    step,
                    worker,
                    rejoin: Some(span),
                }),
                2 => events.push(FaultEvent::Slow {
                    step,
                    worker,
                    factor: 1.5 + (rng.next_u64() % 256) as f64 / 128.0,
                    duration: Some(span),
                }),
                3 => events.push(FaultEvent::Drift {
                    step,
                    worker,
                    rate: (1 + rng.next_u64() % 64) as f64 / 1024.0,
                }),
                _ => {}
            }
        }
        let plan = FaultPlan { events };
        debug_assert!(plan.validate().is_ok());
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_clause_shape() {
        for spec in [
            "none",
            "fail@100:w3",
            "fail@100:w3,rejoin+50",
            "slow@20:w1,x2.5",
            "slow@20:w1,x2.5,for30",
            "drift@0:w2,+0.05",
            "fail@100:w3,rejoin+50;slow@20:w1,x2.5,for30;drift@0:w2,+0.05",
            "fail@10:w0,rejoin+5;fail@40:w0,rejoin+5",
        ] {
            let p = FaultPlan::parse(spec).expect(spec);
            assert_eq!(p.spec(), spec, "spec round trip");
            let again = FaultPlan::parse(&p.spec()).expect(spec);
            assert_eq!(p, again, "{spec}");
        }
    }

    #[test]
    fn parse_rejects_garbage_with_typed_errors() {
        for spec in [
            "",
            "fail",
            "fail@",
            "fail@abc:w1",
            "fail@3",
            "fail@3:x1",
            "fail@3:w",
            "fail@3:w-1",
            "fail@3:w1,rejoin+0",
            "fail@3:w1,rejoin-2",
            "fail@3:w1,rejoin+2,extra",
            "slow@3:w1",
            "slow@3:w1,x0",
            "slow@3:w1,x-2",
            "slow@3:w1,xNaN",
            "slow@3:w1,x2,for0",
            "slow@3:w1,x2,four5",
            "drift@3:w1",
            "drift@3:w1,+-1",
            "drift@3:w1,+inf",
            "wat@3:w1",
            "fail@3:w1;;fail@9:w2",
            // duplicate / overlapping events on one worker
            "fail@3:w1;fail@3:w1",
            "fail@3:w1,rejoin+10;fail@8:w1,rejoin+2",
            "fail@3:w1;fail@900:w1",
            "slow@3:w1,x2;slow@4:w1,x3",
            "drift@3:w1,+0.1;drift@9:w1,+0.2",
        ] {
            let err = FaultPlan::parse(spec);
            assert!(err.is_err(), "{spec:?} should be rejected");
            let msg = format!("{}", err.unwrap_err());
            assert!(msg.contains("scenario"), "typed error for {spec:?}: {msg}");
        }
    }

    #[test]
    fn disjoint_events_on_one_worker_are_fine() {
        for spec in [
            "fail@3:w1,rejoin+2;fail@5:w1,rejoin+2",
            "slow@0:w1,x2,for10;slow@10:w1,x3",
            "fail@3:w1,rejoin+2;slow@3:w1,x2",
        ] {
            FaultPlan::parse(spec).expect(spec);
        }
    }

    #[test]
    fn alive_tracks_fail_and_rejoin_windows() {
        let p = FaultPlan::parse("fail@10:w1,rejoin+5;fail@20:w2").unwrap();
        assert!(p.alive(1, 9));
        assert!(!p.alive(1, 10));
        assert!(!p.alive(1, 14));
        assert!(p.alive(1, 15));
        assert!(p.alive(2, 19));
        assert!(!p.alive(2, 20));
        assert!(!p.alive(2, 1_000_000));
        // untouched / out-of-plan workers are always live
        assert!(p.alive(0, 12));
        assert!(p.alive(7, 12));
        assert!(p.any_dead(3, 12));
        assert!(!p.any_dead(3, 9));
        assert_eq!(p.live_count(3, 12), 2);
        assert_eq!(p.live_count(3, 25), 2);
    }

    #[test]
    fn scale_composes_slow_windows_and_drift() {
        let p =
            FaultPlan::parse("slow@10:w0,x2,for5;drift@20:w0,+0.5").unwrap();
        assert_eq!(p.scale(0, 9), 1.0);
        assert_eq!(p.scale(0, 10), 2.0);
        assert_eq!(p.scale(0, 14), 2.0);
        assert_eq!(p.scale(0, 15), 1.0);
        assert_eq!(p.scale(0, 20), 1.0);
        assert_eq!(p.scale(0, 22), 2.0);
        // another worker is untouched — exactly 1.0
        assert_eq!(p.scale(1, 22).to_bits(), 1.0f64.to_bits());
        assert!(p.has_scaling());
        assert!(!FaultPlan::parse("fail@1:w0").unwrap().has_scaling());
    }

    #[test]
    fn kill_is_a_permanent_fail_alias() {
        let k = FaultPlan::parse("kill@7:w2").unwrap();
        let f = FaultPlan::parse("fail@7:w2").unwrap();
        assert_eq!(k, f, "kill parses to the same event as fail");
        // canonical rendering: spec() emits the fail form, which still
        // round-trips
        assert_eq!(k.spec(), "fail@7:w2");
        assert_eq!(FaultPlan::parse(&k.spec()).unwrap(), k);
        assert!(!k.alive(2, 7));
        assert!(!k.alive(2, u64::MAX));
        // mixed clauses work; kill + rejoin is a contradiction
        FaultPlan::parse("kill@3:w0;slow@1:w1,x2.0").unwrap();
        for spec in ["kill@3:w0,rejoin+5", "kill@3:w0,x2", "kill@3:w0,extra"]
        {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(
                format!("{err}").contains("scenario"),
                "{spec}: {err}"
            );
        }
    }

    #[test]
    fn rejoin_beyond_horizon_is_rejected_not_inert() {
        let p = FaultPlan::parse("fail@10:w1,rejoin+5").unwrap();
        // rejoin at step 15: fine for >= 16 steps, dead weight below
        assert!(p.validate_horizon(16).is_ok());
        for horizon in [15, 12, 11] {
            let err = p.validate_horizon(horizon).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains("scenario"), "{msg}");
            assert!(msg.contains("horizon"), "{msg}");
        }
        // a fail that *starts* beyond the horizon stays legal (plans
        // are allowed to be inert on shorter runs)...
        let late = FaultPlan::parse("fail@100:w1,rejoin+5").unwrap();
        assert!(late.validate_horizon(50).is_ok());
        // ...and permanent fails have no rejoin to strand
        assert!(FaultPlan::parse("kill@10:w1")
            .unwrap()
            .validate_horizon(11)
            .is_ok());
        assert!(FaultPlan::default().validate_horizon(0).is_ok());
    }

    #[test]
    fn validate_for_rejects_out_of_range_workers() {
        let p = FaultPlan::parse("fail@1:w7").unwrap();
        assert!(p.validate_for(8).is_ok());
        let err = p.validate_for(4).unwrap_err();
        assert!(format!("{err}").contains("out of range"));
        assert!(FaultPlan::default().validate_for(0).is_ok());
    }

    #[test]
    fn seeded_plans_are_deterministic_and_valid() {
        let a = FaultPlan::seeded(42, 16, 200);
        let b = FaultPlan::seeded(42, 16, 200);
        assert_eq!(a, b);
        assert!(a.validate().is_ok());
        assert!(!a.is_empty(), "16 workers should draw some events");
        assert_ne!(a, FaultPlan::seeded(43, 16, 200));
        // spec round-trips like any scripted plan
        assert_eq!(FaultPlan::parse(&a.spec()).unwrap(), a);
        assert!(a.max_worker().unwrap() < 16);
    }
}
