//! A tiny discrete-event engine (virtual clock + binary-heap queue).
//!
//! Used by the ring-AllReduce timing model in `sim::comm` and available
//! to any future protocol-level simulation. Events carry an opaque `u64`
//! tag; handlers are dispatched by the driver loop.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled event: fires at `time`, carries a tag.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub time: f64,
    /// Monotonic sequence number — makes ordering deterministic for ties.
    pub seq: u64,
    pub tag: u64,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by (time, seq): invert for BinaryHeap's max-heap.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Virtual-time event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `tag` to fire `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: f64, tag: u64) {
        // negated comparison so a NaN delay doesn't trip the assert —
        // NaN events are tolerated (they order as ties, see `Ord`).
        debug_assert!(!(delay < 0.0), "negative delay");
        self.schedule_at(self.now + delay, tag);
    }

    /// Schedule `tag` at absolute virtual time `time` (>= now).
    pub fn schedule_at(&mut self, time: f64, tag: u64) {
        debug_assert!(!(time < self.now), "scheduling into the past");
        self.heap.push(Event { time, seq: self.seq, tag });
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?;
        self.now = ev.time;
        self.processed += 1;
        Some(ev)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Drain all events through `handler` until the queue is empty.
    /// The handler may schedule more events.
    pub fn run(&mut self, mut handler: impl FnMut(&mut EventQueue, Event)) {
        while let Some(ev) = self.pop() {
            // Hand the queue back to the handler via a scratch swap.
            let mut scratch = std::mem::take(self);
            handler(&mut scratch, ev);
            *self = scratch;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, 3);
        q.schedule_at(1.0, 1);
        q.schedule_at(2.0, 2);
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.tag)).collect();
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_fifo_by_seq() {
        let mut q = EventQueue::new();
        for tag in 0..10 {
            q.schedule_at(1.0, tag);
        }
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop().map(|e| e.tag)).collect();
        assert_eq!(tags, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn relative_scheduling_advances() {
        let mut q = EventQueue::new();
        q.schedule_in(1.5, 0);
        q.pop().unwrap();
        q.schedule_in(0.5, 1);
        let e = q.pop().unwrap();
        assert_eq!(e.time, 2.0);
    }

    #[test]
    fn min_heap_order_under_interleaved_push_pop() {
        // heap property must survive pushes between pops
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 5);
        q.schedule_at(1.0, 1);
        assert_eq!(q.pop().unwrap().tag, 1);
        q.schedule_at(3.0, 3);
        q.schedule_at(4.0, 4);
        assert_eq!(q.pop().unwrap().tag, 3);
        q.schedule_at(4.5, 45);
        let tags: Vec<u64> =
            std::iter::from_fn(|| q.pop().map(|e| e.tag)).collect();
        assert_eq!(tags, vec![4, 45, 5]);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn nan_times_do_not_panic_or_lose_events() {
        // A NaN-timed event must neither panic the comparator (the Ord
        // impl treats incomparable times as ties) nor drop events.
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, 100);
        q.schedule_at(1.0, 1);
        q.schedule_at(f64::NAN, 101);
        q.schedule_at(2.0, 2);
        let mut popped = Vec::new();
        while let Some(ev) = q.pop() {
            popped.push(ev.tag);
        }
        assert_eq!(popped.len(), 4, "all events must surface: {popped:?}");
        assert_eq!(q.processed(), 4);
        for tag in [1, 2, 100, 101] {
            assert!(popped.contains(&tag), "lost event {tag}");
        }
    }

    #[test]
    fn nan_now_does_not_block_future_scheduling() {
        // after popping a NaN event, `now` is NaN; scheduling must still
        // work (the past-check uses a negated comparison).
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, 0);
        q.pop().unwrap();
        q.schedule_at(1.0, 1);
        assert_eq!(q.pop().unwrap().tag, 1);
    }

    #[test]
    fn run_with_cascading_events() {
        let mut q = EventQueue::new();
        q.schedule_at(0.0, 0);
        let mut fired = Vec::new();
        q.run(|q, ev| {
            fired.push(ev.tag);
            if ev.tag < 5 {
                q.schedule_in(1.0, ev.tag + 1);
            }
        });
        assert_eq!(fired, vec![0, 1, 2, 3, 4, 5]);
    }
}
