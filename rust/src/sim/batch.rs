//! Multi-replica batched stepping — a structure-of-arrays lockstep
//! pass over S independent replicas.
//!
//! A sweep's seed axis steps S simulators that share everything but
//! their RNG streams: same topology, same compiled schedule, same
//! policy. Stepped one at a time, each replica re-streams the whole
//! flat `(srcs, dsts, hops)` transfer array per step — for a 128-worker
//! ring that is ~half a megabyte of schedule traffic per replica-step,
//! and the compiled phase pass is the dominant per-step cost
//! (`BENCH_perf.json: sim_step_rate_*`). [`ReplicaBatch`] steps the
//! replicas in lockstep instead: one walk over the schedule updates S
//! readiness lanes laid out worker-major (`ready[w * S + lane]`), so
//! the per-edge inner loop is a chunked 4-wide unroll across lanes and
//! the schedule stream is amortized S ways.
//!
//! **Bitwise contract.** Batched stepping is bitwise identical to
//! stepping each replica alone, for every topology, policy, width and
//! fault plan — property-tested in `tests/batch_equivalence.rs`:
//!
//! * *RNG*: each replica keeps its own [`ClusterSim`] and therefore its
//!   own per-worker SplitMix64-derived streams; the compute side of a
//!   batched step is the scalar compute side run replica-by-replica
//!   ([`ClusterSim::begin_step_observed`]), so every draw — including
//!   the bounded fill's early stop — lands in the same stream position
//!   as in a solo run.
//! * *Timing*: each lane of the SoA pass performs the scalar
//!   [`super::compiled::CompiledSchedule`] pass's per-edge operations
//!   in the same order (`mul_add`-free, `>`-guarded max), and the final
//!   per-lane reduction ([`scan_max4`]) is order-fixed, so the result
//!   bits equal the scalar fold's.
//! * *Drops*: tau decisions happen on the compute side (per replica,
//!   scalar); any step whose collective would leave the compiled
//!   full-membership fast path — a missed step deadline, per-phase
//!   checkpoints, a fault-plan kill, the event-queue reference behind
//!   [`ClusterSim::with_reference_timing`], the fixed-`T^c` model —
//!   falls back to the scalar finish for that replica
//!   ([`ClusterSim::batch_lockstep_eligible`]), with survivor-restart
//!   schedules memoized in one batch-shared
//!   [`SurvivorScheduleCache`]. The scalar path *is* the oracle; the
//!   fallback is bitwise by construction.
//!
//! Live observers ([`SimObserver`]) consume per-phase readiness slices
//! that the lane-parallel pass does not materialize, so
//! [`ReplicaBatch::step_installed_observed`] routes observed replicas
//! through the scalar pass — every hook fires exactly as in a solo run,
//! which is what keeps sweep obs output independent of `--batch`.

use crate::config::ClusterConfig;
use crate::obs::{NoopObserver, SimObserver};
use crate::policy::DropPolicy;

use super::cluster::{ClusterSim, StepOutcome};
use super::survivor::SurvivorScheduleCache;

/// S replicas (same cluster shape and policy, independent seeds)
/// stepped in lockstep through one structure-of-arrays phase pass.
#[derive(Debug)]
pub struct ReplicaBatch {
    sims: Vec<ClusterSim>,
    /// One survivor cache shared by every replica's fallback drop
    /// branch (swapped in around scalar finishes; memoization never
    /// changes results, so sharing is bitwise-safe).
    cache: SurvivorScheduleCache,
    /// Replica indices eligible for this step's lockstep pass.
    lanes: Vec<usize>,
    /// The step index each eligible lane was begun at (parallel to
    /// `lanes`).
    lane_steps: Vec<usize>,
    /// Lane-major readiness: worker `w` of lane `l` at `w * lanes + l`.
    ready: Vec<f64>,
    next: Vec<f64>,
    /// One lane's column, gathered for the final per-lane reduction.
    lane_buf: Vec<f64>,
}

impl ReplicaBatch {
    /// One replica per seed, each built exactly like a solo
    /// [`ClusterSim::new`] + [`ClusterSim::with_policy`] run.
    pub fn new(
        cfg: &ClusterConfig,
        policy: &DropPolicy,
        seeds: &[u64],
    ) -> Self {
        let sims = seeds
            .iter()
            .map(|&s| ClusterSim::new(cfg, s).with_policy(policy.clone()))
            .collect();
        Self::from_sims(sims)
    }

    /// Batch caller-built sims (e.g. with fault plans or replay sources
    /// attached). The replicas must share a worker count and comm model
    /// — that is what makes one compiled schedule (and one survivor
    /// cache) serve every lane.
    pub fn from_sims(sims: Vec<ClusterSim>) -> Self {
        assert!(!sims.is_empty(), "a batch needs at least one replica");
        let cache = SurvivorScheduleCache::new(sims[0].comm_model());
        let n = sims[0].worker_count();
        for sim in &sims {
            assert_eq!(
                sim.worker_count(),
                n,
                "batched replicas must share a worker count"
            );
            assert!(
                cache.matches(sim.comm_model()),
                "batched replicas must share a comm model"
            );
        }
        let s = sims.len();
        Self {
            cache,
            lanes: Vec::with_capacity(s),
            lane_steps: Vec::with_capacity(s),
            ready: Vec::with_capacity(n * s),
            next: Vec::with_capacity(n * s),
            lane_buf: Vec::with_capacity(n),
            sims,
        }
    }

    pub fn replicas(&self) -> usize {
        self.sims.len()
    }

    pub fn sims(&self) -> &[ClusterSim] {
        &self.sims
    }

    /// Dissolve the batch back into its replicas (their RNG streams and
    /// step counters are exactly where solo stepping would have left
    /// them).
    pub fn into_sims(self) -> Vec<ClusterSim> {
        self.sims
    }

    /// Adopt a warm shared survivor cache (e.g. from a sweep's
    /// [`crate::sweep::SurvivorCachePool`]); a cache built for a
    /// different comm model is discarded, like
    /// [`ClusterSim::with_survivor_cache`].
    pub fn with_survivor_cache(mut self, cache: SurvivorScheduleCache) -> Self {
        if cache.matches(self.sims[0].comm_model()) {
            self.cache = cache;
        }
        self
    }

    /// Hand the shared survivor cache back (for pooling), leaving a
    /// fresh empty one behind.
    pub fn take_survivor_cache(&mut self) -> SurvivorScheduleCache {
        std::mem::replace(
            &mut self.cache,
            SurvivorScheduleCache::new(self.sims[0].comm_model()),
        )
    }

    /// Step every replica once under its installed policy (allocating
    /// convenience; prefer [`Self::step_installed_into`] in loops).
    pub fn step_installed(&mut self) -> Vec<StepOutcome> {
        let mut outs = vec![StepOutcome::default(); self.sims.len()];
        self.step_installed_into(&mut outs);
        outs
    }

    /// Step every replica once under its installed policy, in lockstep:
    /// per replica the scalar compute side (RNG fills + tau scan), then
    /// one SoA phase pass timing every eligible replica's collective,
    /// with ineligible replicas finished by the scalar oracle. `outs`
    /// holds one [`StepOutcome`] per replica; in steady state the whole
    /// batched step is allocation-free.
    pub fn step_installed_into(&mut self, outs: &mut [StepOutcome]) {
        assert_eq!(
            outs.len(),
            self.sims.len(),
            "one StepOutcome per replica"
        );
        self.lanes.clear();
        self.lane_steps.clear();
        for r in 0..self.sims.len() {
            // Local-SGD periods interleave compute and sync h times;
            // the whole period takes the scalar path
            if self.sims[r].installed_local_sgd().is_some() {
                self.sims[r].swap_survivor_cache(&mut self.cache);
                self.sims[r].step_installed_into(&mut outs[r]);
                self.sims[r].swap_survivor_cache(&mut self.cache);
                continue;
            }
            let tau = self.sims[r].installed_tau();
            let step_idx = self.sims[r].begin_step_observed(
                tau,
                &mut outs[r],
                &mut NoopObserver,
            );
            if self.sims[r]
                .batch_lockstep_eligible(step_idx, &outs[r].worker_compute)
            {
                self.lanes.push(r);
                self.lane_steps.push(step_idx);
            } else {
                self.sims[r].swap_survivor_cache(&mut self.cache);
                self.sims[r].finish_step_observed(
                    step_idx,
                    &mut outs[r],
                    &mut NoopObserver,
                );
                self.sims[r].swap_survivor_cache(&mut self.cache);
            }
        }
        if self.lanes.is_empty() {
            return;
        }
        self.lockstep_pass(outs);
    }

    /// Step every replica once with per-replica observers. Observers
    /// consume per-phase readiness slices the SoA pass does not build,
    /// so this routes through the scalar pass replica-by-replica — the
    /// oracle path, bitwise identical to solo observed runs by
    /// construction (and the reason sweep obs output cannot depend on
    /// the batch width).
    pub fn step_installed_observed<O: SimObserver>(
        &mut self,
        outs: &mut [StepOutcome],
        obs: &mut [O],
    ) {
        assert_eq!(
            outs.len(),
            self.sims.len(),
            "one StepOutcome per replica"
        );
        assert_eq!(obs.len(), self.sims.len(), "one observer per replica");
        for r in 0..self.sims.len() {
            self.sims[r].swap_survivor_cache(&mut self.cache);
            self.sims[r].step_installed_observed(&mut outs[r], &mut obs[r]);
            self.sims[r].swap_survivor_cache(&mut self.cache);
        }
    }

    /// The lockstep collective: one walk over the compiled schedule
    /// updating `lanes.len()` readiness lanes per edge. Per lane the
    /// op sequence is exactly the scalar
    /// [`super::compiled::CompiledSchedule::completion_with_phases`]
    /// pass — same clamp, same hop expression, same `>`-guarded max in
    /// the same order — so each lane's bits equal a solo run's.
    fn lockstep_pass(&mut self, outs: &mut [StepOutcome]) {
        if self.sims[self.lanes[0]].batch_schedule().is_none() {
            // unreachable per batch_lockstep_eligible; degrade to the
            // scalar oracle rather than trusting the invariant
            for i in 0..self.lanes.len() {
                let r = self.lanes[i];
                let step_idx = self.lane_steps[i];
                self.sims[r].swap_survivor_cache(&mut self.cache);
                self.sims[r].finish_step_observed(
                    step_idx,
                    &mut outs[r],
                    &mut NoopObserver,
                );
                self.sims[r].swap_survivor_cache(&mut self.cache);
            }
            return;
        }
        let Some(c) = self.sims[self.lanes[0]].batch_schedule() else {
            return;
        };
        let n = c.workers();
        let e = self.lanes.len();
        let total = n * e;
        let ready = &mut self.ready;
        let next = &mut self.next;
        ready.resize(total, 0.0);
        next.resize(total, 0.0);
        // lane-major init, clamped exactly like the scalar pass (NaN
        // arrivals land at 0.0 under f64::max, both here and there)
        for (l, &r) in self.lanes.iter().enumerate() {
            let arrivals = &outs[r].worker_compute;
            for (w, &a) in arrivals.iter().enumerate() {
                ready[w * e + l] = a.max(0.0);
            }
        }
        let (srcs, dsts, hops) = c.edges();
        for p in 0..c.phase_count() {
            next[..total].copy_from_slice(&ready[..total]);
            let (lo, hi) = c.phase_bounds(p);
            for k in lo..hi {
                let src = srcs[k] as usize * e;
                let dst = dsts[k] as usize * e;
                let hop = hops[k];
                // chunked 4-wide unroll across replica lanes; no
                // mul_add, no reassociation — each lane runs the
                // scalar pass's two guarded maxes
                let mut l = 0;
                while l + 4 <= e {
                    let d0 = ready[src + l] + hop;
                    let d1 = ready[src + l + 1] + hop;
                    let d2 = ready[src + l + 2] + hop;
                    let d3 = ready[src + l + 3] + hop;
                    if d0 > next[dst + l] {
                        next[dst + l] = d0;
                    }
                    if d1 > next[dst + l + 1] {
                        next[dst + l + 1] = d1;
                    }
                    if d2 > next[dst + l + 2] {
                        next[dst + l + 2] = d2;
                    }
                    if d3 > next[dst + l + 3] {
                        next[dst + l + 3] = d3;
                    }
                    if d0 > next[src + l] {
                        next[src + l] = d0;
                    }
                    if d1 > next[src + l + 1] {
                        next[src + l + 1] = d1;
                    }
                    if d2 > next[src + l + 2] {
                        next[src + l + 2] = d2;
                    }
                    if d3 > next[src + l + 3] {
                        next[src + l + 3] = d3;
                    }
                    l += 4;
                }
                while l < e {
                    let done = ready[src + l] + hop;
                    if done > next[dst + l] {
                        next[dst + l] = done;
                    }
                    if done > next[src + l] {
                        next[src + l] = done;
                    }
                    l += 1;
                }
            }
            std::mem::swap(ready, next);
        }
        // per-lane completion: gather the lane's column and reduce
        // with the order-fixed 4-wide scan; compute_time replicates
        // finish_into's empty-guarded sequential fold verbatim
        self.lane_buf.resize(n, 0.0);
        for (l, &r) in self.lanes.iter().enumerate() {
            for w in 0..n {
                self.lane_buf[w] = ready[w * e + l];
            }
            let out = &mut outs[r];
            out.compute_time = if out.worker_compute.is_empty() {
                0.0
            } else {
                out.worker_compute
                    .iter()
                    .cloned()
                    .fold(f64::NEG_INFINITY, f64::max)
            };
            out.iter_time = scan_max4(&self.lane_buf);
        }
        for i in 0..self.lanes.len() {
            let r = self.lanes[i];
            self.sims[r].seal_batched_step(&mut outs[r], &mut NoopObserver);
        }
    }
}

/// Order-fixed chunked 4-wide max reduction, bitwise equal to
/// `xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)`.
///
/// Why reassociating is safe here, bit for bit: `f64::max` ignores NaN
/// (a NaN operand yields the other operand), every accumulator is
/// seeded with `NEG_INFINITY`, and for any non-NaN value set the
/// reduction returns the set's maximum element — the same bits
/// whichever association computed it. The lone formal exception is a
/// maximum attained by both `+0.0` and `-0.0` (IEEE leaves the sign
/// unspecified); the batched pass never feeds that case — readiness
/// values are clamped non-negative at phase entry and `-0.0` cannot
/// reach them. Empty input folds to `NEG_INFINITY`, like the scalar
/// fold (callers with empty-set semantics guard first, as
/// `finish_into` does).
pub fn scan_max4(xs: &[f64]) -> f64 {
    let chunks = xs.len() / 4;
    let mut m0 = f64::NEG_INFINITY;
    let mut m1 = f64::NEG_INFINITY;
    let mut m2 = f64::NEG_INFINITY;
    let mut m3 = f64::NEG_INFINITY;
    for i in 0..chunks {
        let b = i * 4;
        m0 = m0.max(xs[b]);
        m1 = m1.max(xs[b + 1]);
        m2 = m2.max(xs[b + 2]);
        m3 = m3.max(xs[b + 3]);
    }
    let mut m = m0.max(m1).max(m2.max(m3));
    let mut i = chunks * 4;
    while i < xs.len() {
        m = m.max(xs[i]);
        i += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NoiseKind, StragglerKind};
    use crate::topology::TopologyKind;

    fn config(workers: usize) -> ClusterConfig {
        ClusterConfig {
            workers,
            accumulations: 6,
            microbatch_mean: 0.4,
            microbatch_std: 0.05,
            noise: NoiseKind::Exponential { mean: 0.3 },
            stragglers: StragglerKind::Uniform { p: 0.25, delay: 2.0 },
            topology: Some(TopologyKind::Ring),
            ..Default::default()
        }
    }

    fn assert_outcomes_eq(a: &StepOutcome, b: &StepOutcome, what: &str) {
        assert_eq!(
            a.iter_time.to_bits(),
            b.iter_time.to_bits(),
            "{what}: iter_time"
        );
        assert_eq!(
            a.compute_time.to_bits(),
            b.compute_time.to_bits(),
            "{what}: compute_time"
        );
        assert_eq!(a.completed, b.completed, "{what}: completed");
        for (x, y) in a.worker_compute.iter().zip(&b.worker_compute) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: worker_compute");
        }
    }

    #[test]
    fn batched_steps_match_solo_runs_bitwise() {
        let cfg = config(9);
        let policy = DropPolicy::None;
        let seeds = [3u64, 17, 92, 5];
        let mut batch = ReplicaBatch::new(&cfg, &policy, &seeds);
        let mut solos: Vec<ClusterSim> = seeds
            .iter()
            .map(|&s| ClusterSim::new(&cfg, s).with_policy(policy.clone()))
            .collect();
        let mut outs = batch.step_installed();
        let mut want = StepOutcome::default();
        for _ in 0..12 {
            batch.step_installed_into(&mut outs);
            for (r, solo) in solos.iter_mut().enumerate() {
                solo.step_installed_into(&mut want);
                assert_outcomes_eq(&outs[r], &want, "replica");
            }
        }
    }

    #[test]
    fn drop_deadline_fallback_lanes_stay_bitwise() {
        // a tight step deadline forces the drop path (scalar fallback)
        // on many steps while others ride the lockstep pass
        let mut cfg = config(8);
        cfg.stragglers = StragglerKind::Uniform { p: 0.5, delay: 6.0 };
        let policy = DropPolicy::comm_deadline(0.5);
        let seeds = [1u64, 2, 3, 4, 5];
        let mut batch = ReplicaBatch::new(&cfg, &policy, &seeds);
        let mut solos: Vec<ClusterSim> = seeds
            .iter()
            .map(|&s| ClusterSim::new(&cfg, s).with_policy(policy.clone()))
            .collect();
        let mut outs = batch.step_installed();
        let mut want = StepOutcome::default();
        let mut dropped_steps = 0;
        for _ in 0..20 {
            batch.step_installed_into(&mut outs);
            for (r, solo) in solos.iter_mut().enumerate() {
                solo.step_installed_into(&mut want);
                assert_outcomes_eq(&outs[r], &want, "replica");
                if want.total_completed()
                    < cfg.workers * cfg.accumulations
                {
                    dropped_steps += 1;
                }
            }
        }
        assert!(dropped_steps > 0, "deadline must actually drop someone");
    }

    #[test]
    fn scan_max4_matches_sequential_fold() {
        let cases: Vec<Vec<f64>> = vec![
            vec![],
            vec![1.5],
            vec![3.0, 1.0, 2.0],
            vec![0.0, f64::INFINITY, 2.0, 9.0, 4.4],
            vec![f64::NAN, 1.0, f64::NAN, 5.0, f64::NAN],
            vec![f64::NAN; 7],
            (0..23).map(|i| (i * 37 % 11) as f64 * 0.125).collect(),
        ];
        for xs in &cases {
            let want =
                xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let got = scan_max4(xs);
            assert_eq!(got.to_bits(), want.to_bits(), "{xs:?}");
        }
    }

    #[test]
    fn shared_cache_round_trips_through_the_pool_seam() {
        let mut cfg = config(6);
        cfg.comm_drop_deadline = 0.0;
        let policy = DropPolicy::comm_deadline(0.4);
        let mut batch =
            ReplicaBatch::new(&cfg, &policy, &[7, 8]).with_survivor_cache(
                SurvivorScheduleCache::new(
                    ClusterSim::new(&cfg, 7).comm_model(),
                ),
            );
        let mut outs = batch.step_installed();
        for _ in 0..10 {
            batch.step_installed_into(&mut outs);
        }
        let cache = batch.take_survivor_cache();
        assert!(
            cache.compiled_count() > 0,
            "drop-heavy batch must warm the shared cache"
        );
    }
}
