//! Discrete-event / virtual-clock cluster simulation.
//!
//! * [`noise`] — per-micro-batch latency models (App. B.1 noise, Fig 13/14
//!   families, Fig 12 straggler scenarios, Fig 6 heterogeneity);
//! * [`event`] — virtual-clock event queue;
//! * [`comm`] — AllReduce timing models: fixed `T^c`, plus any
//!   [`crate::topology::Schedule`] (ring / tree / hierarchical / torus)
//!   timed event-driven with per-worker arrivals, and the bounded-wait
//!   DropComm membership rule (step-level and per-phase — see
//!   [`crate::policy::DropPolicy`]);
//! * [`compiled`] — the heapless compiled fast path for schedule
//!   timing ([`CompiledSchedule`]), bitwise equal to the event-queue
//!   reference but allocation-free in steady state;
//! * [`survivor`] — per-survivor-count compiled schedules for the
//!   DropComm exclusion branch ([`SurvivorScheduleCache`]), making
//!   drop-heavy stepping as cheap as the no-drop path;
//! * [`fault`] — the scenario lab's deterministic fault injection
//!   ([`FaultPlan`]): scripted fail/rejoin/slow/drift events that vary
//!   live membership and per-worker latency scale between steps;
//! * [`cluster`] — synchronous / DropCompute / DropComm / Local-SGD
//!   step timing, driven by the unified [`crate::policy::DropPolicy`]
//!   surface ([`ClusterSim::step_with`]);
//! * [`trace`] — `t_{i,n}^{(m)}` recording for Algorithm 2 and
//!   post-analysis, plus the versioned replayable [`TraceRecord`]
//!   format: any live run records its per-worker draws and outcomes
//!   ([`ClusterSim::start_recording`]), and replaying the record
//!   ([`ClusterSim::from_trace`]) reproduces those outcomes bitwise on
//!   both timing paths — the conformance harness and the input of
//!   [`crate::analysis::budget_fit`].

pub mod batch;
pub mod cluster;
pub mod comm;
pub mod compiled;
pub mod event;
pub mod fault;
pub mod noise;
pub mod survivor;
pub mod trace;

pub use batch::{scan_max4, ReplicaBatch};
pub use cluster::{ClusterSim, PreemptionMode, StepOutcome};
pub use fault::{FaultEvent, FaultPlan};
pub use comm::{
    bounded_wait_cutoff, bounded_wait_survivors, schedule_completion, CommModel,
};
pub use compiled::{CompiledSchedule, PhaseBounded, ScheduleScratch};
pub use event::EventQueue;
pub use noise::{build_noise, LatencyModel, NoiseSampler};
pub use survivor::SurvivorScheduleCache;
pub use trace::{
    StepTrace, Trace, TraceComm, TraceMeta, TraceMode, TraceOutcome,
    TraceRecord, TraceTransport, TraceWriter, TRACE_FORMAT_VERSION,
};
