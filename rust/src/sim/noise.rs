//! Per-micro-batch latency models: the cluster's "compute variance".
//!
//! `t_n^{(m)} = base ⊕ additive-noise ⊕ straggler-delay` — exactly the
//! paper's simulated-delay environment (App. B.1) plus the straggler
//! scenarios of Fig 12 and the sub-optimal heterogeneous system of Fig 6.

use crate::config::{ClusterConfig, NoiseKind, StragglerKind};
use crate::rng::{
    Bernoulli, BoundedLogNormal, Distribution, Exponential, Gamma, LogNormal,
    Normal, Xoshiro256pp,
};

/// Build the additive-noise sampler for a config (None = no noise).
/// For `PaperLogNormal` the sample is *relative*: `t += mu_compute * eps`.
pub fn build_noise(kind: &NoiseKind) -> Option<Box<dyn Distribution>> {
    match kind {
        NoiseKind::None => None,
        NoiseKind::PaperLogNormal { mu, sigma, alpha, beta } => {
            Some(Box::new(BoundedLogNormal::new(*mu, *sigma, *alpha, *beta)))
        }
        NoiseKind::LogNormal { mean, var } => {
            Some(Box::new(LogNormal::from_moments(*mean, *var)))
        }
        NoiseKind::Normal { mean, var } => {
            Some(Box::new(Normal::from_moments(*mean, *var)))
        }
        NoiseKind::Bernoulli { p, value } => {
            Some(Box::new(Bernoulli::new(*p, *value)))
        }
        NoiseKind::Exponential { mean } => {
            Some(Box::new(Exponential::from_mean(*mean)))
        }
        NoiseKind::Gamma { mean, var } => {
            Some(Box::new(Gamma::from_moments(*mean, *var)))
        }
    }
}

/// Whether the noise sample multiplies the base mean (paper's form) or is
/// an absolute additive number of seconds (Fig 13/14 form).
fn noise_is_relative(kind: &NoiseKind) -> bool {
    matches!(kind, NoiseKind::PaperLogNormal { .. })
}

/// Per-worker latency sampler with optional heterogeneity.
pub struct LatencyModel {
    base: Normal,
    noise: Option<Box<dyn Distribution>>,
    relative: bool,
    mean_scale: f64,
    stragglers: StragglerKind,
    /// Per-worker speed multipliers (1.0 = nominal). Length >= workers.
    worker_scale: Vec<f64>,
}

impl std::fmt::Debug for LatencyModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyModel")
            .field("base", &self.base)
            .field("relative", &self.relative)
            .finish()
    }
}

impl LatencyModel {
    pub fn from_config(c: &ClusterConfig) -> Self {
        Self {
            base: Normal::new(c.microbatch_mean, c.microbatch_std),
            noise: build_noise(&c.noise),
            relative: noise_is_relative(&c.noise),
            mean_scale: c.microbatch_mean,
            stragglers: c.stragglers.clone(),
            worker_scale: vec![1.0; c.workers],
        }
    }

    /// Inject per-worker heterogeneity (Fig 6's sub-optimal system):
    /// worker n's base latency is multiplied by `scales[n]`.
    pub fn with_worker_scales(mut self, scales: Vec<f64>) -> Self {
        self.worker_scale = scales;
        self
    }

    /// Sample the compute latency of one micro-batch for worker `n`.
    pub fn sample_microbatch(&self, n: usize, rng: &mut Xoshiro256pp) -> f64 {
        let scale = self.worker_scale.get(n).copied().unwrap_or(1.0);
        // Base compute: truncated-at-10%-of-mean normal (hardware cannot
        // be arbitrarily fast).
        let mut t = self.base.sample(rng).max(0.1 * self.base.mu) * scale;
        if let Some(noise) = &self.noise {
            // Noise may be signed (the Fig 13 Normal family allows a
            // worker to run *faster* than nominal); only the total
            // latency is clamped to a physical floor.
            let eps = noise.sample(rng);
            t += if self.relative { self.mean_scale * eps } else { eps };
        }
        t.max(0.01 * self.base.mu)
    }

    /// Effectively-infinite delay of a failed worker (finite so the
    /// max/CDF arithmetic stays well-defined).
    pub const FATAL_DELAY: f64 = 1e9;

    /// Per-step straggler delay for worker `n` (0 if not straggling).
    pub fn sample_straggler(&self, n: usize, rng: &mut Xoshiro256pp) -> f64 {
        self.sample_straggler_at(n, usize::MAX, rng)
    }

    /// Step-aware variant (needed by `Fatal`, which triggers at a step).
    pub fn sample_straggler_at(
        &self,
        n: usize,
        step: usize,
        rng: &mut Xoshiro256pp,
    ) -> f64 {
        match &self.stragglers {
            StragglerKind::None => 0.0,
            StragglerKind::Uniform { p, delay } => {
                if rng.next_f64() < *p {
                    *delay
                } else {
                    0.0
                }
            }
            StragglerKind::SingleServer { p, delay, server_size } => {
                if n < *server_size && rng.next_f64() < *p {
                    *delay
                } else {
                    0.0
                }
            }
            StragglerKind::Fatal { worker, from_step } => {
                if n == *worker && step >= *from_step {
                    Self::FATAL_DELAY
                } else {
                    0.0
                }
            }
        }
    }

    /// Analytical mean of one micro-batch latency (no stragglers).
    pub fn mean(&self) -> f64 {
        let noise_mean = self
            .noise
            .as_ref()
            .map(|d| if self.relative { self.mean_scale * d.mean() } else { d.mean() })
            .unwrap_or(0.0);
        self.base.mean() + noise_mean
    }

    /// Analytical variance of one micro-batch latency (no stragglers).
    pub fn variance(&self) -> f64 {
        let noise_var = self
            .noise
            .as_ref()
            .map(|d| {
                if self.relative {
                    self.mean_scale * self.mean_scale * d.variance()
                } else {
                    d.variance()
                }
            })
            .unwrap_or(0.0);
        self.base.variance() + noise_var
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn base_config() -> ClusterConfig {
        ClusterConfig {
            workers: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.01,
            ..Default::default()
        }
    }

    #[test]
    fn no_noise_matches_base_moments() {
        let m = LatencyModel::from_config(&base_config());
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| m.sample_microbatch(0, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 0.45).abs() < 1e-3, "{mean}");
        assert!((m.mean() - 0.45).abs() < 1e-12);
    }

    #[test]
    fn paper_noise_x15_slowdown() {
        // App. B.1: with the paper constants each accumulation takes
        // ~1.5x longer on average.
        let mut c = base_config();
        c.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        let m = LatencyModel::from_config(&c);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|_| m.sample_microbatch(0, &mut rng)).sum::<f64>() / n as f64;
        let ratio = mean / 0.45;
        assert!((1.35..1.65).contains(&ratio), "ratio {ratio}");
        // analytic model agrees with sampling
        assert!((m.mean() - mean).abs() < 5e-3, "{} vs {mean}", m.mean());
    }

    #[test]
    fn absolute_noise_families() {
        for kind in [
            NoiseKind::LogNormal { mean: 0.225, var: 0.05 },
            NoiseKind::Normal { mean: 0.225, var: 0.05 },
            NoiseKind::Exponential { mean: 0.225 },
            NoiseKind::Gamma { mean: 0.225, var: 0.05 },
            NoiseKind::Bernoulli { p: 0.5, value: 0.45 },
        ] {
            let mut c = base_config();
            c.noise = kind.clone();
            let m = LatencyModel::from_config(&c);
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            let n = 150_000;
            let mean: f64 = (0..n)
                .map(|_| m.sample_microbatch(0, &mut rng))
                .sum::<f64>()
                / n as f64;
            assert!(
                (mean - m.mean()).abs() < 8e-3,
                "{kind:?}: sampled {mean} vs analytic {}",
                m.mean()
            );
        }
    }

    #[test]
    fn straggler_scenarios() {
        let mut c = base_config();
        c.stragglers = StragglerKind::SingleServer {
            p: 1.0,
            delay: 2.0,
            server_size: 2,
        };
        let m = LatencyModel::from_config(&c);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        assert_eq!(m.sample_straggler(0, &mut rng), 2.0);
        assert_eq!(m.sample_straggler(1, &mut rng), 2.0);
        assert_eq!(m.sample_straggler(2, &mut rng), 0.0);
        assert_eq!(m.sample_straggler(3, &mut rng), 0.0);
    }

    #[test]
    fn worker_scales_heterogeneity() {
        let m = LatencyModel::from_config(&base_config())
            .with_worker_scales(vec![1.0, 2.0, 1.0, 1.0]);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let n = 50_000;
        let mean = |w: usize, rng: &mut Xoshiro256pp| -> f64 {
            (0..n).map(|_| m.sample_microbatch(w, rng)).sum::<f64>() / n as f64
        };
        let m0 = mean(0, &mut rng);
        let m1 = mean(1, &mut rng);
        assert!((m1 / m0 - 2.0).abs() < 0.05, "{m0} {m1}");
    }
}
