//! Per-micro-batch latency models: the cluster's "compute variance".
//!
//! `t_n^{(m)} = base ⊕ additive-noise ⊕ straggler-delay` — exactly the
//! paper's simulated-delay environment (App. B.1) plus the straggler
//! scenarios of Fig 12 and the sub-optimal heterogeneous system of Fig 6.
//!
//! The noise families live behind [`NoiseSampler`], a *closed* enum over
//! the six [`NoiseKind`] distributions. The old `Box<dyn Distribution>`
//! paid an indirect call per draw in the innermost simulation loop; the
//! enum dispatches once per accumulation run ([`LatencyModel`]'s batched
//! `fill_*` methods hoist the match out of the loop entirely) and every
//! inner `sample` inlines. The boxed builder ([`build_noise`]) survives
//! as the reference arm of the `noise_fill_rate` benchmark and of the
//! draw-for-draw property test in `tests/perf_equivalence.rs`.

use crate::config::{ClusterConfig, NoiseKind, StragglerKind};
use crate::rng::{
    Bernoulli, BoundedLogNormal, Distribution, Exponential, Gamma, LogNormal,
    Normal, SplitMix64, Xoshiro256pp,
};

/// Build the additive-noise sampler for a config (None = no noise).
/// For `PaperLogNormal` the sample is *relative*: `t += mu_compute * eps`.
///
/// This is the *boxed* (virtual-dispatch) form, kept as the reference
/// oracle for [`NoiseSampler`]; the simulator's hot loops use the enum.
pub fn build_noise(kind: &NoiseKind) -> Option<Box<dyn Distribution>> {
    match kind {
        NoiseKind::None => None,
        NoiseKind::PaperLogNormal { mu, sigma, alpha, beta } => {
            Some(Box::new(BoundedLogNormal::new(*mu, *sigma, *alpha, *beta)))
        }
        NoiseKind::LogNormal { mean, var } => {
            Some(Box::new(LogNormal::from_moments(*mean, *var)))
        }
        NoiseKind::Normal { mean, var } => {
            Some(Box::new(Normal::from_moments(*mean, *var)))
        }
        NoiseKind::Bernoulli { p, value } => {
            Some(Box::new(Bernoulli::new(*p, *value)))
        }
        NoiseKind::Exponential { mean } => {
            Some(Box::new(Exponential::from_mean(*mean)))
        }
        NoiseKind::Gamma { mean, var } => {
            Some(Box::new(Gamma::from_moments(*mean, *var)))
        }
        // the step-indexed scenario families draw nothing per
        // micro-batch — their whole effect is the deterministic
        // [`NoiseSampler::step_offset`]
        NoiseKind::SharedBurst { .. } | NoiseKind::Drift { .. } => None,
    }
}

/// Whether the noise sample multiplies the base mean (paper's form) or is
/// an absolute additive number of seconds (Fig 13/14 form).
fn noise_is_relative(kind: &NoiseKind) -> bool {
    matches!(kind, NoiseKind::PaperLogNormal { .. })
}

/// Closed, enum-dispatched noise sampler: one variant per
/// [`NoiseKind`] family. Draw-for-draw identical to the boxed sampler
/// [`build_noise`] returns for the same kind (property-tested), but
/// `sample` inlines and [`NoiseSampler::fill`] draws a whole buffer with
/// the variant match hoisted out of the loop.
#[derive(Debug, Clone, Copy)]
pub enum NoiseSampler {
    None,
    PaperBounded(BoundedLogNormal),
    LogNormal(LogNormal),
    Normal(Normal),
    Bernoulli(Bernoulli),
    Exponential(Exponential),
    Gamma(Gamma),
    /// Correlated shared-burst straggler process (the scenario lab):
    /// one seeded burst clock divides time into windows of `period`
    /// steps; a window bursts with probability `p`, and during a burst
    /// every worker with id `< subset` pays `delay` extra seconds at
    /// its step start. Step-indexed — the effect is surfaced through
    /// [`NoiseSampler::step_offset`], never per-draw sampling, so
    /// per-worker streams are untouched.
    SharedBurst { seed: u64, p: f64, period: u64, delay: f64, subset: usize },
    /// Per-worker mean drift (the scenario lab): each worker's step
    /// offset random-walks across steps with increments uniform in
    /// `[-sigma, sigma]`, clamped at 0 (a worker can drift back to
    /// nominal but never run ahead of it). Step-indexed like
    /// [`NoiseSampler::SharedBurst`].
    Drift { seed: u64, sigma: f64 },
}

impl NoiseSampler {
    pub fn from_kind(kind: &NoiseKind) -> Self {
        match kind {
            NoiseKind::None => NoiseSampler::None,
            NoiseKind::PaperLogNormal { mu, sigma, alpha, beta } => {
                NoiseSampler::PaperBounded(BoundedLogNormal::new(
                    *mu, *sigma, *alpha, *beta,
                ))
            }
            NoiseKind::LogNormal { mean, var } => {
                NoiseSampler::LogNormal(LogNormal::from_moments(*mean, *var))
            }
            NoiseKind::Normal { mean, var } => {
                NoiseSampler::Normal(Normal::from_moments(*mean, *var))
            }
            NoiseKind::Bernoulli { p, value } => {
                NoiseSampler::Bernoulli(Bernoulli::new(*p, *value))
            }
            NoiseKind::Exponential { mean } => {
                NoiseSampler::Exponential(Exponential::from_mean(*mean))
            }
            NoiseKind::Gamma { mean, var } => {
                NoiseSampler::Gamma(Gamma::from_moments(*mean, *var))
            }
            NoiseKind::SharedBurst { p, period, delay, subset, seed } => {
                NoiseSampler::SharedBurst {
                    seed: *seed,
                    p: *p,
                    period: *period,
                    delay: *delay,
                    subset: *subset,
                }
            }
            NoiseKind::Drift { sigma, seed } => {
                NoiseSampler::Drift { seed: *seed, sigma: *sigma }
            }
        }
    }

    /// Whether this kind contributes no *per-draw* noise. True for the
    /// step-indexed scenario families too: their whole effect is
    /// [`Self::step_offset`], so the micro-batch draw paths treat them
    /// exactly like `None`.
    #[inline]
    pub fn is_none(&self) -> bool {
        matches!(
            self,
            NoiseSampler::None
                | NoiseSampler::SharedBurst { .. }
                | NoiseSampler::Drift { .. }
        )
    }

    /// Deterministic step-indexed latency offset (0.0 for every
    /// per-draw family). A pure function of `(worker, step)`: the burst
    /// clock and the drift walks are reseeded from their own seeds on
    /// every call, consuming nothing from any worker stream, so replay,
    /// parallel sweeps and the event-queue oracle all see identical
    /// bits with no shared mutable state.
    pub fn step_offset(&self, worker: usize, step: u64) -> f64 {
        match *self {
            NoiseSampler::SharedBurst { seed, p, period, delay, subset } => {
                shared_burst_offset(seed, p, period, delay, subset, worker, step)
            }
            NoiseSampler::Drift { seed, sigma } => {
                drift_offset(seed, sigma, worker, step)
            }
            // every per-draw family: step-indexed offsets don't apply
            NoiseSampler::None
            | NoiseSampler::PaperBounded(_)
            | NoiseSampler::LogNormal(_)
            | NoiseSampler::Normal(_)
            | NoiseSampler::Bernoulli(_)
            | NoiseSampler::Exponential(_)
            | NoiseSampler::Gamma(_) => 0.0,
        }
    }

    /// Draw one sample (0.0 for `None`). Same stream position per draw
    /// as the boxed sampler for the same kind.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        match self {
            NoiseSampler::None => 0.0,
            NoiseSampler::PaperBounded(d) => d.sample(rng),
            NoiseSampler::LogNormal(d) => d.sample(rng),
            NoiseSampler::Normal(d) => d.sample(rng),
            NoiseSampler::Bernoulli(d) => d.sample(rng),
            NoiseSampler::Exponential(d) => d.sample(rng),
            NoiseSampler::Gamma(d) => d.sample(rng),
            NoiseSampler::SharedBurst { .. } | NoiseSampler::Drift { .. } => 0.0,
        }
    }

    /// Fill `buf` with `buf.len()` consecutive draws — identical stream
    /// consumption to `buf.len()` calls of [`Self::sample`], with the
    /// variant dispatch paid once instead of per draw (each arm
    /// monomorphizes [`fill_slice`] for its concrete sampler).
    pub fn fill(&self, buf: &mut [f64], rng: &mut Xoshiro256pp) {
        match self {
            NoiseSampler::None => buf.fill(0.0),
            NoiseSampler::PaperBounded(d) => fill_slice(d, buf, rng),
            NoiseSampler::LogNormal(d) => fill_slice(d, buf, rng),
            NoiseSampler::Normal(d) => fill_slice(d, buf, rng),
            NoiseSampler::Bernoulli(d) => fill_slice(d, buf, rng),
            NoiseSampler::Exponential(d) => fill_slice(d, buf, rng),
            NoiseSampler::Gamma(d) => fill_slice(d, buf, rng),
            NoiseSampler::SharedBurst { .. } | NoiseSampler::Drift { .. } => {
                buf.fill(0.0)
            }
        }
    }

    /// Analytical mean (0.0 for `None`).
    pub fn mean(&self) -> f64 {
        match self {
            NoiseSampler::None => 0.0,
            NoiseSampler::PaperBounded(d) => d.mean(),
            NoiseSampler::LogNormal(d) => d.mean(),
            NoiseSampler::Normal(d) => d.mean(),
            NoiseSampler::Bernoulli(d) => d.mean(),
            NoiseSampler::Exponential(d) => d.mean(),
            NoiseSampler::Gamma(d) => d.mean(),
            // the step-indexed offsets live outside the per-draw
            // compute model the analytic moments describe
            NoiseSampler::SharedBurst { .. } | NoiseSampler::Drift { .. } => 0.0,
        }
    }

    /// Analytical variance (0.0 for `None`).
    pub fn variance(&self) -> f64 {
        match self {
            NoiseSampler::None => 0.0,
            NoiseSampler::PaperBounded(d) => d.variance(),
            NoiseSampler::LogNormal(d) => d.variance(),
            NoiseSampler::Normal(d) => d.variance(),
            NoiseSampler::Bernoulli(d) => d.variance(),
            NoiseSampler::Exponential(d) => d.variance(),
            NoiseSampler::Gamma(d) => d.variance(),
            NoiseSampler::SharedBurst { .. } | NoiseSampler::Drift { .. } => 0.0,
        }
    }
}

/// Domain separator of the shared burst clock.
const BURST_SEED_DOMAIN: u64 = 0xB025_7C10_C45E_ED01;
/// Domain separator of the per-worker drift walks.
const DRIFT_SEED_DOMAIN: u64 = 0xD21F_70A1_C5EE_D001;

/// One uniform f64 in [0, 1) from 64 raw bits — the standard 53-bit
/// mantissa construction `Xoshiro256pp::next_f64` uses.
#[inline]
fn unit_f64(bits: u64) -> f64 {
    (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The shared-burst offset for `(worker, step)`: the burst clock hashes
/// the window index through its own [`SplitMix64`] stream, so every
/// in-subset worker sees the *same* burst decision — the correlation
/// the independent per-worker streams cannot express.
fn shared_burst_offset(
    seed: u64,
    p: f64,
    period: u64,
    delay: f64,
    subset: usize,
    worker: usize,
    step: u64,
) -> f64 {
    if worker >= subset {
        return 0.0;
    }
    let window = step / period.max(1);
    let mut clock = SplitMix64::new((seed ^ BURST_SEED_DOMAIN).wrapping_add(window));
    if unit_f64(clock.next_u64()) < p {
        delay
    } else {
        0.0
    }
}

/// The drift-walk offset for `(worker, step)`: the worker's walk is
/// replayed from its seed on every call (O(step) — scenario horizons
/// are short; purity buys bitwise replay with no cached walk state).
fn drift_offset(seed: u64, sigma: f64, worker: usize, step: u64) -> f64 {
    let mut walk =
        SplitMix64::new((seed ^ DRIFT_SEED_DOMAIN).wrapping_add(worker as u64));
    let mut x = 0.0f64;
    for _ in 0..=step {
        x = (x + sigma * (2.0 * unit_f64(walk.next_u64()) - 1.0)).max(0.0);
    }
    x
}

/// Statically-dispatched draw loop: monomorphized per sampler family,
/// so the inner `sample` inlines with no per-draw branch.
#[inline(always)]
fn fill_slice<D: Distribution>(d: &D, buf: &mut [f64], rng: &mut Xoshiro256pp) {
    for s in buf.iter_mut() {
        *s = d.sample(rng);
    }
}

/// Per-worker latency sampler with optional heterogeneity.
pub struct LatencyModel {
    base: Normal,
    noise: NoiseSampler,
    relative: bool,
    mean_scale: f64,
    stragglers: StragglerKind,
    /// Per-worker speed multipliers (1.0 = nominal). Length >= workers.
    worker_scale: Vec<f64>,
}

impl std::fmt::Debug for LatencyModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyModel")
            .field("base", &self.base)
            .field("noise", &self.noise)
            .field("relative", &self.relative)
            .finish()
    }
}

impl LatencyModel {
    pub fn from_config(c: &ClusterConfig) -> Self {
        Self {
            base: Normal::new(c.microbatch_mean, c.microbatch_std),
            noise: NoiseSampler::from_kind(&c.noise),
            relative: noise_is_relative(&c.noise),
            mean_scale: c.microbatch_mean,
            stragglers: c.stragglers.clone(),
            worker_scale: vec![1.0; c.workers],
        }
    }

    /// Inject per-worker heterogeneity (Fig 6's sub-optimal system):
    /// worker n's base latency is multiplied by `scales[n]`.
    pub fn with_worker_scales(mut self, scales: Vec<f64>) -> Self {
        self.worker_scale = scales;
        self
    }

    /// Worker `n`'s current base-latency multiplier (1.0 when unset).
    #[inline]
    pub fn worker_scale(&self, n: usize) -> f64 {
        self.worker_scale.get(n).copied().unwrap_or(1.0)
    }

    /// Set worker `n`'s base-latency multiplier in place — the fault
    /// plan's slow/drift events re-scale workers between steps through
    /// the same seam Fig 6's static heterogeneity uses.
    pub fn set_worker_scale(&mut self, n: usize, scale: f64) {
        if self.worker_scale.len() <= n {
            self.worker_scale.resize(n + 1, 1.0);
        }
        self.worker_scale[n] = scale;
    }

    /// The deterministic step-indexed latency offset of the installed
    /// noise kind ([`NoiseSampler::step_offset`]): exactly 0.0 for
    /// every classic per-draw family, so adding it to a step's straggle
    /// is a bitwise no-op outside the scenario families.
    #[inline]
    pub fn step_offset(&self, n: usize, step: u64) -> f64 {
        self.noise.step_offset(n, step)
    }

    /// Sample the compute latency of one micro-batch for worker `n`.
    #[inline]
    pub fn sample_microbatch(&self, n: usize, rng: &mut Xoshiro256pp) -> f64 {
        let scale = self.worker_scale.get(n).copied().unwrap_or(1.0);
        // Base compute: truncated-at-10%-of-mean normal (hardware cannot
        // be arbitrarily fast).
        let mut t = self.base.sample(rng).max(0.1 * self.base.mu) * scale;
        if !self.noise.is_none() {
            // Noise may be signed (the Fig 13 Normal family allows a
            // worker to run *faster* than nominal); only the total
            // latency is clamped to a physical floor.
            let eps = self.noise.sample(rng);
            t += if self.relative { self.mean_scale * eps } else { eps };
        }
        t.max(0.01 * self.base.mu)
    }

    /// The shared core of the batched fills: draw up to `m` micro-batch
    /// latencies into `buf`, base and noise interleaved per sample in
    /// exactly [`Self::sample_microbatch`]'s order. With
    /// `bound = Some((start, tau))` the run stops after the first sample
    /// whose running total `start + s_1 + ... + s_j` reaches `tau` —
    /// precisely where the sequential preemption loops stopped drawing,
    /// so the worker's stream position stays bitwise identical to the
    /// un-batched code in both preemption modes.
    #[inline(always)]
    fn fill_core(
        &self,
        n: usize,
        m: usize,
        bound: Option<(f64, f64)>,
        buf: &mut Vec<f64>,
        rng: &mut Xoshiro256pp,
        mut eps: impl FnMut(&mut Xoshiro256pp) -> f64,
        has_noise: bool,
    ) -> usize {
        buf.clear();
        buf.reserve(m);
        let scale = self.worker_scale.get(n).copied().unwrap_or(1.0);
        let base_floor = 0.1 * self.base.mu;
        let total_floor = 0.01 * self.base.mu;
        let mut cum = match bound {
            Some((start, _)) => start,
            None => 0.0,
        };
        for _ in 0..m {
            let mut t = self.base.sample(rng).max(base_floor) * scale;
            if has_noise {
                let e = eps(rng);
                t += if self.relative { self.mean_scale * e } else { e };
            }
            let t = t.max(total_floor);
            buf.push(t);
            if let Some((_, tau)) = bound {
                cum += t;
                // negated comparison: both preemption modes stop drawing
                // at the first crossing (Preemptive's `next < tau` guard
                // and BetweenAccumulations' `t >= tau` check agree here)
                if !(cum < tau) {
                    break;
                }
            }
        }
        buf.len()
    }

    /// Dispatch [`Self::fill_core`] once per run on the noise variant —
    /// the whole accumulation run is drawn with no per-sample dispatch.
    #[inline]
    fn fill_dispatch(
        &self,
        n: usize,
        m: usize,
        bound: Option<(f64, f64)>,
        buf: &mut Vec<f64>,
        rng: &mut Xoshiro256pp,
    ) -> usize {
        match self.noise {
            NoiseSampler::None => {
                self.fill_core(n, m, bound, buf, rng, |_| 0.0, false)
            }
            NoiseSampler::PaperBounded(d) => {
                self.fill_core(n, m, bound, buf, rng, |r| d.sample(r), true)
            }
            NoiseSampler::LogNormal(d) => {
                self.fill_core(n, m, bound, buf, rng, |r| d.sample(r), true)
            }
            NoiseSampler::Normal(d) => {
                self.fill_core(n, m, bound, buf, rng, |r| d.sample(r), true)
            }
            NoiseSampler::Bernoulli(d) => {
                self.fill_core(n, m, bound, buf, rng, |r| d.sample(r), true)
            }
            NoiseSampler::Exponential(d) => {
                self.fill_core(n, m, bound, buf, rng, |r| d.sample(r), true)
            }
            NoiseSampler::Gamma(d) => {
                self.fill_core(n, m, bound, buf, rng, |r| d.sample(r), true)
            }
            // step-indexed families: no per-draw noise (the offset is
            // added to the step's straggle by the caller)
            NoiseSampler::SharedBurst { .. } | NoiseSampler::Drift { .. } => {
                self.fill_core(n, m, bound, buf, rng, |_| 0.0, false)
            }
        }
    }

    /// Draw worker `n`'s whole accumulation run — `m` micro-batch
    /// latencies — into `buf` in one batched call. Stream consumption is
    /// bitwise identical to `m` sequential [`Self::sample_microbatch`]
    /// calls (property-tested in `tests/perf_equivalence.rs`).
    pub fn fill_microbatches(
        &self,
        n: usize,
        m: usize,
        buf: &mut Vec<f64>,
        rng: &mut Xoshiro256pp,
    ) {
        self.fill_dispatch(n, m, None, buf, rng);
    }

    /// [`Self::fill_microbatches`] for a thresholded (DropCompute) run
    /// starting at `start` (the straggler delay): stops drawing after
    /// the first sample whose running total reaches `tau`, exactly where
    /// the sequential preemption loops stopped — the worker's stream
    /// position is bitwise identical to the un-batched code. Returns the
    /// number of samples drawn (`buf.len()`).
    pub fn fill_microbatches_bounded(
        &self,
        n: usize,
        start: f64,
        tau: f64,
        m: usize,
        buf: &mut Vec<f64>,
        rng: &mut Xoshiro256pp,
    ) -> usize {
        self.fill_dispatch(n, m, Some((start, tau)), buf, rng)
    }

    /// The Bernoulli straggler coin worker `n` flips every step/local
    /// step, as `(p, delay)` — `Some` exactly when
    /// [`Self::straggler_draws`] is true (`Uniform` everywhere,
    /// `SingleServer` inside the server). `None` and `Fatal` flip no
    /// coin.
    fn straggler_coin(&self, n: usize) -> Option<(f64, f64)> {
        match &self.stragglers {
            StragglerKind::None | StragglerKind::Fatal { .. } => None,
            StragglerKind::Uniform { p, delay } => Some((*p, *delay)),
            StragglerKind::SingleServer { p, delay, server_size } => {
                (n < *server_size).then(|| (*p, *delay))
            }
        }
    }

    /// The fused Local-SGD period fill: `h` (straggler coin,
    /// micro-batch) pairs drawn in the exact sequential interleaving —
    /// coin then sample, per local step — with the straggler *and*
    /// noise dispatch hoisted out of the loop (the last per-draw branch
    /// on the Local-SGD hot path). Each entry of `buf` is
    /// `straggle + micro-batch latency`, the local step's compute time.
    #[inline(always)]
    fn fill_local_core(
        &self,
        n: usize,
        h: usize,
        p: f64,
        delay: f64,
        buf: &mut Vec<f64>,
        rng: &mut Xoshiro256pp,
        mut eps: impl FnMut(&mut Xoshiro256pp) -> f64,
        has_noise: bool,
    ) {
        buf.clear();
        buf.reserve(h);
        let scale = self.worker_scale.get(n).copied().unwrap_or(1.0);
        let base_floor = 0.1 * self.base.mu;
        let total_floor = 0.01 * self.base.mu;
        for _ in 0..h {
            // exactly sample_straggler_at's Uniform / in-server coin
            let straggle = if rng.next_f64() < p { delay } else { 0.0 };
            // exactly sample_microbatch's draw order and clamps
            let mut t = self.base.sample(rng).max(base_floor) * scale;
            if has_noise {
                let e = eps(rng);
                t += if self.relative { self.mean_scale * e } else { e };
            }
            buf.push(straggle + t.max(total_floor));
        }
    }

    /// Draw worker `n`'s whole Local-SGD period — `h` local steps whose
    /// straggler coin flips interleave with the micro-batch draws in
    /// its stream — in one batched call. Stream consumption is bitwise
    /// identical to the sequential
    /// `sample_straggler_at` + [`Self::sample_microbatch`] loop
    /// (property-tested in `tests/perf_equivalence.rs`); the caller
    /// must only use it when [`Self::straggler_draws`] is true (the
    /// coin-free scenarios batch through [`Self::fill_microbatches`]
    /// with the straggle hoisted instead).
    pub fn fill_local_steps(
        &self,
        n: usize,
        h: usize,
        buf: &mut Vec<f64>,
        rng: &mut Xoshiro256pp,
    ) {
        let (p, delay) = self
            .straggler_coin(n)
            .expect("fill_local_steps needs a coin-flipping straggler");
        match self.noise {
            NoiseSampler::None => {
                self.fill_local_core(n, h, p, delay, buf, rng, |_| 0.0, false)
            }
            NoiseSampler::PaperBounded(d) => self
                .fill_local_core(n, h, p, delay, buf, rng, |r| d.sample(r), true),
            NoiseSampler::LogNormal(d) => self
                .fill_local_core(n, h, p, delay, buf, rng, |r| d.sample(r), true),
            NoiseSampler::Normal(d) => self
                .fill_local_core(n, h, p, delay, buf, rng, |r| d.sample(r), true),
            NoiseSampler::Bernoulli(d) => self
                .fill_local_core(n, h, p, delay, buf, rng, |r| d.sample(r), true),
            NoiseSampler::Exponential(d) => self
                .fill_local_core(n, h, p, delay, buf, rng, |r| d.sample(r), true),
            NoiseSampler::Gamma(d) => self
                .fill_local_core(n, h, p, delay, buf, rng, |r| d.sample(r), true),
            NoiseSampler::SharedBurst { .. } | NoiseSampler::Drift { .. } => {
                self.fill_local_core(n, h, p, delay, buf, rng, |_| 0.0, false)
            }
        }
    }

    /// Effectively-infinite delay of a failed worker (finite so the
    /// max/CDF arithmetic stays well-defined).
    pub const FATAL_DELAY: f64 = 1e9;

    /// Per-step straggler delay for worker `n` (0 if not straggling).
    pub fn sample_straggler(&self, n: usize, rng: &mut Xoshiro256pp) -> f64 {
        self.sample_straggler_at(n, usize::MAX, rng)
    }

    /// Whether sampling worker `n`'s straggler delay consumes random
    /// draws from its stream. `None` and `Fatal` are pure functions of
    /// `(n, step)`; `Uniform` flips a coin every call, `SingleServer`
    /// only for workers inside the server. Callers batching micro-batch
    /// draws use this to know when straggler draws interleave.
    pub fn straggler_draws(&self, n: usize) -> bool {
        match &self.stragglers {
            StragglerKind::None | StragglerKind::Fatal { .. } => false,
            StragglerKind::Uniform { .. } => true,
            StragglerKind::SingleServer { server_size, .. } => n < *server_size,
        }
    }

    /// Step-aware variant (needed by `Fatal`, which triggers at a step).
    pub fn sample_straggler_at(
        &self,
        n: usize,
        step: usize,
        rng: &mut Xoshiro256pp,
    ) -> f64 {
        match &self.stragglers {
            StragglerKind::None => 0.0,
            StragglerKind::Uniform { p, delay } => {
                if rng.next_f64() < *p {
                    *delay
                } else {
                    0.0
                }
            }
            StragglerKind::SingleServer { p, delay, server_size } => {
                if n < *server_size && rng.next_f64() < *p {
                    *delay
                } else {
                    0.0
                }
            }
            StragglerKind::Fatal { worker, from_step } => {
                if n == *worker && step >= *from_step {
                    Self::FATAL_DELAY
                } else {
                    0.0
                }
            }
        }
    }

    /// Analytical mean of one micro-batch latency (no stragglers).
    pub fn mean(&self) -> f64 {
        let noise_mean = if self.noise.is_none() {
            0.0
        } else if self.relative {
            self.mean_scale * self.noise.mean()
        } else {
            self.noise.mean()
        };
        self.base.mean() + noise_mean
    }

    /// Analytical variance of one micro-batch latency (no stragglers).
    pub fn variance(&self) -> f64 {
        let noise_var = if self.noise.is_none() {
            0.0
        } else if self.relative {
            self.mean_scale * self.mean_scale * self.noise.variance()
        } else {
            self.noise.variance()
        };
        self.base.variance() + noise_var
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn base_config() -> ClusterConfig {
        ClusterConfig {
            workers: 4,
            microbatch_mean: 0.45,
            microbatch_std: 0.01,
            ..Default::default()
        }
    }

    #[test]
    fn no_noise_matches_base_moments() {
        let m = LatencyModel::from_config(&base_config());
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| m.sample_microbatch(0, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 0.45).abs() < 1e-3, "{mean}");
        assert!((m.mean() - 0.45).abs() < 1e-12);
    }

    #[test]
    fn paper_noise_x15_slowdown() {
        // App. B.1: with the paper constants each accumulation takes
        // ~1.5x longer on average.
        let mut c = base_config();
        c.noise = NoiseKind::PaperLogNormal {
            mu: 4.0,
            sigma: 1.0,
            alpha: 2.0 * (4.5f64).exp(),
            beta: 5.5,
        };
        let m = LatencyModel::from_config(&c);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|_| m.sample_microbatch(0, &mut rng)).sum::<f64>() / n as f64;
        let ratio = mean / 0.45;
        assert!((1.35..1.65).contains(&ratio), "ratio {ratio}");
        // analytic model agrees with sampling
        assert!((m.mean() - mean).abs() < 5e-3, "{} vs {mean}", m.mean());
    }

    #[test]
    fn absolute_noise_families() {
        for kind in [
            NoiseKind::LogNormal { mean: 0.225, var: 0.05 },
            NoiseKind::Normal { mean: 0.225, var: 0.05 },
            NoiseKind::Exponential { mean: 0.225 },
            NoiseKind::Gamma { mean: 0.225, var: 0.05 },
            NoiseKind::Bernoulli { p: 0.5, value: 0.45 },
        ] {
            let mut c = base_config();
            c.noise = kind.clone();
            let m = LatencyModel::from_config(&c);
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            let n = 150_000;
            let mean: f64 = (0..n)
                .map(|_| m.sample_microbatch(0, &mut rng))
                .sum::<f64>()
                / n as f64;
            assert!(
                (mean - m.mean()).abs() < 8e-3,
                "{kind:?}: sampled {mean} vs analytic {}",
                m.mean()
            );
        }
    }

    #[test]
    fn enum_sampler_matches_boxed_for_every_kind() {
        // NoiseSampler must be draw-for-draw bitwise identical to the
        // boxed Distribution the same kind builds (the deeper
        // fill/stream property tests live in tests/perf_equivalence.rs).
        for kind in [
            NoiseKind::PaperLogNormal {
                mu: 4.0,
                sigma: 1.0,
                alpha: 2.0 * (4.5f64).exp(),
                beta: 5.5,
            },
            NoiseKind::LogNormal { mean: 0.225, var: 0.05 },
            NoiseKind::Normal { mean: 0.225, var: 0.05 },
            NoiseKind::Bernoulli { p: 0.5, value: 0.45 },
            NoiseKind::Exponential { mean: 0.225 },
            NoiseKind::Gamma { mean: 0.225, var: 0.05 },
        ] {
            let boxed = build_noise(&kind).expect("non-None kind");
            let sampler = NoiseSampler::from_kind(&kind);
            assert!(!sampler.is_none());
            let mut r1 = Xoshiro256pp::seed_from_u64(0xD1CE);
            let mut r2 = Xoshiro256pp::seed_from_u64(0xD1CE);
            for i in 0..2_000 {
                assert_eq!(
                    boxed.sample(&mut r1).to_bits(),
                    sampler.sample(&mut r2).to_bits(),
                    "{kind:?} draw {i}"
                );
            }
            assert_eq!(boxed.mean().to_bits(), sampler.mean().to_bits());
            assert_eq!(boxed.variance().to_bits(), sampler.variance().to_bits());
        }
        assert!(NoiseSampler::from_kind(&NoiseKind::None).is_none());
        assert!(build_noise(&NoiseKind::None).is_none());
    }

    #[test]
    fn batched_fill_matches_sequential_microbatches() {
        for kind in [
            NoiseKind::None,
            NoiseKind::PaperLogNormal {
                mu: 4.0,
                sigma: 1.0,
                alpha: 2.0 * (4.5f64).exp(),
                beta: 5.5,
            },
            NoiseKind::Gamma { mean: 0.225, var: 0.05 },
        ] {
            let mut c = base_config();
            c.noise = kind;
            let m = LatencyModel::from_config(&c)
                .with_worker_scales(vec![1.0, 1.7, 1.0, 1.0]);
            let mut r1 = Xoshiro256pp::seed_from_u64(0xF111);
            let mut r2 = Xoshiro256pp::seed_from_u64(0xF111);
            let mut buf = Vec::new();
            for n in [0usize, 1] {
                m.fill_microbatches(n, 16, &mut buf, &mut r2);
                assert_eq!(buf.len(), 16);
                for (i, &s) in buf.iter().enumerate() {
                    assert_eq!(
                        s.to_bits(),
                        m.sample_microbatch(n, &mut r1).to_bits(),
                        "worker {n} sample {i}"
                    );
                }
            }
            // streams end at the same position
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
    }

    #[test]
    fn bounded_fill_stops_at_the_crossing_sample() {
        let m = LatencyModel::from_config(&base_config());
        let mut r1 = Xoshiro256pp::seed_from_u64(3);
        let mut r2 = Xoshiro256pp::seed_from_u64(3);
        let mut buf = Vec::new();
        // tau below one sample: exactly one draw happens
        let drawn = m.fill_microbatches_bounded(0, 0.0, 0.1, 12, &mut buf, &mut r1);
        assert_eq!(drawn, 1);
        assert_eq!(buf[0].to_bits(), m.sample_microbatch(0, &mut r2).to_bits());
        assert_eq!(r1.next_u64(), r2.next_u64());
        // huge tau: the full run is drawn
        let drawn = m.fill_microbatches_bounded(0, 0.0, 1e9, 12, &mut buf, &mut r1);
        assert_eq!(drawn, 12);
        // a crossing mid-run stops mid-run (0.45s samples, tau = 1.0
        // crosses on the third sample: 0.45, 0.90, 1.35)
        let drawn = m.fill_microbatches_bounded(0, 0.0, 1.0, 12, &mut buf, &mut r1);
        assert_eq!(drawn, 3, "{buf:?}");
    }

    #[test]
    fn fused_local_fill_matches_sequential_coin_and_sample() {
        // the fused (coin, micro-batch) fill must consume the stream
        // exactly like the sequential interleaving, for coin-flipping
        // straggler scenarios across noise families
        for noise in [
            NoiseKind::None,
            NoiseKind::Exponential { mean: 0.2 },
            NoiseKind::PaperLogNormal {
                mu: 4.0,
                sigma: 1.0,
                alpha: 2.0 * (4.5f64).exp(),
                beta: 5.5,
            },
        ] {
            for strag in [
                StragglerKind::Uniform { p: 0.4, delay: 1.5 },
                StragglerKind::SingleServer {
                    p: 0.6,
                    delay: 2.0,
                    server_size: 2,
                },
            ] {
                let mut c = base_config();
                c.noise = noise.clone();
                c.stragglers = strag.clone();
                let m = LatencyModel::from_config(&c)
                    .with_worker_scales(vec![1.0, 1.3, 1.0, 1.0]);
                let mut r1 = Xoshiro256pp::seed_from_u64(0xC01);
                let mut r2 = Xoshiro256pp::seed_from_u64(0xC01);
                let mut buf = Vec::new();
                for n in [0usize, 1] {
                    assert!(m.straggler_draws(n), "{strag:?}");
                    m.fill_local_steps(n, 9, &mut buf, &mut r2);
                    assert_eq!(buf.len(), 9);
                    for (i, &t) in buf.iter().enumerate() {
                        let straggle = m.sample_straggler(n, &mut r1);
                        let want =
                            straggle + m.sample_microbatch(n, &mut r1);
                        assert_eq!(
                            t.to_bits(),
                            want.to_bits(),
                            "{noise:?} {strag:?} worker {n} step {i}"
                        );
                    }
                }
                // streams end at the same position
                assert_eq!(r1.next_u64(), r2.next_u64());
            }
        }
    }

    #[test]
    fn straggler_scenarios() {
        let mut c = base_config();
        c.stragglers = StragglerKind::SingleServer {
            p: 1.0,
            delay: 2.0,
            server_size: 2,
        };
        let m = LatencyModel::from_config(&c);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        assert_eq!(m.sample_straggler(0, &mut rng), 2.0);
        assert_eq!(m.sample_straggler(1, &mut rng), 2.0);
        assert_eq!(m.sample_straggler(2, &mut rng), 0.0);
        assert_eq!(m.sample_straggler(3, &mut rng), 0.0);
    }

    #[test]
    fn straggler_draws_tracks_rng_consumption() {
        let mk = |s: StragglerKind| {
            let mut c = base_config();
            c.stragglers = s;
            LatencyModel::from_config(&c)
        };
        assert!(!mk(StragglerKind::None).straggler_draws(0));
        assert!(!mk(StragglerKind::Fatal { worker: 1, from_step: 0 })
            .straggler_draws(1));
        assert!(mk(StragglerKind::Uniform { p: 0.1, delay: 1.0 })
            .straggler_draws(3));
        let ss = mk(StragglerKind::SingleServer {
            p: 0.1,
            delay: 1.0,
            server_size: 2,
        });
        // only in-server workers flip the coin (short-circuit in the
        // sampler): rng state after sampling an out-of-server worker is
        // untouched
        assert!(ss.straggler_draws(0) && ss.straggler_draws(1));
        assert!(!ss.straggler_draws(2));
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let before = rng.clone().next_u64();
        ss.sample_straggler(2, &mut rng);
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn shared_burst_offsets_are_correlated_and_deterministic() {
        let kind = NoiseKind::SharedBurst {
            p: 0.5,
            period: 10,
            delay: 2.0,
            subset: 2,
            seed: 42,
        };
        let s = NoiseSampler::from_kind(&kind);
        assert!(s.is_none(), "step-indexed families draw nothing per batch");
        assert!(build_noise(&kind).is_none());
        let mut burst_steps = 0usize;
        for step in 0..400u64 {
            let a = s.step_offset(0, step);
            let b = s.step_offset(1, step);
            // one shared burst clock: in-subset workers agree exactly
            assert_eq!(a.to_bits(), b.to_bits(), "step {step}");
            assert!(a == 0.0 || a == 2.0, "step {step}: {a}");
            // out-of-subset workers never burst
            assert_eq!(s.step_offset(2, step), 0.0);
            // pure in (worker, step): re-query is bitwise identical
            assert_eq!(a.to_bits(), s.step_offset(0, step).to_bits());
            // windows are 10 steps wide: the decision is constant
            // within a window
            assert_eq!(a.to_bits(), s.step_offset(0, (step / 10) * 10).to_bits());
            if a > 0.0 {
                burst_steps += 1;
            }
        }
        // p = 0.5 over 40 windows: some burst, some don't
        assert!(burst_steps > 0 && burst_steps < 400, "{burst_steps}");
    }

    #[test]
    fn drift_walk_is_deterministic_per_worker_and_non_negative() {
        let kind = NoiseKind::Drift { sigma: 0.05, seed: 7 };
        let s = NoiseSampler::from_kind(&kind);
        assert!(s.is_none());
        let mut moved = false;
        for step in 0..200u64 {
            let a = s.step_offset(0, step);
            assert!(a >= 0.0, "walk clamps at nominal: step {step} -> {a}");
            assert!(a <= 0.05 * (step + 1) as f64 + 1e-12);
            assert_eq!(a.to_bits(), s.step_offset(0, step).to_bits());
            if (a - s.step_offset(1, step)).abs() > 1e-12 {
                moved = true;
            }
        }
        assert!(moved, "independent walks per worker");
        // classic families have exactly zero step offset
        let classic = NoiseSampler::from_kind(&NoiseKind::Exponential {
            mean: 0.2,
        });
        for step in [0u64, 7, 99] {
            assert_eq!(classic.step_offset(0, step).to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn step_indexed_kinds_leave_the_draw_paths_untouched() {
        // a SharedBurst model's micro-batch draws must be bitwise the
        // no-noise model's (the offset rides the straggle, not the
        // per-draw stream)
        let mut c = base_config();
        c.noise = NoiseKind::SharedBurst {
            p: 1.0,
            period: 5,
            delay: 1.0,
            subset: 4,
            seed: 1,
        };
        let burst = LatencyModel::from_config(&c);
        let plain = LatencyModel::from_config(&base_config());
        let mut r1 = Xoshiro256pp::seed_from_u64(77);
        let mut r2 = Xoshiro256pp::seed_from_u64(77);
        let (mut b1, mut b2) = (Vec::new(), Vec::new());
        burst.fill_microbatches(0, 12, &mut b1, &mut r1);
        plain.fill_microbatches(0, 12, &mut b2, &mut r2);
        for (i, (a, b)) in b1.iter().zip(&b2).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "sample {i}");
        }
        assert_eq!(r1.next_u64(), r2.next_u64(), "same stream position");
    }

    #[test]
    fn worker_scale_accessors_roundtrip() {
        let mut m = LatencyModel::from_config(&base_config());
        assert_eq!(m.worker_scale(1), 1.0);
        m.set_worker_scale(1, 2.5);
        assert_eq!(m.worker_scale(1), 2.5);
        // out-of-range set grows the table; unset workers stay nominal
        m.set_worker_scale(9, 1.5);
        assert_eq!(m.worker_scale(9), 1.5);
        assert_eq!(m.worker_scale(8), 1.0);
        assert_eq!(m.worker_scale(100), 1.0);
    }

    #[test]
    fn worker_scales_heterogeneity() {
        let m = LatencyModel::from_config(&base_config())
            .with_worker_scales(vec![1.0, 2.0, 1.0, 1.0]);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let n = 50_000;
        let mean = |w: usize, rng: &mut Xoshiro256pp| -> f64 {
            (0..n).map(|_| m.sample_microbatch(w, rng)).sum::<f64>() / n as f64
        };
        let m0 = mean(0, &mut rng);
        let m1 = mean(1, &mut rng);
        assert!((m1 / m0 - 2.0).abs() < 0.05, "{m0} {m1}");
    }
}
