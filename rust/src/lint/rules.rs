//! The invariant rules: each one encodes a project-wide contract that
//! used to live only in reviewers' memories and PR notes.
//!
//! Rules are scoped by *relative path under the lint root* (e.g.
//! `sim/cluster.rs`), so moving a file in or out of a
//! determinism-critical module changes what is enforced — exactly the
//! intent. All rules skip test code ([`SourceModel::in_test`]): tests
//! may panic, allocate, and read clocks freely.

use super::lexer::{is_ident, is_punct, Kind, Token};
use super::scan::{brace_depths, skip_braces, SourceModel};
use super::{Diagnostic, Severity};

pub const WALL_CLOCK: &str = "wall-clock";
pub const UNORDERED_ITER: &str = "unordered-iter";
pub const ENUM_WILDCARD: &str = "enum-wildcard";
pub const HOTPATH_PANIC: &str = "hotpath-panic";
pub const HOTPATH_ALLOC: &str = "hotpath-alloc";
pub const LOCK_ACROSS_IO: &str = "lock-across-io";
/// Meta rule: misuse of the lint surface itself (unknown rule names in
/// `lint:allow`, stale baseline entries). Warn-level — it never gates.
pub const LINT_USAGE: &str = "lint-usage";

/// Catalog entry for one rule: suppression key, full invariant name,
/// severity, one-line summary (the README table renders from this).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub key: &'static str,
    pub name: &'static str,
    pub severity: Severity,
    pub summary: &'static str,
}

pub const RULES: [RuleInfo; 6] = [
    RuleInfo {
        key: WALL_CLOCK,
        name: "determinism/wall-clock",
        severity: Severity::Deny,
        summary: "Instant::now/SystemTime outside transport/, util/, \
                  sweep/runner.rs: simulated time comes from the \
                  virtual clock, never the host clock",
    },
    RuleInfo {
        key: UNORDERED_ITER,
        name: "determinism/unordered-iteration",
        severity: Severity::Deny,
        summary: "HashMap/HashSet in sim/, sweep/, obs/, analysis/, \
                  transport/: hash order is not deterministic across \
                  runs; use BTreeMap/BTreeSet or index-ordered Vecs",
    },
    RuleInfo {
        key: ENUM_WILDCARD,
        name: "closed-enum-exhaustiveness",
        severity: Severity::Deny,
        summary: "wildcard `_` arm in a match on a closed enum \
                  (DropPolicy, NoiseKind, NoiseSampler, DropCause, \
                  FaultEvent): a future variant must be a compile \
                  error, not a silent fallthrough",
    },
    RuleInfo {
        key: HOTPATH_PANIC,
        name: "hot-path-panic",
        severity: Severity::Deny,
        summary: "unwrap()/expect() in a designated steady-state \
                  function: the stepping hot path must not panic",
    },
    RuleInfo {
        key: HOTPATH_ALLOC,
        name: "hot-path-allocation",
        severity: Severity::Deny,
        summary: "Vec::new/vec![]/collect()/Box::new in a designated \
                  steady-state function: stepping is allocation-free \
                  after warmup",
    },
    RuleInfo {
        key: LOCK_ACROSS_IO,
        name: "transport-lock-discipline",
        severity: Severity::Deny,
        summary: "Mutex guard bound by `let` and still live across a \
                  blocking send/recv/sleep: a stalled peer must never \
                  stall unrelated lock holders",
    },
];

/// Look up a rule's catalog entry (the meta rule has no entry).
pub fn rule_info(key: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.key == key)
}

/// Is `key` a rule name `lint:allow` may legitimately reference?
pub fn known_rule(key: &str) -> bool {
    key == LINT_USAGE || rule_info(key).is_some()
}

/// Files where wall-clock reads are the point: the real transport
/// measures reality, the sweep progress meter reports to a human, and
/// `util::Stopwatch` is the sanctioned timer.
const CLOCK_ALLOWLIST: &[&str] = &["transport/", "util/", "sweep/runner.rs"];

/// Modules whose state feeds deterministic results: any iteration
/// order that reaches an output must be total and stable.
const ORDERED_MODULES: &[&str] =
    &["sim/", "sweep/", "obs/", "analysis/", "transport/"];

/// Closed enums whose matches must stay exhaustive (no `_` arms):
/// adding a variant to any of these must break the build everywhere a
/// decision is made about it.
const CLOSED_ENUMS: &[&str] =
    &["DropPolicy", "NoiseKind", "NoiseSampler", "DropCause", "FaultEvent"];

/// The designated steady-state functions: one entry per (file,
/// function) pair, so a name like `completion` can be hot in
/// `sim/survivor.rs` without designating every `completion` in the
/// crate. These are the allocation-free, panic-free stepping paths the
/// perf suite and the PR notes have claimed since PR 2/3.
const HOT_FUNCTIONS: &[(&str, &[&str])] = &[
    (
        "sim/cluster.rs",
        &[
            "step_into",
            "step_observed",
            "begin_step_observed",
            "finish_step_observed",
            "seal_batched_step",
            "finish_into",
            "per_phase_iter_time",
            "recursive_survivor_time",
            "recursive_restart_rounds",
            "finish_faulted",
        ],
    ),
    (
        "sim/batch.rs",
        &["step_installed_into", "lockstep_pass", "scan_max4"],
    ),
    (
        "sim/compiled.rs",
        &["completion_with", "completion_with_phases", "bounded_completion_with"],
    ),
    (
        "sim/survivor.rs",
        &["completion", "completion_at", "bounded_completion", "bounded_completion_at"],
    ),
];

/// Modules where the lock-discipline rule applies (everything that
/// talks to channels or sockets).
const LOCK_MODULES: &[&str] = &["transport/", "collective/"];

/// Calls that can block on a peer: holding a lock across any of these
/// couples unrelated threads to the slowest peer.
const BLOCKING_CALLS: &[&str] = &[
    "write_frame",
    "read_frame",
    "recv",
    "recv_timeout",
    "recv_deadline",
    "recv_matching",
    "sleep",
    "connect",
    "accept",
    "write_all",
    "read_exact",
    "flush",
];

/// Run every rule over one file's model. `path` is the relative path
/// under the lint root with `/` separators.
pub fn run_rules(path: &str, model: &SourceModel) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    wall_clock(path, model, &mut out);
    unordered_iter(path, model, &mut out);
    enum_wildcard(path, model, &mut out);
    hotpath_panic(path, model, &mut out);
    hotpath_alloc(path, model, &mut out);
    lock_across_io(path, model, &mut out);
    out
}

fn diag(rule: &'static str, path: &str, line: u32, message: String) -> Diagnostic {
    let severity = rule_info(rule).map_or(Severity::Warn, |r| r.severity);
    Diagnostic {
        rule,
        severity,
        file: path.to_string(),
        line,
        message,
        snippet: String::new(),
        suppressed: None,
    }
}

fn path_in(path: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| {
        if p.ends_with('/') {
            path.starts_with(p)
        } else {
            path == *p
        }
    })
}

/// Rule 1: no wall-clock reads outside the allowlist. Flags
/// `Instant::now` call paths and any `SystemTime` use.
fn wall_clock(path: &str, model: &SourceModel, out: &mut Vec<Diagnostic>) {
    if path_in(path, CLOCK_ALLOWLIST) {
        return;
    }
    let t = &model.tokens;
    for i in 0..t.len() {
        if model.in_test(i) {
            continue;
        }
        if is_ident(&t[i], "Instant")
            && i + 2 < t.len()
            && is_punct(&t[i + 1], "::")
            && is_ident(&t[i + 2], "now")
        {
            out.push(diag(
                WALL_CLOCK,
                path,
                t[i].line,
                "`Instant::now()` outside the wall-clock allowlist \
                 (transport/, util/, sweep/runner.rs): simulated timing \
                 must come from the virtual clock"
                    .to_string(),
            ));
        } else if is_ident(&t[i], "SystemTime") {
            out.push(diag(
                WALL_CLOCK,
                path,
                t[i].line,
                "`SystemTime` outside the wall-clock allowlist: \
                 simulated timing must come from the virtual clock"
                    .to_string(),
            ));
        }
    }
}

/// Rule 2: no hash-ordered containers in determinism-critical modules.
fn unordered_iter(path: &str, model: &SourceModel, out: &mut Vec<Diagnostic>) {
    if !path_in(path, ORDERED_MODULES) {
        return;
    }
    let t = &model.tokens;
    for i in 0..t.len() {
        if model.in_test(i) || t[i].kind != Kind::Ident {
            continue;
        }
        if t[i].text == "HashMap" || t[i].text == "HashSet" {
            out.push(diag(
                UNORDERED_ITER,
                path,
                t[i].line,
                format!(
                    "`{}` in a determinism-critical module: iteration \
                     order is unstable across runs and can feed \
                     results; use BTreeMap/BTreeSet or an \
                     index-ordered Vec",
                    t[i].text
                ),
            ));
        }
    }
}

/// One parsed match arm: pattern token range (guard excluded), whether
/// a guard follows, and the pattern's first line.
struct Arm {
    pattern: (usize, usize),
    has_guard: bool,
    line: u32,
}

/// Parse the arms of the `match` whose keyword sits at `mi`. Pattern
/// tokens run to the `=>` (or the guard `if`) at arm depth; arm bodies
/// are skipped with balanced delimiters, so nested matches inside a
/// body never masquerade as outer arms (they get their own parse from
/// the outer token walk). Returns `None` for shapes that are not a
/// match expression we understand.
fn parse_match_arms(t: &[Token], mi: usize) -> Option<Vec<Arm>> {
    // scrutinee: everything to the first `{` at paren/bracket depth 0
    let mut paren = 0i64;
    let mut brack = 0i64;
    let mut j = mi + 1;
    loop {
        let tok = t.get(j)?;
        if is_punct(tok, "(") {
            paren += 1;
        } else if is_punct(tok, ")") {
            paren -= 1;
        } else if is_punct(tok, "[") {
            brack += 1;
        } else if is_punct(tok, "]") {
            brack -= 1;
        } else if paren == 0 && brack == 0 {
            if is_punct(tok, "{") {
                break;
            }
            if is_punct(tok, ";") {
                return None;
            }
        }
        j += 1;
    }
    let close = skip_braces(t, j).checked_sub(1)?;
    let mut arms = Vec::new();
    let mut k = j + 1;
    while k < close {
        let arm_line = t[k].line;
        let pat_start = k;
        let mut p = 0i64;
        let mut b = 0i64;
        let mut br = 0i64;
        let mut has_guard = false;
        let mut pat_end = None;
        let mut found_arrow = false;
        while k < close {
            let tok = &t[k];
            if is_punct(tok, "(") {
                p += 1;
            } else if is_punct(tok, ")") {
                p -= 1;
            } else if is_punct(tok, "[") {
                b += 1;
            } else if is_punct(tok, "]") {
                b -= 1;
            } else if is_punct(tok, "{") {
                br += 1;
            } else if is_punct(tok, "}") {
                br -= 1;
            } else if p == 0 && b == 0 && br == 0 {
                if is_ident(tok, "if") && pat_end.is_none() {
                    has_guard = true;
                    pat_end = Some(k);
                } else if is_punct(tok, "=>") {
                    if pat_end.is_none() {
                        pat_end = Some(k);
                    }
                    found_arrow = true;
                    k += 1;
                    break;
                }
            }
            k += 1;
        }
        let (Some(pe), true) = (pat_end, found_arrow) else { break };
        arms.push(Arm { pattern: (pat_start, pe), has_guard, line: arm_line });
        // arm body: a block (optionally comma-terminated) or an
        // expression running to the `,` at arm depth
        if k < close && is_punct(&t[k], "{") {
            k = skip_braces(t, k);
            if k < close && is_punct(&t[k], ",") {
                k += 1;
            }
        } else {
            let mut p2 = 0i64;
            let mut b2 = 0i64;
            let mut br2 = 0i64;
            while k < close {
                let tok = &t[k];
                if is_punct(tok, "(") {
                    p2 += 1;
                } else if is_punct(tok, ")") {
                    p2 -= 1;
                } else if is_punct(tok, "[") {
                    b2 += 1;
                } else if is_punct(tok, "]") {
                    b2 -= 1;
                } else if is_punct(tok, "{") {
                    br2 += 1;
                } else if is_punct(tok, "}") {
                    br2 -= 1;
                } else if p2 == 0
                    && b2 == 0
                    && br2 == 0
                    && is_punct(tok, ",")
                {
                    k += 1;
                    break;
                }
                k += 1;
            }
        }
    }
    Some(arms)
}

/// Which closed enum (if any) do this match's arm *patterns* name?
/// Patterns only — `match parts.len()` with `DropPolicy::…`
/// constructors in arm bodies is not a match *on* the enum.
fn closed_enum_in_patterns(t: &[Token], arms: &[Arm]) -> Option<&'static str> {
    for arm in arms {
        for i in arm.pattern.0..arm.pattern.1 {
            if t[i].kind == Kind::Ident
                && i + 1 < arm.pattern.1
                && is_punct(&t[i + 1], "::")
            {
                if let Some(e) =
                    CLOSED_ENUMS.iter().find(|e| **e == t[i].text)
                {
                    return Some(e);
                }
            }
        }
    }
    None
}

/// Rule 3: no bare `_` arms in matches on closed enums. A guarded
/// wildcard (`_ if cond =>`) is a deliberate predicate catch-all and
/// is not flagged; neither is a tuple pattern with `_` elements — only
/// the arm whose entire pattern is `_` silently swallows variants.
fn enum_wildcard(path: &str, model: &SourceModel, out: &mut Vec<Diagnostic>) {
    let t = &model.tokens;
    for i in 0..t.len() {
        if !is_ident(&t[i], "match") || model.in_test(i) {
            continue;
        }
        let Some(arms) = parse_match_arms(t, i) else { continue };
        let Some(enum_name) = closed_enum_in_patterns(t, &arms) else {
            continue;
        };
        for arm in &arms {
            let (s, e) = arm.pattern;
            if !arm.has_guard && e - s == 1 && is_ident(&t[s], "_") {
                out.push(diag(
                    ENUM_WILDCARD,
                    path,
                    arm.line,
                    format!(
                        "wildcard `_` arm in a match on closed enum \
                         `{enum_name}`: a future variant would fall \
                         through silently; list the remaining variants \
                         explicitly"
                    ),
                ));
            }
        }
    }
}

/// Iterate the designated steady-state functions of `path`.
fn hot_fns<'m>(
    path: &str,
    model: &'m SourceModel,
) -> impl Iterator<Item = &'m super::scan::FnSpan> {
    let names: &'static [&'static str] =
        match HOT_FUNCTIONS.iter().find(|(f, _)| *f == path) {
            Some(&(_, names)) => names,
            None => &[],
        };
    model
        .fns
        .iter()
        .filter(move |f| !f.in_test && names.contains(&f.name.as_str()))
}

/// Rule 4: no `unwrap()`/`expect()` in designated hot functions.
fn hotpath_panic(path: &str, model: &SourceModel, out: &mut Vec<Diagnostic>) {
    let t = &model.tokens;
    for f in hot_fns(path, model) {
        for i in f.body.0..f.body.1.min(t.len()) {
            if is_punct(&t[i], ".")
                && i + 2 < t.len()
                && (is_ident(&t[i + 1], "unwrap") || is_ident(&t[i + 1], "expect"))
                && is_punct(&t[i + 2], "(")
            {
                out.push(diag(
                    HOTPATH_PANIC,
                    path,
                    t[i + 1].line,
                    format!(
                        "`.{}()` in steady-state function `{}`: the \
                         stepping hot path must not panic — return a \
                         typed error or restructure the borrow",
                        t[i + 1].text, f.name
                    ),
                ));
            }
        }
    }
}

/// Rule 5: no allocation in designated hot functions.
fn hotpath_alloc(path: &str, model: &SourceModel, out: &mut Vec<Diagnostic>) {
    let t = &model.tokens;
    for f in hot_fns(path, model) {
        for i in f.body.0..f.body.1.min(t.len()) {
            let what = if is_ident(&t[i], "Vec")
                && i + 2 < t.len()
                && is_punct(&t[i + 1], "::")
                && is_ident(&t[i + 2], "new")
            {
                Some("Vec::new")
            } else if is_ident(&t[i], "Box")
                && i + 2 < t.len()
                && is_punct(&t[i + 1], "::")
                && is_ident(&t[i + 2], "new")
            {
                Some("Box::new")
            } else if is_ident(&t[i], "vec")
                && i + 1 < t.len()
                && is_punct(&t[i + 1], "!")
            {
                Some("vec![]")
            } else if is_punct(&t[i], ".")
                && i + 1 < t.len()
                && is_ident(&t[i + 1], "collect")
            {
                Some("collect()")
            } else {
                None
            };
            if let Some(what) = what {
                out.push(diag(
                    HOTPATH_ALLOC,
                    path,
                    t[i].line,
                    format!(
                        "allocation (`{what}`) in steady-state function \
                         `{}`: stepping is allocation-free after warmup \
                         — reuse a scratch buffer",
                        f.name
                    ),
                ));
            }
        }
    }
}

/// Rule 6: a `let`-bound Mutex guard must not stay live across a
/// blocking call. The guard's scope is approximated by the brace depth
/// of its `let`: the scan runs from the end of the binding statement
/// until the enclosing block closes (or an explicit `drop(name)`),
/// flagging the first blocking call inside that window. The `.lock()`
/// is attributed to the *innermost* enclosing `let`, so a guard
/// confined to a `{ … }` initializer block never taints the outer
/// binding.
fn lock_across_io(path: &str, model: &SourceModel, out: &mut Vec<Diagnostic>) {
    if !path_in(path, LOCK_MODULES) {
        return;
    }
    let t = &model.tokens;
    let depths = brace_depths(t);
    for f in model.fns.iter().filter(|f| !f.in_test) {
        let (start, end) = (f.body.0, f.body.1.min(t.len()));
        // every `let` statement in the body and its terminating `;`
        let mut lets: Vec<(usize, usize)> = Vec::new();
        for i in start..end {
            if !is_ident(&t[i], "let") {
                continue;
            }
            // `if let` / `while let` scrutinees are not guard bindings
            if i > 0
                && (is_ident(&t[i - 1], "if") || is_ident(&t[i - 1], "while"))
            {
                continue;
            }
            let d = depths[i];
            let mut paren = 0i64;
            let mut brack = 0i64;
            let mut j = i + 1;
            while j < end {
                if is_punct(&t[j], "(") {
                    paren += 1;
                } else if is_punct(&t[j], ")") {
                    paren -= 1;
                } else if is_punct(&t[j], "[") {
                    brack += 1;
                } else if is_punct(&t[j], "]") {
                    brack -= 1;
                } else if paren == 0
                    && brack == 0
                    && depths[j] == d
                    && is_punct(&t[j], ";")
                {
                    break;
                }
                j += 1;
            }
            lets.push((i, j));
        }
        // each `.lock(` goes to its innermost enclosing `let`
        for i in start..end {
            if !(is_punct(&t[i], ".")
                && i + 2 < end
                && is_ident(&t[i + 1], "lock")
                && is_punct(&t[i + 2], "("))
            {
                continue;
            }
            let Some(&(li, lend)) = lets
                .iter()
                .filter(|&&(s, e)| s < i && i < e)
                .max_by_key(|&&(s, _)| s)
            else {
                continue; // temporary guard, dropped at statement end
            };
            // binding name: `let [mut] name = …` (skip destructuring)
            let mut ni = li + 1;
            if ni < end && is_ident(&t[ni], "mut") {
                ni += 1;
            }
            if ni >= end || t[ni].kind != Kind::Ident {
                continue;
            }
            let name = &t[ni].text;
            let let_depth = depths[li];
            let mut k = lend + 1;
            while k < end && depths[k] >= let_depth {
                if is_ident(&t[k], "drop")
                    && k + 2 < end
                    && is_punct(&t[k + 1], "(")
                    && is_ident(&t[k + 2], name)
                {
                    break;
                }
                if t[k].kind == Kind::Ident
                    && BLOCKING_CALLS.contains(&t[k].text.as_str())
                    && k + 1 < end
                    && is_punct(&t[k + 1], "(")
                {
                    out.push(diag(
                        LOCK_ACROSS_IO,
                        path,
                        t[li].line,
                        format!(
                            "mutex guard `{name}` is still live across \
                             blocking `{}`: a stalled peer would stall \
                             every thread contending this lock — drop \
                             the guard first",
                            t[k].text
                        ),
                    ));
                    break;
                }
                k += 1;
            }
        }
    }
}
