//! Checked-in lint baseline: grandfathered findings that pre-date a
//! rule, so adoption can be incremental without inline noise.
//!
//! Entries are content-addressed, not line-addressed: a finding is
//! keyed by `(rule, file, trimmed source line)`, so unrelated edits
//! that shift line numbers never invalidate the baseline, while
//! *touching the flagged line itself* resurfaces the finding — exactly
//! when a human is already looking at it. Duplicate lines count as a
//! multiset: two identical findings need two entries. Entries that no
//! longer match anything are reported as warn-level `lint-usage`
//! diagnostics so the file can only shrink.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::Result;

use super::Diagnostic;

const HEADER: &str = "\
# dropcompute lint baseline — grandfathered findings, one per line:
#   rule|file|first-matching-source-line (trimmed)
# Matching is by content, not line number; regenerate with
# `dropcompute lint --update-baseline`.
";

/// Multiset of grandfathered findings keyed `rule|file|snippet`.
#[derive(Debug, Default)]
pub struct Baseline {
    entries: BTreeMap<(String, String, String), usize>,
}

impl Baseline {
    pub fn empty() -> Self {
        Self::default()
    }

    /// Parse the baseline format. Blank lines and `#` comments are
    /// ignored; malformed lines (fewer than three `|`-separated
    /// fields) are ignored too — a lint pass degrades, never fails.
    pub fn parse(text: &str) -> Self {
        let mut entries: BTreeMap<(String, String, String), usize> =
            BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(3, '|');
            let (Some(rule), Some(file), Some(snippet)) =
                (parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            *entries
                .entry((
                    rule.trim().to_string(),
                    file.trim().to_string(),
                    snippet.to_string(),
                ))
                .or_insert(0) += 1;
        }
        Baseline { entries }
    }

    /// Load from `path`; a missing file is an empty baseline (the
    /// common state — this repo keeps itself clean).
    pub fn load(path: &Path) -> Result<Self> {
        if !path.exists() {
            return Ok(Self::empty());
        }
        Ok(Self::parse(&std::fs::read_to_string(path)?))
    }

    /// Serialize `diags` as a baseline file (sorted, deduplicated into
    /// multiset entries by repetition).
    pub fn format<'d>(diags: impl IntoIterator<Item = &'d Diagnostic>) -> String {
        let mut lines: Vec<String> = diags
            .into_iter()
            .map(|d| format!("{}|{}|{}", d.rule, d.file, d.snippet))
            .collect();
        lines.sort();
        let mut out = String::from(HEADER);
        for l in lines {
            out.push_str(&l);
            out.push('\n');
        }
        out
    }

    /// Consume one matching entry if present.
    pub fn take(&mut self, rule: &str, file: &str, snippet: &str) -> bool {
        let key =
            (rule.to_string(), file.to_string(), snippet.to_string());
        match self.entries.get_mut(&key) {
            Some(n) if *n > 0 => {
                *n -= 1;
                if *n == 0 {
                    self.entries.remove(&key);
                }
                true
            }
            _ => false,
        }
    }

    /// Entries never consumed by [`Self::take`] — stale grandfathering
    /// that should be deleted from the file.
    pub fn stale(&self) -> Vec<(String, String, String)> {
        self.entries
            .iter()
            .flat_map(|(k, &n)| std::iter::repeat(k.clone()).take(n))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.entries.values().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}
