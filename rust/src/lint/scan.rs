//! Brace-aware item/function scanner over the lexed token stream.
//!
//! Builds the structural model the rules consume: function spans (so
//! the hot-path rules can scope to designated steady-state functions),
//! test regions (`#[cfg(test)]` items and `#[test]` functions are
//! exempt from every rule — test code may panic, allocate, and read
//! clocks at will), and balanced-delimiter navigation helpers. Same
//! spirit as the `obs lint` exposition checker: hand-rolled, total,
//! and tolerant — malformed input yields fewer spans, never a panic.

use super::lexer::{is_ident, is_punct, Allow, Kind, Lexed, Token};

/// A function item with its body as a half-open token-index range
/// (excluding the outer braces).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub line: u32,
    pub body: (usize, usize),
    pub in_test: bool,
}

/// The per-file structural model: tokens, suppressions, functions,
/// test regions.
#[derive(Debug)]
pub struct SourceModel {
    pub tokens: Vec<Token>,
    pub allows: Vec<Allow>,
    pub fns: Vec<FnSpan>,
    test_ranges: Vec<(usize, usize)>,
}

impl SourceModel {
    pub fn build(lexed: Lexed) -> Self {
        let Lexed { tokens, allows } = lexed;
        let test_ranges = find_test_ranges(&tokens);
        let fns = find_fns(&tokens, &test_ranges);
        SourceModel { tokens, allows, fns, test_ranges }
    }

    /// Is token index `ti` inside a `#[cfg(test)]` item or `#[test]`
    /// function?
    pub fn in_test(&self, ti: usize) -> bool {
        self.test_ranges.iter().any(|&(s, e)| ti >= s && ti < e)
    }
}

/// Index just past the `}` matching the `{` at `open`.
pub fn skip_braces(toks: &[Token], open: usize) -> usize {
    let mut depth = 0i64;
    let mut i = open;
    while i < toks.len() {
        if is_punct(&toks[i], "{") {
            depth += 1;
        } else if is_punct(&toks[i], "}") {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    toks.len()
}

/// Brace-nesting depth at every token: both braces of a block sit at
/// the *outer* depth, everything between them one deeper. Rules use
/// this to bound a `let` binding's scope (the guard-across-blocking
/// check) without re-walking.
pub fn brace_depths(toks: &[Token]) -> Vec<i64> {
    let mut out = Vec::with_capacity(toks.len());
    let mut depth = 0i64;
    for t in toks {
        if is_punct(t, "}") {
            depth -= 1;
        }
        out.push(depth);
        if is_punct(t, "{") {
            depth += 1;
        }
    }
    out
}

/// Does this attribute body (tokens between `#[` and `]`) mark test
/// code? Exactly `#[test]`, or any `cfg(test…)` — `cfg(not(test))`
/// does *not* match (the `test` ident is not directly after `cfg(`).
fn is_test_attr(attr: &[Token]) -> bool {
    if attr.len() == 1 && is_ident(&attr[0], "test") {
        return true;
    }
    attr.windows(3).any(|w| {
        is_ident(&w[0], "cfg") && is_punct(&w[1], "(") && is_ident(&w[2], "test")
    })
}

/// Token ranges of test-only items: from each test attribute through
/// the end of the item it decorates (`;` for a bare item, the matching
/// `}` for a block item).
fn find_test_ranges(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut out: Vec<(usize, usize)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(is_punct(&toks[i], "#")
            && i + 1 < toks.len()
            && is_punct(&toks[i + 1], "["))
        {
            i += 1;
            continue;
        }
        let (attr_end, attr) = read_attr(toks, i);
        if !is_test_attr(attr) {
            i = attr_end;
            continue;
        }
        // skip any further attributes stacked on the same item
        let mut k = attr_end;
        while k + 1 < toks.len()
            && is_punct(&toks[k], "#")
            && is_punct(&toks[k + 1], "[")
        {
            k = read_attr(toks, k).0;
        }
        // the item itself: runs to `;` or a balanced `{…}` block
        let mut paren = 0i64;
        let mut brack = 0i64;
        let mut end = k;
        while end < toks.len() {
            let t = &toks[end];
            if is_punct(t, "(") {
                paren += 1;
            } else if is_punct(t, ")") {
                paren -= 1;
            } else if is_punct(t, "[") {
                brack += 1;
            } else if is_punct(t, "]") {
                brack -= 1;
            } else if paren == 0 && brack == 0 {
                if is_punct(t, ";") {
                    end += 1;
                    break;
                }
                if is_punct(t, "{") {
                    end = skip_braces(toks, end);
                    break;
                }
            }
            end += 1;
        }
        out.push((i, end));
        i = end;
    }
    out
}

/// Read one `#[…]` attribute starting at the `#`; returns (index past
/// the closing `]`, body tokens).
fn read_attr(toks: &[Token], hash: usize) -> (usize, &[Token]) {
    let body_start = hash + 2;
    let mut depth = 1i64;
    let mut j = body_start;
    while j < toks.len() && depth > 0 {
        if is_punct(&toks[j], "[") {
            depth += 1;
        } else if is_punct(&toks[j], "]") {
            depth -= 1;
        }
        j += 1;
    }
    (j, &toks[body_start..j.saturating_sub(1).max(body_start)])
}

/// Every `fn name(…) … { body }` item (top-level, impl, or nested).
/// `fn(…)` pointer types (no name ident) and bodyless trait
/// declarations (`;` before `{`) are skipped.
fn find_fns(toks: &[Token], test_ranges: &[(usize, usize)]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if !is_ident(&toks[i], "fn") {
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { continue };
        if name_tok.kind != Kind::Ident {
            continue;
        }
        let mut paren = 0i64;
        let mut brack = 0i64;
        let mut j = i + 2;
        let mut body = None;
        while j < toks.len() {
            let t = &toks[j];
            if is_punct(t, "(") {
                paren += 1;
            } else if is_punct(t, ")") {
                paren -= 1;
            } else if is_punct(t, "[") {
                brack += 1;
            } else if is_punct(t, "]") {
                brack -= 1;
            } else if paren == 0 && brack == 0 {
                if is_punct(t, ";") {
                    break;
                }
                if is_punct(t, "{") {
                    body = Some((j + 1, skip_braces(toks, j).saturating_sub(1)));
                    break;
                }
            }
            j += 1;
        }
        if let Some(body) = body {
            let in_test =
                test_ranges.iter().any(|&(s, e)| i >= s && i < e);
            out.push(FnSpan {
                name: name_tok.text.clone(),
                line: toks[i].line,
                body,
                in_test,
            });
        }
    }
    out
}
