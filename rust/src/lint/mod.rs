//! In-tree invariant lint engine: the machine checker for the
//! contracts every PR note used to assert by hand.
//!
//! A hand-rolled Rust-source static-analysis pass (lexer →
//! brace-aware item/function scanner → rules, same spirit as the
//! `obs lint` exposition checker) walks `rust/src/**` and enforces the
//! project invariants as named, severity-tagged rules:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `wall-clock`     | determinism: no `Instant::now`/`SystemTime` outside the transport/util/progress allowlist |
//! | `unordered-iter` | determinism: no `HashMap`/`HashSet` in result-feeding modules |
//! | `enum-wildcard`  | no `_` arms in matches on closed enums (`DropPolicy`, `NoiseKind`, `NoiseSampler`, `DropCause`, `FaultEvent`) |
//! | `hotpath-panic`  | no `unwrap()`/`expect()` in designated steady-state functions |
//! | `hotpath-alloc`  | no `Vec::new`/`vec![]`/`collect()`/`Box::new` in those functions |
//! | `lock-across-io` | transport: no Mutex guard live across a blocking send/recv/sleep |
//!
//! Findings are suppressed inline with `// lint:allow(rule)` (same
//! line or the line above, with a `: justification` tail by
//! convention) or grandfathered via the checked-in content-addressed
//! [`Baseline`]. Diagnostics flow through [`crate::report::Table`]
//! (human) and JSON (machine) from the `dropcompute lint` subcommand;
//! `--deny` turns any active deny-severity finding into a non-zero
//! exit, which is what the CI `lint-gate` job runs. The
//! `tests/lint_rules.rs` suite pins one bad fixture per rule, clean
//! fixtures, suppression and baseline round-trips, and a self-lint of
//! this very repo.

mod baseline;
mod lexer;
mod rules;
mod scan;

use std::path::{Path, PathBuf};

use crate::util::Result;

pub use baseline::Baseline;
pub use lexer::{lex, Allow};
pub use rules::{
    known_rule, rule_info, RuleInfo, ENUM_WILDCARD, HOTPATH_ALLOC,
    HOTPATH_PANIC, LINT_USAGE, LOCK_ACROSS_IO, RULES, UNORDERED_ITER,
    WALL_CLOCK,
};
pub use scan::SourceModel;

/// How bad is a finding: `Deny` findings fail the `--deny` gate,
/// `Warn` findings (the `lint-usage` meta rule) only report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warn,
    Deny,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// Why a finding is not active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suppressed {
    /// An inline `// lint:allow(rule)` on the finding's line or the
    /// line above.
    Inline,
    /// A matching entry in the checked-in baseline file.
    Baseline,
}

/// One lint finding, pointing at a real source line.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub severity: Severity,
    pub file: String,
    pub line: u32,
    pub message: String,
    /// The trimmed source line — the baseline's content address.
    pub snippet: String,
    pub suppressed: Option<Suppressed>,
}

impl Diagnostic {
    pub fn is_active(&self) -> bool {
        self.suppressed.is_none()
    }
}

/// Lint one file's source. `rel_path` is the path under the lint root
/// with `/` separators — rules scope by it (`sim/…` vs `transport/…`).
/// Inline suppressions are applied; the baseline is applied by
/// [`lint_root`] / [`apply_baseline`].
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Diagnostic> {
    let model = SourceModel::build(lexer::lex(src));
    let mut diags = rules::run_rules(rel_path, &model);
    for a in &model.allows {
        if !rules::known_rule(&a.rule) {
            diags.push(Diagnostic {
                rule: rules::LINT_USAGE,
                severity: Severity::Warn,
                file: rel_path.to_string(),
                line: a.line,
                message: format!(
                    "unknown rule `{}` in lint:allow (known: {})",
                    a.rule,
                    rules::RULES
                        .iter()
                        .map(|r| r.key)
                        .collect::<Vec<_>>()
                        .join(", ")
                ),
                snippet: String::new(),
                suppressed: None,
            });
        }
    }
    let lines: Vec<&str> = src.lines().collect();
    for d in &mut diags {
        d.snippet = lines
            .get(d.line.saturating_sub(1) as usize)
            .map_or("", |l| l.trim())
            .to_string();
        let inline = model.allows.iter().any(|a| {
            a.rule == d.rule && (a.line == d.line || a.line + 1 == d.line)
        });
        if inline {
            d.suppressed = Some(Suppressed::Inline);
        }
    }
    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

/// Consume baseline entries against `diags`, marking matches
/// suppressed. Inline-suppressed findings never consume an entry.
pub fn apply_baseline(diags: &mut [Diagnostic], baseline: &mut Baseline) {
    for d in diags.iter_mut() {
        if d.suppressed.is_none()
            && baseline.take(d.rule, &d.file, &d.snippet)
        {
            d.suppressed = Some(Suppressed::Baseline);
        }
    }
}

/// The whole-tree report [`lint_root`] produces.
#[derive(Debug)]
pub struct LintReport {
    pub root: String,
    pub files_scanned: usize,
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    pub fn active(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.is_active())
    }

    pub fn active_deny(&self) -> usize {
        self.active().filter(|d| d.severity == Severity::Deny).count()
    }

    pub fn active_warn(&self) -> usize {
        self.active().filter(|d| d.severity == Severity::Warn).count()
    }

    pub fn suppressed(&self, by: Suppressed) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.suppressed == Some(by))
            .count()
    }

    /// Machine-readable report (the CI `lint-gate` artifact).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"root\": \"{}\",\n", esc(&self.root)));
        s.push_str(&format!(
            "  \"files_scanned\": {},\n",
            self.files_scanned
        ));
        s.push_str(&format!(
            "  \"summary\": {{\"active\": {}, \"deny\": {}, \"warn\": {}, \
             \"suppressed_inline\": {}, \"suppressed_baseline\": {}}},\n",
            self.active().count(),
            self.active_deny(),
            self.active_warn(),
            self.suppressed(Suppressed::Inline),
            self.suppressed(Suppressed::Baseline),
        ));
        s.push_str("  \"diagnostics\": [\n");
        for (i, d) in self.diagnostics.iter().enumerate() {
            let suppressed = match d.suppressed {
                None => "null".to_string(),
                Some(Suppressed::Inline) => "\"inline\"".to_string(),
                Some(Suppressed::Baseline) => "\"baseline\"".to_string(),
            };
            s.push_str(&format!(
                "    {{\"rule\": \"{}\", \"severity\": \"{}\", \
                 \"file\": \"{}\", \"line\": {}, \"message\": \"{}\", \
                 \"snippet\": \"{}\", \"suppressed\": {}}}{}\n",
                esc(d.rule),
                d.severity.name(),
                esc(&d.file),
                d.line,
                esc(&d.message),
                esc(&d.snippet),
                suppressed,
                if i + 1 < self.diagnostics.len() { "," } else { "" },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Lint every `.rs` file under `root` (sorted walk — deterministic
/// report order), consuming `baseline`; leftover entries surface as
/// warn-level stale-baseline diagnostics.
pub fn lint_root(root: &Path, mut baseline: Baseline) -> Result<LintReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, Path::new(""), &mut files)?;
    files.sort();
    let mut diagnostics = Vec::new();
    for rel in &files {
        let src = std::fs::read_to_string(root.join(rel))?;
        let rel_s = rel.to_string_lossy().replace('\\', "/");
        let mut diags = lint_source(&rel_s, &src);
        apply_baseline(&mut diags, &mut baseline);
        diagnostics.extend(diags);
    }
    for (rule, file, snippet) in baseline.stale() {
        diagnostics.push(Diagnostic {
            rule: rules::LINT_USAGE,
            severity: Severity::Warn,
            file,
            line: 0,
            message: format!(
                "stale baseline entry for rule `{rule}` no longer \
                 matches any finding — delete it: `{snippet}`"
            ),
            snippet,
            suppressed: None,
        });
    }
    Ok(LintReport {
        root: root.to_string_lossy().into_owned(),
        files_scanned: files.len(),
        diagnostics,
    })
}

fn collect_rs(
    root: &Path,
    rel: &Path,
    out: &mut Vec<PathBuf>,
) -> Result<()> {
    for entry in std::fs::read_dir(root.join(rel))? {
        let entry = entry?;
        let name = entry.file_name();
        let child = rel.join(&name);
        let ty = entry.file_type()?;
        if ty.is_dir() {
            collect_rs(root, &child, out)?;
        } else if name.to_string_lossy().ends_with(".rs") {
            out.push(child);
        }
    }
    Ok(())
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}
