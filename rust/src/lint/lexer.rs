//! Minimal Rust-source tokenizer for the invariant lint pass.
//!
//! Just enough lexing to make the rules sound: comments (line + nested
//! block) are stripped — so prose mentioning `HashMap` or `Instant`
//! never trips a rule — while `// lint:allow(rule)` markers inside
//! them are captured as [`Allow`] suppressions; string literals
//! (escaped, raw `r#"…"#`, byte, byte-raw) and char literals collapse
//! to opaque [`Kind::Literal`] tokens; the `'a`-vs-`'a'`
//! lifetime/char-literal ambiguity is disambiguated by the closing
//! quote. Identifiers keep their text (rules match on names), numbers
//! keep theirs (match-arm patterns like `0 =>` are inspected), and the
//! three multi-char puncts the scanner cares about (`::`, `=>`, `->`)
//! are fused. Every token carries its 1-based source line so
//! diagnostics point at real code.

/// Token class. Keywords are plain [`Kind::Ident`]s — the scanner
/// recognizes `fn` / `match` / `let` by text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Literal,
    Lifetime,
}

/// One lexical token with its 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

/// An inline `// lint:allow(rule)` suppression captured from a line
/// comment. One [`Allow`] per rule named in the parenthesized,
/// comma-separated list; anything after the closing paren (e.g. a
/// `: justification` tail) is free-form commentary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    pub rule: String,
    pub line: u32,
}

/// Output of [`lex`]: the token stream plus the suppression markers.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub allows: Vec<Allow>,
}

pub fn is_ident(t: &Token, s: &str) -> bool {
    t.kind == Kind::Ident && t.text == s
}

pub fn is_punct(t: &Token, s: &str) -> bool {
    t.kind == Kind::Punct && t.text == s
}

fn ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn ident_char(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Tokenize `src`. Never fails: unrecognized bytes become single-char
/// puncts, unterminated literals run to end-of-file — a lint pass must
/// degrade gracefully on code it half-understands, not refuse to scan.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        // line comment: capture lint:allow markers — but not from
        // `///` / `//!` doc comments, which are prose *about* the
        // suppression syntax, not suppressions
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            let text = &src[start..i];
            let is_doc = text.starts_with("///") || text.starts_with("//!");
            if !is_doc {
                scan_allows(text, line, &mut out.allows);
            }
            continue;
        }
        // block comment, nesting like rustc
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            i += 2;
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if c == b'"' {
            let l = line;
            i = skip_string(b, i, &mut line);
            out.tokens.push(Token { kind: Kind::Literal, text: String::new(), line: l });
            continue;
        }
        // raw / byte string forms: r"…", r#"…"#, b"…", br#"…"#, b'…'
        if (c == b'r' || c == b'b') && i + 1 < n {
            let l = line;
            if let Some(next) = raw_or_byte_end(b, i, &mut line) {
                out.tokens.push(Token {
                    kind: Kind::Literal,
                    text: String::new(),
                    line: l,
                });
                i = next;
                continue;
            }
        }
        if c == b'\'' {
            let l = line;
            if let Some(next) = char_literal_end(b, i) {
                out.tokens.push(Token { kind: Kind::Literal, text: String::new(), line: l });
                i = next;
            } else {
                // lifetime / loop label: consume the ident run
                let mut j = i + 1;
                while j < n && ident_char(b[j]) {
                    j += 1;
                }
                out.tokens.push(Token { kind: Kind::Lifetime, text: String::new(), line: l });
                i = j;
            }
            continue;
        }
        if ident_start(c) {
            let start = i;
            while i < n && ident_char(b[i]) {
                i += 1;
            }
            out.tokens.push(Token {
                kind: Kind::Ident,
                text: src[start..i].to_string(),
                line,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                if ident_char(b[i]) {
                    i += 1;
                } else if b[i] == b'.'
                    && i + 1 < n
                    && b[i + 1].is_ascii_digit()
                {
                    // `1.5` continues the number; `0..3` and `1.max(…)`
                    // stop before the dot
                    i += 1;
                } else {
                    break;
                }
            }
            out.tokens.push(Token {
                kind: Kind::Literal,
                text: src[start..i].to_string(),
                line,
            });
            continue;
        }
        // punct: fuse the multi-char forms the scanner dispatches on
        let two = if i + 1 < n { &src[i..i + 2] } else { "" };
        if two == "::" || two == "=>" || two == "->" {
            out.tokens.push(Token { kind: Kind::Punct, text: two.to_string(), line });
            i += 2;
        } else {
            out.tokens.push(Token {
                kind: Kind::Punct,
                text: src[i..i + 1].to_string(),
                line,
            });
            i += 1;
        }
    }
    out
}

/// Skip a `"…"` string starting at the opening quote; returns the index
/// past the closing quote and counts embedded newlines.
fn skip_string(b: &[u8], start: usize, line: &mut u32) -> usize {
    let mut i = start + 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                // an escaped newline (line-continuation) still ends a
                // source line — count it or every later diagnostic in
                // the file points one line short
                if b.get(i + 1) == Some(&b'\n') {
                    *line += 1;
                }
                i += 2;
            }
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// If position `i` (at `r` or `b`) starts a raw/byte string or byte
/// char literal, skip it and return the index past its end. `None`
/// means this is an ordinary identifier like `rank` or `bytes`.
fn raw_or_byte_end(b: &[u8], i: usize, line: &mut u32) -> Option<usize> {
    let n = b.len();
    let (raw_from, is_byte) = if b[i] == b'r' {
        (i + 1, false)
    } else {
        // b"…" / b'…' / br#"…"#
        match b.get(i + 1) {
            Some(b'"') => return Some(skip_string(b, i + 1, line)),
            Some(b'\'') => return char_literal_end(b, i + 1),
            Some(b'r') => (i + 2, true),
            _ => return None,
        }
    };
    let _ = is_byte;
    let mut hashes = 0usize;
    let mut j = raw_from;
    while j < n && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || b[j] != b'"' {
        return None;
    }
    // raw string body: no escapes; ends at `"` + `hashes` hashes
    j += 1;
    while j < n {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && seen < hashes && b[k] == b'#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return Some(k);
            }
        }
        j += 1;
    }
    Some(j)
}

/// If position `i` (at `'`) starts a char literal, return the index
/// past its closing quote; `None` means it is a lifetime/label.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    let n = b.len();
    let next = *b.get(i + 1)?;
    if next == b'\\' {
        // escaped char: scan to the closing quote (covers \n, \u{…})
        let mut j = i + 3;
        while j < n && b[j] != b'\'' {
            j += 1;
        }
        return Some((j + 1).min(n));
    }
    if ident_char(next) {
        // `'a'` is a char literal, `'a` (no closing quote after the
        // ident run) is a lifetime
        let mut j = i + 1;
        while j < n && ident_char(b[j]) {
            j += 1;
        }
        if j < n && b[j] == b'\'' {
            return Some(j + 1);
        }
        return None;
    }
    // punctuation / space / non-ascii char literal like '(' or 'é'
    let mut j = i + 1;
    while j < n && b[j] != b'\'' && b[j] != b'\n' {
        j += 1;
    }
    if j < n && b[j] == b'\'' {
        return Some(j + 1);
    }
    None
}

/// Collect every `lint:allow(rule[, rule])` marker in a line comment.
fn scan_allows(comment: &str, line: u32, out: &mut Vec<Allow>) {
    let mut rest = comment;
    while let Some(at) = rest.find("lint:allow(") {
        rest = &rest[at + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else { return };
        for rule in rest[..close].split(',') {
            let rule = rule.trim();
            if !rule.is_empty() {
                out.push(Allow { rule: rule.to_string(), line });
            }
        }
        rest = &rest[close + 1..];
    }
}
