//! The [`SimObserver`] hook set: the zero-cost seam between
//! [`crate::sim::ClusterSim`]'s hot step path and any instrumentation.
//!
//! The sim's step methods are generic over `O: SimObserver` and the
//! default method bodies are empty `#[inline]` fns, so the
//! [`NoopObserver`] monomorphization compiles to exactly the
//! un-instrumented code — disabled runs are bitwise and perf-identical
//! (held by the `obs_overhead` bench pair and the equivalence tests in
//! `tests/obs_equivalence.rs`). Observers only *read*: no hook receives
//! mutable sim state, so an attached observer can never perturb a run.
//!
//! Hook order within one step:
//! 1. [`on_worker`](SimObserver::on_worker) once per worker, in worker
//!    order, as compute draws finish (plus a
//!    [`DropCause::Tau`] `on_drop` right after a worker that dropped
//!    micro-batches locally);
//! 2. [`on_phase`](SimObserver::on_phase) once per collective phase on
//!    the compiled full-cluster path, with the raw post-phase readiness
//!    slice (the observer computes its own fold so the noop closure
//!    does literally nothing);
//! 3. [`on_drop`](SimObserver::on_drop) for every comm-side exclusion
//!    (step deadline, per-phase checkpoint, survivor restart);
//! 4. [`on_step`](SimObserver::on_step) once with the finished
//!    [`StepOutcome`].

use crate::sim::StepOutcome;

/// Why a worker lost work this step. `Tau` is a *local* drop (the
/// worker stays in the collective with fewer micro-batches); the other
/// three are *comm* drops (the worker's whole contribution is excluded
/// from the reduction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropCause {
    /// Compute-threshold τ drop: the worker abandoned `microbatches`
    /// of its scheduled accumulation (or local-SGD steps) locally.
    Tau { microbatches: usize },
    /// The worker missed the whole-step DropComm deadline.
    StepDeadline,
    /// The worker was dropped at a per-phase budget checkpoint.
    /// `checkpoint` is the *closing* checkpoint of the bounded scan —
    /// when one scan merges drops from several checkpoints the last
    /// (triggering) one is reported. The event-queue oracle path only
    /// produces a merged drop mask, so it reports `checkpoint: 0`;
    /// exact indices come from the compiled path.
    PhaseCheckpoint { checkpoint: usize },
    /// The worker survived the initial cut but was dropped in a
    /// recursive survivor-restart round at `checkpoint`.
    SurvivorRestart { checkpoint: usize },
    /// The worker is dead this step under the installed
    /// [`crate::sim::FaultPlan`] (failed and not yet rejoined): it
    /// computed nothing and the collective ran over the survivors.
    WorkerFault,
}

impl DropCause {
    /// Stable label used by the exporters (`cause="..."`).
    pub fn label(&self) -> &'static str {
        match self {
            DropCause::Tau { .. } => "tau",
            DropCause::StepDeadline => "step_deadline",
            DropCause::PhaseCheckpoint { .. } => "phase_checkpoint",
            DropCause::SurvivorRestart { .. } => "survivor_restart",
            DropCause::WorkerFault => "worker_fault",
        }
    }

    /// Whether this cause excludes the worker from the collective
    /// (vs. a local τ trim).
    pub fn is_comm(&self) -> bool {
        !matches!(self, DropCause::Tau { .. })
    }
}

/// Per-step event hooks. All methods default to empty `#[inline]`
/// bodies — implement only what you need; [`NoopObserver`] implements
/// nothing and costs nothing.
pub trait SimObserver {
    /// Worker `worker` finished its compute with total draw `compute`
    /// seconds and `completed` surviving micro-batches (pre-comm).
    #[inline]
    fn on_worker(&mut self, _worker: usize, _compute: f64, _completed: usize) {}

    /// Collective phase `phase` completed; `ready` is the raw
    /// per-position readiness slice after the phase (compiled
    /// full-cluster path only).
    #[inline]
    fn on_phase(&mut self, _phase: usize, _ready: &[f64]) {}

    /// Worker `worker` lost work for `cause`.
    #[inline]
    fn on_drop(&mut self, _worker: usize, _cause: DropCause) {}

    /// The step finished; `outcome` is final (post-comm zeroing).
    #[inline]
    fn on_step(&mut self, _outcome: &StepOutcome) {}
}

/// The default do-nothing observer: every un-instrumented entry point
/// delegates to the observed one with `&mut NoopObserver`, and the
/// empty inline hooks vanish at codegen.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopObserver;

impl SimObserver for NoopObserver {}

/// `&mut O` forwards, so observed methods can be called with a
/// reborrowed observer without consuming it.
impl<O: SimObserver + ?Sized> SimObserver for &mut O {
    #[inline]
    fn on_worker(&mut self, worker: usize, compute: f64, completed: usize) {
        (**self).on_worker(worker, compute, completed);
    }

    #[inline]
    fn on_phase(&mut self, phase: usize, ready: &[f64]) {
        (**self).on_phase(phase, ready);
    }

    #[inline]
    fn on_drop(&mut self, worker: usize, cause: DropCause) {
        (**self).on_drop(worker, cause);
    }

    #[inline]
    fn on_step(&mut self, outcome: &StepOutcome) {
        (**self).on_step(outcome);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cause_labels_and_kind() {
        assert_eq!(DropCause::Tau { microbatches: 2 }.label(), "tau");
        assert_eq!(DropCause::StepDeadline.label(), "step_deadline");
        assert_eq!(
            DropCause::PhaseCheckpoint { checkpoint: 1 }.label(),
            "phase_checkpoint"
        );
        assert_eq!(
            DropCause::SurvivorRestart { checkpoint: 0 }.label(),
            "survivor_restart"
        );
        assert!(!DropCause::Tau { microbatches: 1 }.is_comm());
        assert!(DropCause::StepDeadline.is_comm());
        assert!(DropCause::PhaseCheckpoint { checkpoint: 0 }.is_comm());
        assert!(DropCause::SurvivorRestart { checkpoint: 3 }.is_comm());
        assert_eq!(DropCause::WorkerFault.label(), "worker_fault");
        assert!(DropCause::WorkerFault.is_comm());
    }
}
