//! Leveled logging shim: one switchboard for every diagnostic line the
//! crate prints.
//!
//! All human-facing chatter (progress/ETA, `info!`/`debug!`/`warn!`
//! macros, report tables) routes through here so the `--quiet`/`-v`
//! flags have a single authority — and so stdout stays reserved for
//! machine-readable output (JSON, Prometheus text) while diagnostics go
//! to stderr. The legacy [`crate::util::set_verbosity`] numeric scale
//! (0 = quiet, 1 = info, 2 = debug) is a thin shim over [`Level`].

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first. A message prints when its level is
/// `<=` the configured [`level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// The `[tag]` prefix printed before the message.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Process-wide log level. Default: [`Level::Info`].
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Whether a message at `l` would print.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Resolve the CLI flags into a level: `--quiet` wins (errors only),
/// `-v`/`--verbose` raises to debug, default is info.
pub fn set_from_flags(quiet: bool, verbose: bool) {
    set_level(if quiet {
        Level::Error
    } else if verbose {
        Level::Debug
    } else {
        Level::Info
    });
}

/// Print one leveled line to stderr (no-op when the level is disabled).
/// Formatting is lazy: `format_args!` defers all rendering to the
/// write, so a disabled call costs one atomic load.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{}] {args}", l.tag());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_flags() {
        // NOTE: LEVEL is process-global; restore the default at the end
        // so parallel tests relying on Info keep passing.
        set_from_flags(true, false);
        assert_eq!(level(), Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_from_flags(false, true);
        assert_eq!(level(), Level::Debug);
        assert!(enabled(Level::Info));
        set_from_flags(true, true);
        assert_eq!(level(), Level::Error, "--quiet wins over -v");
        set_from_flags(false, false);
        assert_eq!(level(), Level::Info);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn tags_are_stable() {
        assert_eq!(Level::Error.tag(), "error");
        assert_eq!(Level::Warn.tag(), "warn");
        assert_eq!(Level::Info.tag(), "info");
        assert_eq!(Level::Debug.tag(), "debug");
    }
}
