//! [`ObsRecorder`]: the standard [`SimObserver`] implementation —
//! streaming histograms, per-worker straggler attribution, and typed
//! drop totals, all in preallocated buffers (no allocation per step
//! once the worker count is seen).

use crate::sim::StepOutcome;

use super::hist::LogHistogram;
use super::observer::{DropCause, SimObserver};

/// Per-worker straggler-attribution row — the operational form of the
/// paper's compute-variance analysis: who is slow, who pays for it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Steps this worker participated in.
    pub steps: u64,
    /// Steps where this worker had the maximum compute draw (ties go
    /// to the lowest index).
    pub was_max: u64,
    /// Steps where this worker was excluded from the collective
    /// (step deadline / phase checkpoint / survivor restart).
    pub dropped: u64,
    /// Micro-batches (or local-SGD steps) this worker abandoned to the
    /// compute threshold τ.
    pub tau_microbatches: u64,
    /// Steps where this worker was the latest arrival among those
    /// excluded — the straggler that most motivated the drop.
    pub triggered_checkpoint: u64,
}

/// Totals per typed drop cause, plus the micro-batch bookkeeping that
/// lets attribution be cross-checked against [`StepOutcome`] counts:
/// `scheduled - completed == tau_microbatches + comm_lost_microbatches`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DropTotals {
    /// τ drop events (one per worker-step that trimmed work locally).
    pub tau_events: u64,
    /// Micro-batches trimmed by τ across all workers and steps.
    pub tau_microbatches: u64,
    /// Worker-steps excluded by the whole-step DropComm deadline.
    pub step_deadline: u64,
    /// Worker-steps excluded at a per-phase budget checkpoint.
    pub phase_checkpoint: u64,
    /// Worker-steps excluded in a recursive survivor-restart round.
    pub survivor_restart: u64,
    /// Worker-steps lost to an injected fault (dead under the
    /// installed [`crate::sim::FaultPlan`]).
    pub worker_fault: u64,
    /// Micro-batches computed but lost to comm-side exclusion.
    pub comm_lost_microbatches: u64,
}

impl DropTotals {
    /// Comm-side exclusion events (worker-steps), all causes.
    pub fn comm_events(&self) -> u64 {
        self.step_deadline
            + self.phase_checkpoint
            + self.survivor_restart
            + self.worker_fault
    }
}

/// Socket-transport counters (see [`crate::transport`]): retries,
/// typed failures, degradation events, and wait-time histograms.
/// All-zero (and absent from exports) unless a real-transport run fed
/// the recorder. Merging is element-wise; fold per-rank stats in rank
/// order for a deterministic run total.
#[derive(Debug, Clone, Default)]
pub struct TransportStats {
    /// Connect attempts that failed and were retried with backoff.
    pub connect_retries: u64,
    /// Send attempts that failed transiently and were retried.
    pub send_retries: u64,
    /// Receives that expired their deadline (typed `Timeout`).
    pub recv_timeouts: u64,
    /// Typed `PeerLost` observations (EOF/reset/retry-exhaustion).
    pub peers_lost: u64,
    /// Steps where some worker degraded after membership agreement.
    pub degraded_steps: u64,
    /// Worker-steps excluded by the membership deadline.
    pub excluded_arrivals: u64,
    /// Frames successfully written to peers.
    pub frames_sent: u64,
    /// Bytes successfully written to peers (headers + payloads).
    pub bytes_sent: u64,
    /// Backoff sleeps taken (seconds).
    pub backoff_wait: LogHistogram,
    /// Time spent blocked in receives (seconds).
    pub recv_wait: LogHistogram,
}

impl TransportStats {
    /// Did any transport activity happen? Gates export emission so
    /// sim-only snapshots are byte-identical to pre-transport ones.
    pub fn used(&self) -> bool {
        self.connect_retries != 0
            || self.send_retries != 0
            || self.recv_timeouts != 0
            || self.peers_lost != 0
            || self.degraded_steps != 0
            || self.excluded_arrivals != 0
            || self.frames_sent != 0
            || self.bytes_sent != 0
            || self.backoff_wait.count() != 0
            || self.recv_wait.count() != 0
    }

    pub fn merge(&mut self, other: &TransportStats) {
        self.connect_retries += other.connect_retries;
        self.send_retries += other.send_retries;
        self.recv_timeouts += other.recv_timeouts;
        self.peers_lost += other.peers_lost;
        self.degraded_steps += other.degraded_steps;
        self.excluded_arrivals += other.excluded_arrivals;
        self.frames_sent += other.frames_sent;
        self.bytes_sent += other.bytes_sent;
        self.backoff_wait.merge(&other.backoff_wait);
        self.recv_wait.merge(&other.recv_wait);
    }
}

/// Streaming per-phase completion-time stats (compiled full-cluster
/// collective path).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseStat {
    pub count: u64,
    pub sum: f64,
    pub max: f64,
}

impl PhaseStat {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }
}

/// The standard recorder. Worker-indexed tables grow on first sight of
/// a worker index and never per step thereafter; the per-step scratch
/// (`step_completed`, `step_drops`) is reused across steps.
///
/// Merging ([`merge`](Self::merge)) is element-wise and deterministic:
/// fold per-shard recorders in a fixed order and the result is bitwise
/// independent of how work was parallelized (see
/// [`super::hist`] module docs for the f64-sum argument).
#[derive(Debug, Clone, Default)]
pub struct ObsRecorder {
    /// Steps observed ([`on_step`](SimObserver::on_step) calls).
    pub steps: u64,
    /// Full iteration times (compute + collective).
    pub iter_time: LogHistogram,
    /// Per-worker compute draws (one sample per worker per step).
    pub compute_time: LogHistogram,
    /// Arrival offsets: each worker's compute draw minus the step's
    /// fastest draw (one sample per worker per step; the fastest
    /// contributes 0).
    pub arrival_offset: LogHistogram,
    /// Per-phase completion stats, indexed by phase.
    pub phases: Vec<PhaseStat>,
    /// Straggler-attribution table, indexed by worker.
    pub workers: Vec<WorkerStats>,
    /// Typed drop totals.
    pub drops: DropTotals,
    /// Micro-batches scheduled (pre-τ): Σ completed-pre + τ shortfall.
    pub scheduled_microbatches: u64,
    /// Micro-batches that made it into the reduction (post-comm).
    pub completed_microbatches: u64,
    /// Real-transport counters (all-zero for sim-only runs).
    pub transport: TransportStats,

    // --- per-step scratch, cleared/overwritten each step ---
    /// Pre-comm completed counts buffered from `on_worker`, so comm
    /// drops know how many micro-batches each exclusion cost.
    step_completed: Vec<usize>,
    /// Comm-side drops seen this step (for triggered-checkpoint
    /// attribution, which needs the step's compute draws).
    step_drops: Vec<usize>,
}

impl ObsRecorder {
    /// `workers` presizes the per-worker tables (0 is fine — they grow
    /// on first use).
    pub fn new(workers: usize) -> Self {
        let mut r = Self::default();
        if workers > 0 {
            r.ensure_worker(workers - 1);
        }
        r
    }

    fn ensure_worker(&mut self, worker: usize) {
        if self.workers.len() <= worker {
            self.workers.resize(worker + 1, WorkerStats::default());
            self.step_completed.resize(worker + 1, 0);
        }
    }

    /// Element-wise merge of another recorder (index order matters for
    /// bitwise f64 sums; counts are order-independent).
    pub fn merge(&mut self, other: &ObsRecorder) {
        self.steps += other.steps;
        self.iter_time.merge(&other.iter_time);
        self.compute_time.merge(&other.compute_time);
        self.arrival_offset.merge(&other.arrival_offset);
        if self.phases.len() < other.phases.len() {
            self.phases.resize(other.phases.len(), PhaseStat::default());
        }
        for (a, b) in self.phases.iter_mut().zip(&other.phases) {
            a.count += b.count;
            a.sum += b.sum;
            if b.max > a.max {
                a.max = b.max;
            }
        }
        if !other.workers.is_empty() {
            self.ensure_worker(other.workers.len() - 1);
        }
        for (a, b) in self.workers.iter_mut().zip(&other.workers) {
            a.steps += b.steps;
            a.was_max += b.was_max;
            a.dropped += b.dropped;
            a.tau_microbatches += b.tau_microbatches;
            a.triggered_checkpoint += b.triggered_checkpoint;
        }
        self.drops.tau_events += other.drops.tau_events;
        self.drops.tau_microbatches += other.drops.tau_microbatches;
        self.drops.step_deadline += other.drops.step_deadline;
        self.drops.phase_checkpoint += other.drops.phase_checkpoint;
        self.drops.survivor_restart += other.drops.survivor_restart;
        self.drops.worker_fault += other.drops.worker_fault;
        self.drops.comm_lost_microbatches += other.drops.comm_lost_microbatches;
        self.scheduled_microbatches += other.scheduled_microbatches;
        self.completed_microbatches += other.completed_microbatches;
        self.transport.merge(&other.transport);
    }

    /// The attribution cross-check the tests hold: every scheduled
    /// micro-batch is either completed, τ-trimmed, or comm-lost.
    pub fn microbatches_balance(&self) -> bool {
        self.scheduled_microbatches
            == self.completed_microbatches
                + self.drops.tau_microbatches
                + self.drops.comm_lost_microbatches
    }
}

impl SimObserver for ObsRecorder {
    fn on_worker(&mut self, worker: usize, compute: f64, completed: usize) {
        self.ensure_worker(worker);
        self.step_completed[worker] = completed;
        self.workers[worker].steps += 1;
        self.scheduled_microbatches += completed as u64;
    }

    fn on_phase(&mut self, phase: usize, ready: &[f64]) {
        if self.phases.len() <= phase {
            self.phases.resize(phase + 1, PhaseStat::default());
        }
        let t = ready.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let stat = &mut self.phases[phase];
        stat.count += 1;
        stat.sum += t;
        if t > stat.max {
            stat.max = t;
        }
    }

    fn on_drop(&mut self, worker: usize, cause: DropCause) {
        self.ensure_worker(worker);
        match cause {
            DropCause::Tau { microbatches } => {
                self.drops.tau_events += 1;
                self.drops.tau_microbatches += microbatches as u64;
                self.workers[worker].tau_microbatches += microbatches as u64;
                // on_worker already counted the surviving micro-batches
                // into `scheduled`; add back the trimmed ones.
                self.scheduled_microbatches += microbatches as u64;
            }
            comm => {
                match comm {
                    DropCause::StepDeadline => self.drops.step_deadline += 1,
                    DropCause::PhaseCheckpoint { .. } => {
                        self.drops.phase_checkpoint += 1
                    }
                    DropCause::SurvivorRestart { .. } => {
                        self.drops.survivor_restart += 1
                    }
                    DropCause::WorkerFault => self.drops.worker_fault += 1,
                    DropCause::Tau { .. } => unreachable!(),
                }
                self.workers[worker].dropped += 1;
                self.drops.comm_lost_microbatches +=
                    self.step_completed[worker] as u64;
                self.step_drops.push(worker);
            }
        }
    }

    fn on_step(&mut self, outcome: &StepOutcome) {
        self.steps += 1;
        self.iter_time.record(outcome.iter_time);
        self.completed_microbatches += outcome.total_completed() as u64;
        if !outcome.worker_compute.is_empty() {
            let min = outcome
                .worker_compute
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            let mut argmax = 0usize;
            let mut max = f64::NEG_INFINITY;
            for (w, &c) in outcome.worker_compute.iter().enumerate() {
                self.compute_time.record(c);
                self.arrival_offset.record(c - min);
                if c > max {
                    max = c;
                    argmax = w;
                }
            }
            self.ensure_worker(outcome.worker_compute.len() - 1);
            self.workers[argmax].was_max += 1;
            // Triggered-checkpoint attribution: the latest arrival
            // among the step's excluded workers (first pushed wins
            // ties) is charged with having triggered the cut.
            if !self.step_drops.is_empty() {
                let mut trig = self.step_drops[0];
                let mut trig_c = outcome.worker_compute[trig];
                for &w in &self.step_drops[1..] {
                    let c = outcome.worker_compute[w];
                    if c > trig_c {
                        trig_c = c;
                        trig = w;
                    }
                }
                self.workers[trig].triggered_checkpoint += 1;
            }
        }
        self.step_drops.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(compute: &[f64], completed: &[usize], iter: f64) -> StepOutcome {
        StepOutcome {
            worker_compute: compute.to_vec(),
            completed: completed.to_vec(),
            compute_time: compute.iter().cloned().fold(0.0, f64::max),
            iter_time: iter,
        }
    }

    #[test]
    fn attribution_and_balance_over_synthetic_steps() {
        let mut r = ObsRecorder::new(3);
        // Step 1: worker 2 straggles and τ-trims one micro-batch.
        for (w, (&c, &d)) in [0.8, 0.9, 1.5].iter().zip(&[4usize, 4, 3]).enumerate()
        {
            r.on_worker(w, c, d);
        }
        r.on_drop(2, DropCause::Tau { microbatches: 1 });
        r.on_step(&outcome(&[0.8, 0.9, 1.5], &[4, 4, 3], 1.7));
        // Step 2: worker 1 straggles and misses the step deadline.
        for (w, (&c, &d)) in [0.7, 2.0, 0.9].iter().zip(&[4usize, 4, 4]).enumerate()
        {
            r.on_worker(w, c, d);
        }
        r.on_drop(1, DropCause::StepDeadline);
        r.on_step(&outcome(&[0.7, 2.0, 0.9], &[4, 0, 4], 1.1));

        assert_eq!(r.steps, 2);
        assert_eq!(r.workers[2].was_max, 1);
        assert_eq!(r.workers[1].was_max, 1);
        assert_eq!(r.workers[0].was_max, 0);
        assert_eq!(r.workers[2].tau_microbatches, 1);
        assert_eq!(r.workers[1].dropped, 1);
        assert_eq!(r.workers[1].triggered_checkpoint, 1);
        assert_eq!(r.drops.tau_events, 1);
        assert_eq!(r.drops.tau_microbatches, 1);
        assert_eq!(r.drops.step_deadline, 1);
        assert_eq!(r.drops.comm_lost_microbatches, 4);
        // scheduled = 2 steps × 3 workers × 4 micro-batches
        assert_eq!(r.scheduled_microbatches, 24);
        assert_eq!(r.completed_microbatches, 11 + 8);
        assert!(r.microbatches_balance());
        // iter/compute/offset histograms saw 2, 6, 6 samples.
        assert_eq!(r.iter_time.count(), 2);
        assert_eq!(r.compute_time.count(), 6);
        assert_eq!(r.arrival_offset.count(), 6);
        // Fastest worker's offset is exactly 0 → bucket 0 occupied.
        assert!(r.arrival_offset.bucket_count(0) >= 2);
    }

    #[test]
    fn worker_fault_steps_keep_the_balance_invariant() {
        let mut r = ObsRecorder::new(3);
        // Worker 1 is dead this step: it computed nothing, so the
        // fault exclusion must charge zero comm-lost micro-batches.
        r.on_worker(0, 0.8, 4);
        r.on_worker(1, 0.0, 0);
        r.on_worker(2, 0.9, 4);
        r.on_drop(1, DropCause::WorkerFault);
        r.on_step(&outcome(&[0.8, 0.0, 0.9], &[4, 0, 4], 1.2));
        assert_eq!(r.drops.worker_fault, 1);
        assert_eq!(r.drops.comm_events(), 1);
        assert_eq!(r.drops.comm_lost_microbatches, 0);
        assert_eq!(r.workers[1].dropped, 1);
        assert_eq!(r.scheduled_microbatches, 8);
        assert_eq!(r.completed_microbatches, 8);
        assert!(r.microbatches_balance());
    }

    #[test]
    fn merge_matches_one_recorder_fed_serially() {
        let step = |r: &mut ObsRecorder, base: f64| {
            r.on_worker(0, base, 2);
            r.on_worker(1, base * 2.0, 2);
            r.on_drop(1, DropCause::PhaseCheckpoint { checkpoint: 1 });
            r.on_step(&outcome(&[base, base * 2.0], &[2, 0], base * 2.5));
        };
        let mut serial = ObsRecorder::new(2);
        step(&mut serial, 0.5);
        step(&mut serial, 0.7);
        let mut a = ObsRecorder::new(2);
        step(&mut a, 0.5);
        let mut b = ObsRecorder::new(2);
        step(&mut b, 0.7);
        let mut merged = ObsRecorder::new(2);
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.steps, serial.steps);
        assert_eq!(merged.workers, serial.workers);
        assert_eq!(merged.drops, serial.drops);
        assert_eq!(
            merged.iter_time.sum().to_bits(),
            serial.iter_time.sum().to_bits()
        );
        assert_eq!(
            merged.arrival_offset.percentile(0.99).to_bits(),
            serial.arrival_offset.percentile(0.99).to_bits()
        );
        assert!(merged.microbatches_balance());
    }

    #[test]
    fn phase_stats_fold_from_raw_readiness() {
        let mut r = ObsRecorder::new(0);
        r.on_phase(0, &[0.1, 0.4, 0.2]);
        r.on_phase(1, &[0.5, 0.6, 0.55]);
        r.on_phase(0, &[0.2, 0.3, 0.1]);
        assert_eq!(r.phases.len(), 2);
        assert_eq!(r.phases[0].count, 2);
        assert_eq!(r.phases[0].max, 0.4);
        assert!((r.phases[0].mean() - 0.35).abs() < 1e-12);
        assert_eq!(r.phases[1].count, 1);
    }
}
