//! Exporters for [`ObsRecorder`]: Prometheus text exposition format
//! and a JSON snapshot — plus an in-tree exposition-format linter the
//! CI smoke job runs against our own output.
//!
//! Metric families (all prefixed `dropcompute_`):
//!
//! * `dropcompute_steps_total` — counter, steps observed;
//! * `dropcompute_drops_total{cause=...}` — counter per typed cause
//!   (`tau` counts events; `tau_microbatches` /
//!   `comm_lost_microbatches` count micro-batches);
//! * `dropcompute_{iter_time,compute_time,arrival_offset}_seconds` —
//!   histograms (sparse cumulative buckets + `+Inf`, `_sum`, `_count`)
//!   with companion `*_quantile_seconds{q=...}` gauges for
//!   p50/p90/p99/p99.9;
//! * `dropcompute_phase_time_seconds{phase=...,stat=...}` — gauge,
//!   per-collective-phase mean/max completion time;
//! * `dropcompute_worker_*_total{worker=...}` — the per-worker
//!   straggler-attribution table.

use std::fmt::Write as _;

use super::hist::{bucket_hi, LogHistogram};
use super::recorder::ObsRecorder;

const QUANTILES: [(f64, &str); 4] =
    [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (0.999, "0.999")];

/// Format an f64 the exposition format accepts (finite shortest-ish
/// decimal, or +Inf/-Inf/NaN).
fn prom_num(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        format!("{v}")
    }
}

fn prom_histogram(out: &mut String, name: &str, help: &str, h: &LogHistogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, c) in h.nonzero_buckets() {
        cum += c;
        let le = bucket_hi(i);
        if le.is_finite() {
            let _ = writeln!(
                out,
                "{name}_bucket{{le=\"{}\"}} {cum}",
                prom_num(le)
            );
        }
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", prom_num(h.sum()));
    let _ = writeln!(out, "{name}_count {}", h.count());
    // Companion quantile gauges (skipped when empty: no NaN samples).
    if h.count() > 0 {
        let qname = name
            .strip_suffix("_seconds")
            .map(|base| format!("{base}_quantile_seconds"))
            .unwrap_or_else(|| format!("{name}_quantile"));
        let _ = writeln!(out, "# TYPE {qname} gauge");
        for (q, label) in QUANTILES {
            let _ = writeln!(
                out,
                "{qname}{{q=\"{label}\"}} {}",
                prom_num(h.percentile(q))
            );
        }
    }
}

/// Render the recorder as Prometheus text exposition format.
pub fn to_prometheus(rec: &ObsRecorder) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# HELP dropcompute_steps_total Steps observed.");
    let _ = writeln!(out, "# TYPE dropcompute_steps_total counter");
    let _ = writeln!(out, "dropcompute_steps_total {}", rec.steps);

    let _ = writeln!(
        out,
        "# HELP dropcompute_drops_total Drop events/micro-batches by cause."
    );
    let _ = writeln!(out, "# TYPE dropcompute_drops_total counter");
    for (cause, v) in [
        ("tau", rec.drops.tau_events),
        ("tau_microbatches", rec.drops.tau_microbatches),
        ("step_deadline", rec.drops.step_deadline),
        ("phase_checkpoint", rec.drops.phase_checkpoint),
        ("survivor_restart", rec.drops.survivor_restart),
        ("worker_fault", rec.drops.worker_fault),
        ("comm_lost_microbatches", rec.drops.comm_lost_microbatches),
    ] {
        let _ =
            writeln!(out, "dropcompute_drops_total{{cause=\"{cause}\"}} {v}");
    }

    let _ = writeln!(
        out,
        "# HELP dropcompute_microbatches_total Scheduled vs completed micro-batches."
    );
    let _ = writeln!(out, "# TYPE dropcompute_microbatches_total counter");
    let _ = writeln!(
        out,
        "dropcompute_microbatches_total{{kind=\"scheduled\"}} {}",
        rec.scheduled_microbatches
    );
    let _ = writeln!(
        out,
        "dropcompute_microbatches_total{{kind=\"completed\"}} {}",
        rec.completed_microbatches
    );

    prom_histogram(
        &mut out,
        "dropcompute_iter_time_seconds",
        "Full iteration time (compute + collective).",
        &rec.iter_time,
    );
    prom_histogram(
        &mut out,
        "dropcompute_compute_time_seconds",
        "Per-worker compute draw.",
        &rec.compute_time,
    );
    prom_histogram(
        &mut out,
        "dropcompute_arrival_offset_seconds",
        "Per-worker arrival offset behind the step's fastest worker.",
        &rec.arrival_offset,
    );

    if !rec.phases.is_empty() {
        let _ = writeln!(
            out,
            "# HELP dropcompute_phase_time_seconds Per-phase collective completion time."
        );
        let _ = writeln!(out, "# TYPE dropcompute_phase_time_seconds gauge");
        for (p, s) in rec.phases.iter().enumerate() {
            if s.count == 0 {
                continue;
            }
            let _ = writeln!(
                out,
                "dropcompute_phase_time_seconds{{phase=\"{p}\",stat=\"mean\"}} {}",
                prom_num(s.mean())
            );
            let _ = writeln!(
                out,
                "dropcompute_phase_time_seconds{{phase=\"{p}\",stat=\"max\"}} {}",
                prom_num(s.max)
            );
        }
    }

    for (name, help, get) in [
        (
            "dropcompute_worker_steps_total",
            "Steps the worker participated in.",
            0usize,
        ),
        (
            "dropcompute_worker_was_max_total",
            "Steps the worker had the maximum compute draw.",
            1,
        ),
        (
            "dropcompute_worker_dropped_total",
            "Steps the worker was excluded from the collective.",
            2,
        ),
        (
            "dropcompute_worker_tau_microbatches_total",
            "Micro-batches the worker trimmed to the compute threshold.",
            3,
        ),
        (
            "dropcompute_worker_triggered_checkpoint_total",
            "Steps the worker was the latest arrival among the excluded.",
            4,
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        for (w, s) in rec.workers.iter().enumerate() {
            let v = match get {
                0 => s.steps,
                1 => s.was_max,
                2 => s.dropped,
                3 => s.tau_microbatches,
                _ => s.triggered_checkpoint,
            };
            let _ = writeln!(out, "{name}{{worker=\"{w}\"}} {v}");
        }
    }

    // Real-transport counters — emitted only when a socket run fed the
    // recorder, so sim-only exposition stays byte-identical.
    let t = &rec.transport;
    if t.used() {
        let _ = writeln!(
            out,
            "# HELP dropcompute_transport_events_total Socket-transport \
             events by kind."
        );
        let _ =
            writeln!(out, "# TYPE dropcompute_transport_events_total counter");
        for (kind, v) in [
            ("connect_retry", t.connect_retries),
            ("send_retry", t.send_retries),
            ("recv_timeout", t.recv_timeouts),
            ("peer_lost", t.peers_lost),
            ("degraded_step", t.degraded_steps),
            ("excluded_arrival", t.excluded_arrivals),
        ] {
            let _ = writeln!(
                out,
                "dropcompute_transport_events_total{{kind=\"{kind}\"}} {v}"
            );
        }
        let _ = writeln!(
            out,
            "# HELP dropcompute_transport_frames_total Frames written to \
             peers."
        );
        let _ =
            writeln!(out, "# TYPE dropcompute_transport_frames_total counter");
        let _ = writeln!(
            out,
            "dropcompute_transport_frames_total {}",
            t.frames_sent
        );
        let _ = writeln!(
            out,
            "# HELP dropcompute_transport_bytes_total Bytes written to peers."
        );
        let _ =
            writeln!(out, "# TYPE dropcompute_transport_bytes_total counter");
        let _ =
            writeln!(out, "dropcompute_transport_bytes_total {}", t.bytes_sent);
        prom_histogram(
            &mut out,
            "dropcompute_transport_backoff_seconds",
            "Backoff sleeps taken on connect/send retry.",
            &t.backoff_wait,
        );
        prom_histogram(
            &mut out,
            "dropcompute_transport_recv_wait_seconds",
            "Time blocked in socket receives.",
            &t.recv_wait,
        );
    }
    out
}

/// JSON number or null for non-finite (NaN percentiles on empty
/// histograms must stay valid JSON).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

fn json_hist(h: &LogHistogram) -> String {
    let mut buckets = String::from("[");
    for (k, (i, c)) in h.nonzero_buckets().enumerate() {
        if k > 0 {
            buckets.push(',');
        }
        let _ = write!(buckets, "[{i},{c}]");
    }
    buckets.push(']');
    format!(
        "{{\"count\":{},\"rejected\":{},\"sum\":{},\"min\":{},\"max\":{},\
         \"mean\":{},\"p50\":{},\"p90\":{},\"p99\":{},\"p999\":{},\
         \"buckets\":{buckets}}}",
        h.count(),
        h.rejected(),
        json_num(h.sum()),
        json_num(h.min()),
        json_num(h.max()),
        json_num(h.mean()),
        json_num(h.percentile(0.5)),
        json_num(h.percentile(0.9)),
        json_num(h.percentile(0.99)),
        json_num(h.percentile(0.999)),
    )
}

/// Render the recorder as one JSON object (parseable by
/// [`crate::runtime::json::Json`]; `buckets` are sparse
/// `[index, count]` pairs over the fixed bin grid).
pub fn to_json_snapshot(rec: &ObsRecorder) -> String {
    let mut out = String::from("{");
    let _ = write!(out, "\"steps\":{}", rec.steps);
    let _ = write!(
        out,
        ",\"scheduled_microbatches\":{},\"completed_microbatches\":{}",
        rec.scheduled_microbatches, rec.completed_microbatches
    );
    let _ = write!(
        out,
        ",\"drops\":{{\"tau_events\":{},\"tau_microbatches\":{},\
         \"step_deadline\":{},\"phase_checkpoint\":{},\
         \"survivor_restart\":{},\"worker_fault\":{},\
         \"comm_lost_microbatches\":{}}}",
        rec.drops.tau_events,
        rec.drops.tau_microbatches,
        rec.drops.step_deadline,
        rec.drops.phase_checkpoint,
        rec.drops.survivor_restart,
        rec.drops.worker_fault,
        rec.drops.comm_lost_microbatches,
    );
    let _ = write!(out, ",\"iter_time\":{}", json_hist(&rec.iter_time));
    let _ = write!(out, ",\"compute_time\":{}", json_hist(&rec.compute_time));
    let _ =
        write!(out, ",\"arrival_offset\":{}", json_hist(&rec.arrival_offset));
    out.push_str(",\"phases\":[");
    for (p, s) in rec.phases.iter().enumerate() {
        if p > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"count\":{},\"mean\":{},\"max\":{}}}",
            s.count,
            json_num(s.mean()),
            json_num(if s.count == 0 { f64::NAN } else { s.max })
        );
    }
    out.push_str("],\"workers\":[");
    for (w, s) in rec.workers.iter().enumerate() {
        if w > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"steps\":{},\"was_max\":{},\"dropped\":{},\
             \"tau_microbatches\":{},\"triggered_checkpoint\":{}}}",
            s.steps, s.was_max, s.dropped, s.tau_microbatches,
            s.triggered_checkpoint
        );
    }
    out.push(']');
    if rec.transport.used() {
        let t = &rec.transport;
        let _ = write!(
            out,
            ",\"transport\":{{\"connect_retries\":{},\"send_retries\":{},\
             \"recv_timeouts\":{},\"peers_lost\":{},\"degraded_steps\":{},\
             \"excluded_arrivals\":{},\"frames_sent\":{},\"bytes_sent\":{},\
             \"backoff_wait\":{},\"recv_wait\":{}}}",
            t.connect_retries,
            t.send_retries,
            t.recv_timeouts,
            t.peers_lost,
            t.degraded_steps,
            t.excluded_arrivals,
            t.frames_sent,
            t.bytes_sent,
            json_hist(&t.backoff_wait),
            json_hist(&t.recv_wait),
        );
    }
    out.push('}');
    out
}

// ---------------------------------------------------------------------
// Prometheus exposition-format linter
// ---------------------------------------------------------------------

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_value(s: &str) -> Option<f64> {
    match s {
        "+Inf" | "Inf" => Some(f64::INFINITY),
        "-Inf" => Some(f64::NEG_INFINITY),
        "NaN" => Some(f64::NAN),
        _ => s.parse().ok(),
    }
}

/// One parsed sample line.
struct Sample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
    line: usize,
}

/// Split `name{labels} value` / `name value`; returns `None` with a
/// pushed violation on malformed lines.
fn parse_sample(
    line: &str,
    lineno: usize,
    errs: &mut Vec<String>,
) -> Option<Sample> {
    let bad = |errs: &mut Vec<String>, why: &str| {
        errs.push(format!("line {lineno}: {why}"));
        None
    };
    let (head, rest) = match line.find('{') {
        Some(b) => {
            let close = match line.rfind('}') {
                Some(c) if c > b => c,
                _ => return bad(errs, "unclosed label braces"),
            };
            (&line[..b], Some((&line[b + 1..close], &line[close + 1..])))
        }
        None => (line, None),
    };
    let (name, labels, tail) = match rest {
        Some((label_body, tail)) => {
            let mut labels = Vec::new();
            let mut s = label_body;
            while !s.is_empty() {
                let eq = match s.find('=') {
                    Some(e) => e,
                    None => return bad(errs, "label without '='"),
                };
                let key = s[..eq].trim();
                if !valid_label_name(key) {
                    return bad(errs, &format!("bad label name {key:?}"));
                }
                let after = &s[eq + 1..];
                if !after.starts_with('"') {
                    return bad(errs, "label value not quoted");
                }
                // Find the closing quote, honoring \" escapes.
                let bytes = after.as_bytes();
                let mut i = 1;
                let mut val = String::new();
                let mut closed = false;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => {
                            match bytes.get(i + 1) {
                                Some(b'\\') => val.push('\\'),
                                Some(b'"') => val.push('"'),
                                Some(b'n') => val.push('\n'),
                                _ => {
                                    return bad(
                                        errs,
                                        "bad escape in label value",
                                    )
                                }
                            }
                            i += 2;
                        }
                        b'"' => {
                            closed = true;
                            i += 1;
                            break;
                        }
                        _ => {
                            val.push(after[i..].chars().next().unwrap());
                            i += after[i..].chars().next().unwrap().len_utf8();
                        }
                    }
                }
                if !closed {
                    return bad(errs, "unterminated label value");
                }
                labels.push((key.to_string(), val));
                s = after[i..].trim_start_matches(',').trim_start();
            }
            (head.trim(), labels, tail.trim())
        }
        None => {
            let mut parts = line.splitn(2, char::is_whitespace);
            let name = parts.next().unwrap_or("");
            (name, Vec::new(), parts.next().unwrap_or("").trim())
        }
    };
    if !valid_metric_name(name) {
        return bad(errs, &format!("bad metric name {name:?}"));
    }
    // Value (+ optional timestamp, which we accept and ignore).
    let mut tail_parts = tail.split_whitespace();
    let value = match tail_parts.next().and_then(parse_value) {
        Some(v) => v,
        None => return bad(errs, "missing or unparsable sample value"),
    };
    if let Some(ts) = tail_parts.next() {
        if ts.parse::<i64>().is_err() {
            return bad(errs, "bad timestamp");
        }
    }
    if tail_parts.next().is_some() {
        return bad(errs, "trailing garbage after value");
    }
    Some(Sample { name: name.to_string(), labels, value, line: lineno })
}

/// Family name for TYPE bookkeeping: strips histogram/summary suffixes.
fn family_of(name: &str) -> &str {
    for suf in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suf) {
            return base;
        }
    }
    name
}

/// Lint a Prometheus text exposition payload. Returns the list of
/// violations (empty = clean). Checks: metric/label name syntax,
/// sample value syntax, `# TYPE` declared at most once per family and
/// before its samples, and for histogram families: `le` strictly
/// increasing with non-decreasing cumulative counts, a `+Inf` bucket
/// equal to `_count`, and `_sum` present.
pub fn lint_prometheus(text: &str) -> Vec<String> {
    let mut errs = Vec::new();
    let mut types: std::collections::BTreeMap<String, String> =
        std::collections::BTreeMap::new();
    let mut seen_samples: std::collections::BTreeSet<String> =
        std::collections::BTreeSet::new();
    let mut samples: Vec<Sample> = Vec::new();

    for (k, raw) in text.lines().enumerate() {
        let lineno = k + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            let c = comment.trim_start();
            if let Some(rest) = c.strip_prefix("TYPE ") {
                let mut parts = rest.split_whitespace();
                let name = parts.next().unwrap_or("");
                let kind = parts.next().unwrap_or("");
                if !valid_metric_name(name) {
                    errs.push(format!(
                        "line {lineno}: bad metric name in TYPE: {name:?}"
                    ));
                    continue;
                }
                if !matches!(
                    kind,
                    "counter" | "gauge" | "histogram" | "summary" | "untyped"
                ) {
                    errs.push(format!(
                        "line {lineno}: unknown TYPE {kind:?} for {name}"
                    ));
                }
                if types.contains_key(name) {
                    errs.push(format!(
                        "line {lineno}: duplicate TYPE for {name}"
                    ));
                }
                if seen_samples.contains(name) {
                    errs.push(format!(
                        "line {lineno}: TYPE for {name} after its samples"
                    ));
                }
                types.insert(name.to_string(), kind.to_string());
            }
            // HELP and plain comments: free text, nothing to check.
            continue;
        }
        if let Some(s) = parse_sample(line, lineno, &mut errs) {
            seen_samples.insert(family_of(&s.name).to_string());
            samples.push(s);
        }
    }

    // Histogram family checks.
    for (family, kind) in &types {
        if kind != "histogram" {
            continue;
        }
        let bucket_name = format!("{family}_bucket");
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_cum = 0.0f64;
        let mut inf_bucket: Option<f64> = None;
        let mut count: Option<f64> = None;
        let mut has_sum = false;
        for s in &samples {
            if s.name == bucket_name {
                let le = match s
                    .labels
                    .iter()
                    .find(|(k, _)| k == "le")
                    .and_then(|(_, v)| parse_value(v))
                {
                    Some(v) => v,
                    None => {
                        errs.push(format!(
                            "line {}: {bucket_name} without parsable le",
                            s.line
                        ));
                        continue;
                    }
                };
                if le <= prev_le {
                    errs.push(format!(
                        "line {}: {bucket_name} le not increasing",
                        s.line
                    ));
                }
                if s.value < prev_cum {
                    errs.push(format!(
                        "line {}: {bucket_name} cumulative count decreased",
                        s.line
                    ));
                }
                prev_le = le;
                prev_cum = s.value;
                if le == f64::INFINITY {
                    inf_bucket = Some(s.value);
                }
            } else if s.name == format!("{family}_sum") {
                has_sum = true;
            } else if s.name == format!("{family}_count") {
                count = Some(s.value);
            }
        }
        match (inf_bucket, count) {
            (None, _) => {
                errs.push(format!("histogram {family}: missing +Inf bucket"))
            }
            (Some(b), Some(c)) if b != c => errs.push(format!(
                "histogram {family}: +Inf bucket {b} != _count {c}"
            )),
            (Some(_), None) => {
                errs.push(format!("histogram {family}: missing _count"))
            }
            _ => {}
        }
        if !has_sum {
            errs.push(format!("histogram {family}: missing _sum"));
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{DropCause, SimObserver};
    use crate::runtime::json::Json;
    use crate::sim::StepOutcome;

    fn sample_recorder() -> ObsRecorder {
        let mut r = ObsRecorder::new(2);
        r.on_worker(0, 0.5, 4);
        r.on_worker(1, 1.5, 3);
        r.on_drop(1, DropCause::Tau { microbatches: 1 });
        r.on_phase(0, &[0.5, 1.5]);
        r.on_step(&StepOutcome {
            worker_compute: vec![0.5, 1.5],
            completed: vec![4, 3],
            compute_time: 1.5,
            iter_time: 1.8,
        });
        r
    }

    #[test]
    fn prometheus_output_passes_own_linter() {
        let text = to_prometheus(&sample_recorder());
        let errs = lint_prometheus(&text);
        assert!(errs.is_empty(), "lint violations: {errs:?}");
        assert!(text.contains("dropcompute_steps_total 1"));
        assert!(text.contains("dropcompute_drops_total{cause=\"tau\"} 1"));
        assert!(text.contains("dropcompute_iter_time_seconds_count 1"));
        assert!(
            text.contains("dropcompute_worker_was_max_total{worker=\"1\"} 1")
        );
    }

    #[test]
    fn empty_recorder_exports_cleanly() {
        let r = ObsRecorder::new(0);
        let errs = lint_prometheus(&to_prometheus(&r));
        assert!(errs.is_empty(), "{errs:?}");
        let j = Json::parse(&to_json_snapshot(&r)).unwrap();
        assert_eq!(j.path(&["steps"]).unwrap().as_f64(), Some(0.0));
        // Empty histogram percentiles serialize as null, not NaN.
        assert!(matches!(
            j.path(&["iter_time", "p50"]).unwrap(),
            Json::Null
        ));
    }

    #[test]
    fn json_snapshot_round_trips() {
        let r = sample_recorder();
        let j = Json::parse(&to_json_snapshot(&r)).unwrap();
        assert_eq!(j.path(&["steps"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.path(&["drops", "tau_microbatches"]).unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            j.path(&["iter_time", "count"]).unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            j.path(&["iter_time", "p50"]).unwrap().as_f64(),
            Some(1.8)
        );
        let workers = j.path(&["workers"]).unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(
            workers[1].get("tau_microbatches").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(j.path(&["phases"]).unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn linter_catches_deliberate_violations() {
        // Bad metric name.
        assert!(!lint_prometheus("9bad_name 1").is_empty());
        // Unquoted label value.
        assert!(!lint_prometheus("m{l=x} 1").is_empty());
        // Unparsable value.
        assert!(!lint_prometheus("m 1.2.3").is_empty());
        // TYPE after samples.
        assert!(!lint_prometheus("m 1\n# TYPE m counter").is_empty());
        // Duplicate TYPE.
        assert!(
            !lint_prometheus("# TYPE m counter\n# TYPE m counter\nm 1")
                .is_empty()
        );
        // Histogram: +Inf bucket disagrees with _count.
        let h = "# TYPE h histogram\n\
                 h_bucket{le=\"1\"} 1\n\
                 h_bucket{le=\"+Inf\"} 2\n\
                 h_sum 1.0\n\
                 h_count 3\n";
        assert!(!lint_prometheus(h).is_empty());
        // Histogram: le not increasing.
        let h2 = "# TYPE h histogram\n\
                  h_bucket{le=\"2\"} 1\n\
                  h_bucket{le=\"1\"} 2\n\
                  h_bucket{le=\"+Inf\"} 2\n\
                  h_sum 1.0\n\
                  h_count 2\n";
        assert!(!lint_prometheus(h2).is_empty());
        // Histogram: missing _sum.
        let h3 = "# TYPE h histogram\n\
                  h_bucket{le=\"+Inf\"} 1\n\
                  h_count 1\n";
        assert!(!lint_prometheus(h3).is_empty());
        // A clean payload stays clean.
        let ok = "# TYPE m counter\nm{a=\"b\"} 1\n";
        assert!(lint_prometheus(ok).is_empty());
    }

    #[test]
    fn transport_block_is_gated_on_use_and_lints() {
        // Sim-only recorders export no transport family at all — the
        // output is byte-identical to the pre-transport format.
        let plain = sample_recorder();
        assert!(!to_prometheus(&plain).contains("transport"));
        assert!(!to_json_snapshot(&plain).contains("transport"));

        let mut r = sample_recorder();
        r.transport.peers_lost = 2;
        r.transport.frames_sent = 40;
        r.transport.bytes_sent = 1024;
        r.transport.recv_wait.record(0.003);
        r.transport.backoff_wait.record(0.010);
        let text = to_prometheus(&r);
        let errs = lint_prometheus(&text);
        assert!(errs.is_empty(), "lint violations: {errs:?}");
        assert!(text
            .contains("dropcompute_transport_events_total{kind=\"peer_lost\"} 2"));
        assert!(text.contains("dropcompute_transport_frames_total 40"));
        assert!(text.contains("dropcompute_transport_recv_wait_seconds_count 1"));

        let j = Json::parse(&to_json_snapshot(&r)).unwrap();
        assert_eq!(
            j.path(&["transport", "peers_lost"]).unwrap().as_f64(),
            Some(2.0)
        );
        assert_eq!(
            j.path(&["transport", "recv_wait", "count"]).unwrap().as_f64(),
            Some(1.0)
        );

        // merge folds transport counters element-wise
        let mut merged = ObsRecorder::new(2);
        merged.merge(&r);
        merged.merge(&r);
        assert_eq!(merged.transport.peers_lost, 4);
        assert_eq!(merged.transport.recv_wait.count(), 2);
        assert!(merged.transport.used());
    }
}
