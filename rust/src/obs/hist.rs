//! Streaming log-bucketed histograms (HDR-style fixed bins).
//!
//! Bucket edges are a pure function of the f64 bit pattern — exponent
//! plus the top [`SUB_BITS`] mantissa bits — so recording never calls
//! `log()`/`powf()` and every machine places a given sample in the same
//! bucket. Buckets form a geometric grid with 2^[`SUB_BITS`] = 8
//! sub-buckets per octave (≤ 12.5% relative error per bucket), anchored
//! at [`LO`] = 1e-6 s; values below `LO` share bucket 0 and values past
//! the top land in the saturating last bucket.
//!
//! **Merge determinism.** [`LogHistogram::merge`] is an element-wise
//! `u64` add plus one f64 `sum` add. Counts and percentiles are
//! therefore order-independent outright; the f64 `sum` is bitwise
//! reproducible as long as merges fold in a fixed order. The sweep
//! runner guarantees that: each grid point records into its own
//! histogram (pure per index) and [`crate::sweep::SweepSpec::run_observed`]
//! folds the per-point recorders in index order, so the merged result
//! is bitwise independent of `--jobs`.
//!
//! Percentiles are "exact" in the HDR sense: `percentile(q)` returns
//! the upper edge of the bucket holding the rank-`ceil(q·n)` sample,
//! clamped to the exact observed `[min, max]` — so p0/p100 are exact,
//! single-sample histograms report the sample itself, and interior
//! quantiles are within one bucket width (≤ 12.5%) of the true order
//! statistic.

/// Lower edge of the first log bucket (seconds). Everything in
/// `[0, LO)` shares bucket 0.
pub const LO: f64 = 1e-6;

/// Mantissa bits per bucket index: 2^3 = 8 sub-buckets per octave.
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;

/// Total bucket count. Bucket 0 is `[0, LO)`; buckets `1..BUCKETS-1`
/// tile `[LO, LO·2^63)` geometrically; the last bucket saturates to
/// `+∞`. 512 buckets cover ~63 octaves above `LO` — 1 µs to ~290k
/// years, far past any simulated time.
pub const BUCKETS: usize = 512;

/// Bucket index for a finite, non-negative value. Pure bit
/// manipulation: scale by `1/LO`, then read the unbiased exponent and
/// top [`SUB_BITS`] mantissa bits.
#[inline]
fn bucket_of(v: f64) -> usize {
    let scaled = v / LO;
    if scaled < 1.0 {
        return 0;
    }
    let bits = scaled.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as u64 - 1023; // >= 0: scaled >= 1
    let man = (bits >> (52 - SUB_BITS)) & (SUB - 1);
    let idx = (exp * SUB + man + 1) as usize;
    idx.min(BUCKETS - 1)
}

/// Upper edge (exclusive) of bucket `i`: bucket `i` covers
/// `[bucket_hi(i-1), bucket_hi(i))`, with bucket 0 = `[0, LO)` and the
/// last bucket open-ended.
pub fn bucket_hi(i: usize) -> f64 {
    if i == 0 {
        return LO;
    }
    if i >= BUCKETS - 1 {
        return f64::INFINITY;
    }
    let k = (i - 1) as u64;
    let e = (k / SUB) as i32;
    let m = (k % SUB) as f64;
    // 2^e is exact in f64; (1 + (m+1)/8) has 3 fractional bits — the
    // product rounds once, identically everywhere.
    LO * 2f64.powi(e) * (1.0 + (m + 1.0) / SUB as f64)
}

/// Lower edge (inclusive) of bucket `i`.
pub fn bucket_lo(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        bucket_hi(i - 1)
    }
}

/// Streaming log-bucketed histogram over non-negative seconds.
/// Fixed-size (one `[u64; BUCKETS]` worth of counts), allocation-free
/// after construction, deterministically mergeable.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Samples refused by [`record`](Self::record): NaN, ±∞, negative.
    rejected: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rejected: 0,
        }
    }

    /// Record one sample. NaN, infinite, and negative values are
    /// rejected (tallied in [`rejected`](Self::rejected), never mixed
    /// into counts/sum/percentiles).
    #[inline]
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            self.rejected += 1;
            return;
        }
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact observed minimum (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Exact observed maximum (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile readout. `q` is clamped to `[0, 1]`; returns NaN when
    /// the histogram is empty. The rank-`ceil(q·n)` sample's bucket
    /// upper edge, clamped to the observed `[min, max]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        // The rank-1 order statistic is the exact observed minimum —
        // reporting its bucket's upper edge would bias p0 upward.
        if rank == 1 {
            return self.min;
        }
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_hi(i).clamp(self.min, self.max);
            }
        }
        self.max // unreachable: cum == count >= rank at the last bucket
    }

    /// Element-wise merge. Counts are order-independent; the f64 `sum`
    /// is bitwise reproducible when merges fold in a fixed order (see
    /// module docs).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.rejected += other.rejected;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Occupied buckets as `(index, count)`, ascending — the sparse
    /// form the exporters serialize.
    pub fn nonzero_buckets(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
    }

    /// Raw count of bucket `i` (test/export helper).
    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_half_open_and_monotone() {
        // [0, LO) is bucket 0; LO itself starts bucket 1.
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(LO * 0.999), 0);
        assert_eq!(bucket_of(LO), 1);
        // Edges strictly increase and every edge value lands in the
        // bucket it opens (half-open [lo, hi)).
        for i in 1..BUCKETS - 1 {
            assert!(bucket_hi(i) > bucket_hi(i - 1), "edge {i} not increasing");
            let lo = bucket_lo(i);
            assert_eq!(bucket_of(lo), i, "lower edge of bucket {i}");
            let hi = bucket_hi(i);
            if hi.is_finite() {
                assert_eq!(bucket_of(hi), i + 1, "upper edge of bucket {i}");
            }
        }
        assert_eq!(bucket_hi(BUCKETS - 1), f64::INFINITY);
        // Huge values saturate instead of indexing out of range.
        assert_eq!(bucket_of(f64::MAX), BUCKETS - 1);
    }

    #[test]
    fn relative_error_bounded_by_sub_bucket_width() {
        for &v in &[1e-6, 3.7e-5, 0.00123, 0.5, 1.0, 17.3, 4096.0] {
            let i = bucket_of(v);
            let (lo, hi) = (bucket_lo(i), bucket_hi(i));
            assert!(lo <= v && v < hi, "{v} outside [{lo}, {hi})");
            assert!(
                (hi - lo) / lo <= 0.125 + 1e-12,
                "bucket {i} wider than 12.5%"
            );
        }
    }

    #[test]
    fn percentile_empty_is_nan() {
        let h = LogHistogram::new();
        assert!(h.percentile(0.5).is_nan());
        assert!(h.min().is_nan());
        assert!(h.max().is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn percentile_single_sample_is_exact() {
        let mut h = LogHistogram::new();
        h.record(0.0371);
        for q in [0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(h.percentile(q), 0.0371, "q={q}");
        }
    }

    #[test]
    fn rejects_nan_inf_negative() {
        let mut h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(-1.0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.rejected(), 4);
        assert!(h.percentile(0.5).is_nan());
        h.record(2.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(0.5), 2.0);
    }

    #[test]
    fn percentiles_track_order_statistics_within_a_bucket() {
        let mut h = LogHistogram::new();
        // 1..=1000 ms: true p50 = 0.5s, p99 = 0.99s.
        for k in 1..=1000 {
            h.record(k as f64 * 1e-3);
        }
        let p50 = h.percentile(0.5);
        let p99 = h.percentile(0.99);
        assert!((p50 - 0.5).abs() / 0.5 <= 0.125, "p50 {p50}");
        assert!((p99 - 0.99).abs() / 0.99 <= 0.125, "p99 {p99}");
        assert_eq!(h.percentile(0.0), 1e-3);
        assert_eq!(h.percentile(1.0), 1.0);
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn merge_equals_serial_bitwise() {
        // One stream recorded serially vs. split into shards and merged
        // in shard order: identical counts and bitwise-identical sum.
        let vals: Vec<f64> =
            (0..500).map(|k| 1e-4 * (1.0 + (k as f64) * 0.37)).collect();
        let mut serial = LogHistogram::new();
        for &v in &vals {
            serial.record(v);
        }
        let mut shards: Vec<LogHistogram> = Vec::new();
        for chunk in vals.chunks(97) {
            let mut h = LogHistogram::new();
            for &v in chunk {
                h.record(v);
            }
            shards.push(h);
        }
        let mut merged = LogHistogram::new();
        for s in &shards {
            merged.merge(s);
        }
        assert_eq!(merged.count(), serial.count());
        assert_eq!(merged.counts, serial.counts);
        assert_eq!(merged.sum().to_bits(), serial.sum().to_bits());
        assert_eq!(merged.min().to_bits(), serial.min().to_bits());
        assert_eq!(merged.max().to_bits(), serial.max().to_bits());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(
                merged.percentile(q).to_bits(),
                serial.percentile(q).to_bits()
            );
        }
    }

    /// Deterministic pseudo-random shards spanning several decades,
    /// with a sprinkle of rejected (negative) samples.
    fn shard(seed: u64, n: usize) -> LogHistogram {
        let mut rng = crate::rng::SplitMix64::new(seed);
        let mut h = LogHistogram::new();
        for k in 0..n {
            let u = rng.next_u64();
            let unit = (u >> 11) as f64 / (1u64 << 53) as f64;
            let v = unit * 10f64.powi((u % 7) as i32 - 3);
            h.record(if k % 41 == 40 { -v - 1.0 } else { v });
        }
        h
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let shards =
            [shard(0xA11CE, 257), shard(0xB0B, 301), shard(0xCAFE, 129)];
        let fold = |order: [usize; 3]| {
            let mut m = LogHistogram::new();
            for &i in &order {
                m.merge(&shards[i]);
            }
            m
        };
        // ((a·b)·c) against every other association/permutation:
        // counts, min/max, rejected and therefore percentiles must be
        // exactly invariant (element-wise u64 adds commute); the f64
        // sum may differ by addition order, but only within rounding
        let want = fold([0, 1, 2]);
        for order in
            [[0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]]
        {
            let got = fold(order);
            assert_eq!(got.counts, want.counts, "{order:?}");
            assert_eq!(got.count(), want.count());
            assert_eq!(got.rejected(), want.rejected());
            assert_eq!(got.min().to_bits(), want.min().to_bits());
            assert_eq!(got.max().to_bits(), want.max().to_bits());
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                assert_eq!(
                    got.percentile(q).to_bits(),
                    want.percentile(q).to_bits(),
                    "{order:?} q={q}"
                );
            }
            let rel = (got.sum() - want.sum()).abs() / want.sum().abs();
            assert!(rel < 1e-12, "{order:?} sum off by {rel}");
        }
        // nested association: a·(b·c) == (a·b)·c element-wise
        let mut bc = shards[1].clone();
        bc.merge(&shards[2]);
        let mut nested = shards[0].clone();
        nested.merge(&bc);
        assert_eq!(nested.counts, want.counts);
        assert_eq!(nested.count(), want.count());
        // merging an empty histogram is the identity on every exact
        // field (min/max stay NaN-free, counts untouched)
        let mut id = want.clone();
        id.merge(&LogHistogram::new());
        assert_eq!(id.counts, want.counts);
        assert_eq!(id.min().to_bits(), want.min().to_bits());
        assert_eq!(id.max().to_bits(), want.max().to_bits());
        assert_eq!(id.sum().to_bits(), want.sum().to_bits());
    }

    #[test]
    fn percentiles_are_monotone_in_rank() {
        for seed in [1u64, 7, 42, 0xDEAD] {
            let h = shard(seed, 513);
            let mut prev = f64::NEG_INFINITY;
            for k in 0..=100 {
                let q = k as f64 / 100.0;
                let p = h.percentile(q);
                assert!(
                    p >= prev,
                    "seed {seed}: percentile({q}) = {p} < {prev}"
                );
                prev = p;
            }
            // the endpoints are the exact observed extrema
            assert_eq!(h.percentile(0.0).to_bits(), h.min().to_bits());
            assert_eq!(h.percentile(1.0).to_bits(), h.max().to_bits());
        }
    }

    #[test]
    fn merge_order_leaves_counts_invariant() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for k in 0..100 {
            a.record(1e-3 * (k + 1) as f64);
            b.record(2e-3 * (k + 1) as f64);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.counts, ba.counts);
        assert_eq!(ab.count(), ba.count());
        // min/max are order-independent too.
        assert_eq!(ab.min().to_bits(), ba.min().to_bits());
        assert_eq!(ab.max().to_bits(), ba.max().to_bits());
    }
}
