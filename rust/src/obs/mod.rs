//! Opt-in, allocation-free observability for the simulator and sweep
//! engine — the runtime form of the paper's compute-variance analysis.
//!
//! The paper's scalability argument is a statement about *compute-time
//! distributions* (max-over-workers arrival offsets, tail percentiles,
//! who straggles); this module makes those quantities visible while a
//! run executes instead of only as end-of-run means, and it is the
//! groundwork for the ROADMAP's `pallas serve` endpoint (cf.
//! OptiReduce's case that tail percentiles, not means, are the metric
//! that matters for bounded-wait AllReduce).
//!
//! Pieces:
//!
//! * [`observer`] — the [`SimObserver`] hook set threaded through
//!   [`crate::sim::ClusterSim`]'s step path, with [`NoopObserver`]
//!   (the default) monomorphizing to exactly the un-instrumented code:
//!   disabled runs are bitwise and perf-identical (`obs_overhead`
//!   bench pair, `tests/obs_equivalence.rs`);
//! * [`hist`] — [`LogHistogram`], HDR-style log-bucketed streaming
//!   histograms with deterministic element-wise merge: per-point sweep
//!   shards reduce to one histogram bitwise-independent of `--jobs`;
//! * [`recorder`] — [`ObsRecorder`], the standard observer: iter-time
//!   / compute-time / arrival-offset histograms, per-worker
//!   straggler-attribution table, typed [`DropCause`] totals;
//! * [`export`] — Prometheus text + JSON snapshot exporters and the
//!   in-tree exposition-format linter CI runs against our own output;
//! * [`log`] — the leveled logging shim behind `--quiet`/`-v` and the
//!   crate's `info!`/`warn!`/`debug!` macros.
//!
//! Wiring: `--obs-out BASE` on `simulate`/`sweep`/`trace replay`
//! writes `BASE.prom` + `BASE.json`; the `[obs]` config section turns
//! recording on without a file (summary table instead); sweeps merge
//! per-point recorders in index order.

pub mod export;
pub mod hist;
pub mod log;
pub mod observer;
pub mod recorder;

pub use export::{lint_prometheus, to_json_snapshot, to_prometheus};
pub use hist::LogHistogram;
pub use observer::{DropCause, NoopObserver, SimObserver};
pub use recorder::{
    DropTotals, ObsRecorder, PhaseStat, TransportStats, WorkerStats,
};
