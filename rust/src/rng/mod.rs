//! Pseudo-random numbers and distribution samplers (from scratch).
//!
//! The sandboxed registry has no `rand`/`rand_distr`, and the paper's
//! simulated-delay machinery needs several distribution families
//! (App. B.1: bounded log-normal; App. C.3: normal / bernoulli /
//! exponential / gamma ablations), so this module implements:
//!
//! * [`SplitMix64`] — seeding generator,
//! * [`Xoshiro256pp`] — the main PRNG (xoshiro256++ 1.0),
//! * [`Distribution`] samplers with analytically-known moments used by
//!   the property tests and the analytical speedup model.

mod distributions;

pub use distributions::{
    Bernoulli, BoundedLogNormal, Distribution, Exponential, Gamma, LogNormal,
    Normal, Uniform,
};

/// SplitMix64: used to expand a `u64` seed into xoshiro state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators", OOPSLA 2014.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna, 2019). Fast, 2^256-1 period,
/// passes BigCrush; plenty for simulation workloads.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        // All-zero state is invalid (fixed point); SplitMix64 cannot emit
        // four zeros in a row, but guard anyway.
        if s == [0; 4] {
            Self { s: [1, 2, 3, 4] }
        } else {
            Self { s }
        }
    }

    /// Derive an independent stream for worker `id` (one jump-free split;
    /// distinct golden-ratio offsets give uncorrelated SplitMix64 seeds).
    pub fn split(&self, id: u64) -> Self {
        Self::seed_from_u64(
            self.s[0]
                .wrapping_add(id.wrapping_mul(0x9E3779B97F4A7C15))
                .wrapping_add(self.s[2].rotate_left(17)),
        )
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via the Marsaglia polar method (no trig).
    pub fn next_standard_normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference output of SplitMix64 for seed 1234567 (first 3 values,
        // cross-checked against the public-domain C implementation).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_and_seed_sensitive() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Xoshiro256pp::seed_from_u64(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_f64();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var={var}");
    }

    #[test]
    fn next_below_unbiased_smoke() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn standard_normal_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let n = 200_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.next_standard_normal();
            s += x;
            s2 += x * x;
            s3 += x * x * x;
        }
        assert!((s / n as f64).abs() < 0.01);
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
        assert!((s3 / n as f64).abs() < 0.05); // symmetry
    }

    #[test]
    fn split_streams_differ() {
        let root = Xoshiro256pp::seed_from_u64(9);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(1);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
