//! Distribution samplers with analytically known moments.
//!
//! Every family used by the paper's noise ablations (App. B.1, C.3) is
//! here, each reporting its `mean()`/`variance()` so the analytical
//! runtime model (Eq. 4/5/11) and the property tests can cross-check the
//! sampler against closed forms.

use super::Xoshiro256pp;

/// A sampleable latency/noise distribution.
pub trait Distribution: Send + Sync + std::fmt::Debug {
    /// Draw one sample.
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64;
    /// Analytical mean.
    fn mean(&self) -> f64;
    /// Analytical variance.
    fn variance(&self) -> f64;
}

/// Uniform on `[lo, hi)`.
#[derive(Debug, Clone, Copy)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo, "uniform: hi < lo");
        Self { lo, hi }
    }
}

impl Distribution for Uniform {
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
    fn mean(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }
    fn variance(&self) -> f64 {
        let w = self.hi - self.lo;
        w * w / 12.0
    }
}

/// Normal(mu, sigma^2).
#[derive(Debug, Clone, Copy)]
pub struct Normal {
    pub mu: f64,
    pub sigma: f64,
}

impl Normal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0, "normal: negative sigma");
        Self { mu, sigma }
    }

    /// Normal with a given mean and variance.
    pub fn from_moments(mean: f64, var: f64) -> Self {
        Self::new(mean, var.max(0.0).sqrt())
    }
}

impl Distribution for Normal {
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.mu + self.sigma * rng.next_standard_normal()
    }
    fn mean(&self) -> f64 {
        self.mu
    }
    fn variance(&self) -> f64 {
        self.sigma * self.sigma
    }
}

/// LogNormal: `exp(N(mu, sigma^2))` — the paper's delay model
/// (user-post lengths are log-normal, Sobkowicz et al. 2013).
#[derive(Debug, Clone, Copy)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0);
        Self { mu, sigma }
    }

    /// Solve (mu, sigma) of the underlying normal from the target
    /// mean/variance of the log-normal itself — used by the Fig 13/14
    /// ablations, which fix `Mean(eps)`/`Var(eps)` and vary the family.
    pub fn from_moments(mean: f64, var: f64) -> Self {
        assert!(mean > 0.0 && var >= 0.0);
        let phi = 1.0 + var / (mean * mean);
        Self::new(mean.ln() - 0.5 * phi.ln(), phi.ln().sqrt())
    }
}

impl Distribution for LogNormal {
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        (self.mu + self.sigma * rng.next_standard_normal()).exp()
    }
    fn mean(&self) -> f64 {
        (self.mu + 0.5 * self.sigma * self.sigma).exp()
    }
    fn variance(&self) -> f64 {
        let s2 = self.sigma * self.sigma;
        (s2.exp() - 1.0) * (2.0 * self.mu + s2).exp()
    }
}

/// The paper's additive noise (App. B.1):
/// `eps = min(Z / alpha, beta)`, `Z ~ LogNormal(4, 1)`, applied as
/// `t <- t + mu_compute * eps`. Moments are computed from the truncated
/// log-normal closed form.
#[derive(Debug, Clone, Copy)]
pub struct BoundedLogNormal {
    pub inner: LogNormal,
    pub alpha: f64,
    pub beta: f64,
}

impl BoundedLogNormal {
    pub fn new(mu: f64, sigma: f64, alpha: f64, beta: f64) -> Self {
        assert!(alpha > 0.0 && beta > 0.0);
        Self { inner: LogNormal::new(mu, sigma), alpha, beta }
    }

    /// The exact constants of App. B.1: Z~LogNormal(4,1),
    /// alpha = 2*exp(4.5), beta = 5.5 → E[eps] ≈ 0.5 (x1.5 slowdown),
    /// max 5.5 (up to ~6.5x on one accumulation).
    pub fn paper_default() -> Self {
        Self::new(4.0, 1.0, 2.0 * (4.5f64).exp(), 5.5)
    }

    /// E[min(Y, beta)] and E[min(Y, beta)^2] for Y = Z/alpha log-normal:
    /// E[min(Y,b)^k] = e^{k m + k^2 s^2/2} Φ((ln b - m - k s^2)/s)
    ///              + b^k (1 - Φ((ln b - m)/s)),
    /// with m = mu - ln(alpha).
    fn truncated_moment(&self, k: f64) -> f64 {
        use crate::stats::normal::phi;
        let m = self.inner.mu - self.alpha.ln();
        let s = self.inner.sigma;
        let lb = self.beta.ln();
        let body = (k * m + 0.5 * k * k * s * s).exp() * phi((lb - m - k * s * s) / s);
        let tail = self.beta.powf(k) * (1.0 - phi((lb - m) / s));
        body + tail
    }
}

impl Distribution for BoundedLogNormal {
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        (self.inner.sample(rng) / self.alpha).min(self.beta)
    }
    fn mean(&self) -> f64 {
        self.truncated_moment(1.0)
    }
    fn variance(&self) -> f64 {
        let m1 = self.truncated_moment(1.0);
        self.truncated_moment(2.0) - m1 * m1
    }
}

/// Exponential(lambda) — rate parameterization.
#[derive(Debug, Clone, Copy)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0);
        Self { lambda }
    }
    pub fn from_mean(mean: f64) -> Self {
        Self::new(1.0 / mean)
    }
}

impl Distribution for Exponential {
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        -rng.next_f64_open().ln() / self.lambda
    }
    fn mean(&self) -> f64 {
        1.0 / self.lambda
    }
    fn variance(&self) -> f64 {
        1.0 / (self.lambda * self.lambda)
    }
}

/// Bernoulli(p) scaled by `value`: the Fig 13 "0.45·Br(p=0.5)" noise.
#[derive(Debug, Clone, Copy)]
pub struct Bernoulli {
    pub p: f64,
    pub value: f64,
}

impl Bernoulli {
    pub fn new(p: f64, value: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        Self { p, value }
    }
}

impl Distribution for Bernoulli {
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        if rng.next_f64() < self.p {
            self.value
        } else {
            0.0
        }
    }
    fn mean(&self) -> f64 {
        self.p * self.value
    }
    fn variance(&self) -> f64 {
        self.value * self.value * self.p * (1.0 - self.p)
    }
}

/// Gamma(shape alpha, rate beta) via Marsaglia–Tsang (2000), with the
/// alpha < 1 boost `Gamma(a) = Gamma(a+1) * U^{1/a}`.
#[derive(Debug, Clone, Copy)]
pub struct Gamma {
    pub shape: f64,
    pub rate: f64,
}

impl Gamma {
    pub fn new(shape: f64, rate: f64) -> Self {
        assert!(shape > 0.0 && rate > 0.0);
        Self { shape, rate }
    }

    pub fn from_moments(mean: f64, var: f64) -> Self {
        assert!(mean > 0.0 && var > 0.0);
        Self::new(mean * mean / var, mean / var)
    }

    fn sample_standard(shape: f64, rng: &mut Xoshiro256pp) -> f64 {
        if shape < 1.0 {
            let u = rng.next_f64_open();
            return Self::sample_standard(shape + 1.0, rng) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = rng.next_standard_normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = rng.next_f64_open();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }
}

impl Distribution for Gamma {
    #[inline]
    fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        Self::sample_standard(self.shape, rng) / self.rate
    }
    fn mean(&self) -> f64 {
        self.shape / self.rate
    }
    fn variance(&self) -> f64 {
        self.shape / (self.rate * self.rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Sampled moments must match the analytical ones — this is the
    /// property that lets the analytical model (Eq. 4/5) trust the
    /// simulator and vice versa.
    fn check_moments(d: &dyn Distribution, n: usize, tol_mean: f64, tol_var: f64) {
        let mut rng = Xoshiro256pp::seed_from_u64(0xD15EA5E);
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = d.sample(&mut rng);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(
            (mean - d.mean()).abs() < tol_mean,
            "{d:?}: sample mean {mean} vs analytic {}",
            d.mean()
        );
        assert!(
            (var - d.variance()).abs() < tol_var,
            "{d:?}: sample var {var} vs analytic {}",
            d.variance()
        );
    }

    #[test]
    fn uniform_moments() {
        check_moments(&Uniform::new(1.0, 3.0), 200_000, 0.01, 0.01);
    }

    #[test]
    fn normal_moments() {
        check_moments(&Normal::new(2.0, 0.5), 200_000, 0.01, 0.01);
    }

    #[test]
    fn lognormal_moments() {
        check_moments(&LogNormal::new(0.0, 0.5), 400_000, 0.01, 0.02);
    }

    #[test]
    fn lognormal_from_moments_roundtrip() {
        for (m, v) in [(0.225, 0.05), (0.225, 0.3), (1.0, 2.0)] {
            let d = LogNormal::from_moments(m, v);
            assert!((d.mean() - m).abs() < 1e-12, "{}", d.mean());
            assert!((d.variance() - v).abs() < 1e-12, "{}", d.variance());
        }
    }

    #[test]
    fn bounded_lognormal_paper_constants() {
        // App. B.1: noise scaled so each accumulation takes ~x1.5 longer
        // on average (E[eps] ~= 0.5) and at most ~6x (beta = 5.5).
        let d = BoundedLogNormal::paper_default();
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut max_seen: f64 = 0.0;
        let mut sum = 0.0;
        let n = 400_000;
        for _ in 0..n {
            let x = d.sample(&mut rng);
            assert!(x >= 0.0 && x <= 5.5 + 1e-12);
            max_seen = max_seen.max(x);
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - d.mean()).abs() < 0.01, "mean {mean} vs {}", d.mean());
        assert!((0.3..0.7).contains(&mean), "paper wants ~0.5, got {mean}");
        assert!(max_seen > 4.0, "bound should be hit occasionally");
    }

    #[test]
    fn bounded_lognormal_moments() {
        let d = BoundedLogNormal::new(0.0, 1.0, 1.0, 2.0);
        check_moments(&d, 400_000, 0.01, 0.02);
    }

    #[test]
    fn exponential_moments() {
        check_moments(&Exponential::new(4.47), 200_000, 0.005, 0.005);
    }

    #[test]
    fn bernoulli_moments() {
        check_moments(&Bernoulli::new(0.5, 0.45), 200_000, 0.005, 0.005);
    }

    #[test]
    fn gamma_moments_shape_above_one() {
        check_moments(&Gamma::new(4.0, 2.0), 300_000, 0.02, 0.05);
    }

    #[test]
    fn gamma_moments_shape_below_one() {
        check_moments(&Gamma::new(0.5, 1.0), 300_000, 0.02, 0.05);
    }

    #[test]
    fn fig13_families_share_moments() {
        // The Fig 13 ablation holds Mean=0.225, Var=0.05 across families.
        let (m, v) = (0.225, 0.05);
        let fams: Vec<Box<dyn Distribution>> = vec![
            Box::new(LogNormal::from_moments(m, v)),
            Box::new(Normal::from_moments(m, v)),
            Box::new(Bernoulli::new(0.5, 0.45)),
            Box::new(Exponential::from_mean(m)),
            Box::new(Gamma::from_moments(m, v)),
        ];
        for d in &fams {
            assert!((d.mean() - m).abs() < 0.015, "{d:?} mean {}", d.mean());
        }
        // bernoulli/exponential variances differ slightly by construction
        // (paper's table does the same); lognormal/normal/gamma are exact.
        for i in [0usize, 1, 4] {
            assert!((fams[i].variance() - v).abs() < 1e-9);
        }
    }
}
