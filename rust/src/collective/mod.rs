//! Decentralized collectives over in-process channels.
//!
//! The paper's setting is AllReduce-based synchronous training
//! (von Luxburg et al.; Patarasuk & Yuan 2009) with **no parameter
//! server** — DropCompute must work where no central entity decides who
//! participates. These collectives run one OS thread per worker over
//! `std::sync::mpsc` channels arranged in a ring, providing:
//!
//! * [`ring_all_reduce`] — reduce-scatter + all-gather sum (bandwidth
//!   optimal), used for gradient aggregation;
//! * [`all_gather_varlen`] — variable-length gather, used by Algorithm 2
//!   to synchronize empirical latency distributions (and by stochastic
//!   batch-size weighting to exchange per-worker completed counts);
//! * [`Communicator`] — the per-worker handle tying a thread group
//!   together.
//!
//! Beyond the fixed ring, [`engine`] executes any
//! [`crate::topology::Schedule`] (ring / tree / hierarchical / torus)
//! over the full [`mesh`], with the same phase discipline the
//! virtual-time model in [`crate::sim::comm`] simulates — the two
//! consumers of the `topology` subsystem.

pub mod engine;
pub mod mesh;

pub use engine::{schedule_all_reduce, topology_all_reduce};
pub use mesh::{
    naive_all_reduce, tree_all_reduce, try_naive_all_reduce,
    try_tree_all_reduce, CommError, MeshComm, DEFAULT_RECV_DEADLINE,
};

use crate::topology::chunk_bounds;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

/// Message on the ring: a chunk of f64/f32 payload.
enum Msg {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// Per-worker communicator: ring neighbours + a group barrier.
pub struct Communicator {
    pub rank: usize,
    pub size: usize,
    to_next: Sender<Msg>,
    from_prev: Receiver<Msg>,
    barrier: Arc<Barrier>,
}

impl Communicator {
    /// Create a fully-wired ring of `n` communicators.
    pub fn ring(n: usize) -> Vec<Communicator> {
        assert!(n > 0);
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        let barrier = Arc::new(Barrier::new(n));
        (0..n)
            .map(|rank| Communicator {
                rank,
                size: n,
                // worker `rank` sends to `rank+1`'s channel
                to_next: senders[(rank + 1) % n].clone(),
                from_prev: receivers[rank].take().unwrap(),
                barrier: Arc::clone(&barrier),
            })
            .collect()
    }

    /// Block until every worker reaches this point (the Eq. 1 barrier).
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    fn send_f32(&self, data: Vec<f32>) {
        self.to_next.send(Msg::F32(data)).expect("ring send");
    }

    fn recv_f32(&self) -> Vec<f32> {
        match self.from_prev.recv().expect("ring recv") {
            Msg::F32(v) => v,
            _ => panic!("dtype mismatch on ring"),
        }
    }

    fn send_f64(&self, data: Vec<f64>) {
        self.to_next.send(Msg::F64(data)).expect("ring send");
    }

    fn recv_f64(&self) -> Vec<f64> {
        match self.from_prev.recv().expect("ring recv") {
            Msg::F64(v) => v,
            _ => panic!("dtype mismatch on ring"),
        }
    }
}

/// Ring all-reduce (sum) in place: reduce-scatter then all-gather,
/// 2(N-1) phases of `len/N` chunks — the decentralized aggregation of
/// Eq. 1. Call concurrently from every worker thread.
///
/// Perf note (§Perf in EXPERIMENTS.md): message buffers are *recycled* —
/// each received `Vec` becomes the next send buffer, so after the first
/// phase the ring circulates N buffers with zero steady-state
/// allocation (the naive per-phase `to_vec()` version allocated
/// 2(N-1) chunk buffers per call and was ~1.4x slower at 8x1M f32).
pub fn ring_all_reduce(comm: &Communicator, buf: &mut [f32]) {
    let n = comm.size;
    if n == 1 {
        return;
    }
    let len = buf.len();
    let mut scratch: Vec<f32> = Vec::new();

    let mut send_chunk = |comm: &Communicator, scratch: &mut Vec<f32>,
                          src: &[f32]| {
        let mut out = std::mem::take(scratch);
        out.clear();
        out.extend_from_slice(src);
        comm.send_f32(out);
    };

    // Phase 1: reduce-scatter. In step s, send chunk (rank - s) and
    // accumulate received chunk (rank - s - 1).
    for s in 0..n - 1 {
        let send_idx = (comm.rank + n - s) % n;
        let recv_idx = (comm.rank + n - s - 1) % n;
        let (a, b) = chunk_bounds(len, n, send_idx);
        send_chunk(comm, &mut scratch, &buf[a..b]);
        let incoming = comm.recv_f32();
        let (a, b) = chunk_bounds(len, n, recv_idx);
        debug_assert_eq!(incoming.len(), b - a);
        for (dst, src) in buf[a..b].iter_mut().zip(&incoming) {
            *dst += *src;
        }
        scratch = incoming; // recycle for the next send
    }
    // Phase 2: all-gather. In step s, send chunk (rank + 1 - s), receive
    // chunk (rank - s).
    for s in 0..n - 1 {
        let send_idx = (comm.rank + 1 + n - s) % n;
        let recv_idx = (comm.rank + n - s) % n;
        let (a, b) = chunk_bounds(len, n, send_idx);
        send_chunk(comm, &mut scratch, &buf[a..b]);
        let incoming = comm.recv_f32();
        let (a, b) = chunk_bounds(len, n, recv_idx);
        buf[a..b].copy_from_slice(&incoming);
        scratch = incoming;
    }
}

/// The pre-optimization reference implementation (allocates every chunk);
/// kept for the §Perf before/after measurement and as a differential
///-testing oracle for the recycled version.
pub fn ring_all_reduce_naive(comm: &Communicator, buf: &mut [f32]) {
    let n = comm.size;
    if n == 1 {
        return;
    }
    let len = buf.len();
    for s in 0..n - 1 {
        let send_idx = (comm.rank + n - s) % n;
        let recv_idx = (comm.rank + n - s - 1) % n;
        let (a, b) = chunk_bounds(len, n, send_idx);
        comm.send_f32(buf[a..b].to_vec());
        let incoming = comm.recv_f32();
        let (a, b) = chunk_bounds(len, n, recv_idx);
        for (dst, src) in buf[a..b].iter_mut().zip(&incoming) {
            *dst += *src;
        }
    }
    for s in 0..n - 1 {
        let send_idx = (comm.rank + 1 + n - s) % n;
        let recv_idx = (comm.rank + n - s) % n;
        let (a, b) = chunk_bounds(len, n, send_idx);
        comm.send_f32(buf[a..b].to_vec());
        let incoming = comm.recv_f32();
        let (a, b) = chunk_bounds(len, n, recv_idx);
        buf[a..b].copy_from_slice(&incoming);
    }
}

/// All-gather of variable-length f64 payloads: returns every worker's
/// contribution, indexed by rank. Ring-rotated N-1 times.
pub fn all_gather_varlen(comm: &Communicator, mine: Vec<f64>) -> Vec<Vec<f64>> {
    let n = comm.size;
    let mut out: Vec<Vec<f64>> = vec![Vec::new(); n];
    out[comm.rank] = mine;
    let mut cursor = comm.rank;
    for _ in 0..n - 1 {
        comm.send_f64(out[cursor].clone());
        let incoming = comm.recv_f64();
        cursor = (cursor + n - 1) % n;
        out[cursor] = incoming;
    }
    out
}

/// All-reduce of a single scalar (sum) — used for completed-batch counts
/// in the stochastic batch-size weighting (App. B.2.2's "synchronize the
/// computed batch of each worker ... during the AllReduce").
pub fn all_reduce_scalar(comm: &Communicator, x: f64) -> f64 {
    let gathered = all_gather_varlen(comm, vec![x]);
    gathered.iter().map(|v| v[0]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Run `f(rank, comm)` on one thread per ring member; collect results.
    fn run_group<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize, &Communicator) -> T + Send + Sync + 'static,
    {
        let comms = Communicator::ring(n);
        let f = Arc::new(f);
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let f = Arc::clone(&f);
                thread::spawn(move || f(rank, &comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn chunk_bounds_partition() {
        for (len, size) in [(10, 3), (7, 7), (5, 8), (16, 4)] {
            let mut covered = 0;
            for i in 0..size {
                let (a, b) = chunk_bounds(len, size, i);
                assert_eq!(a, covered);
                covered = b;
            }
            assert_eq!(covered, len);
        }
    }

    #[test]
    fn ring_all_reduce_sums() {
        for n in [1usize, 2, 3, 5, 8] {
            let len = 23; // deliberately not divisible by n
            let results = run_group(n, move |rank, comm| {
                let mut buf: Vec<f32> =
                    (0..len).map(|i| (rank * len + i) as f32).collect();
                ring_all_reduce(comm, &mut buf);
                buf
            });
            // expected sum over ranks for each position
            for (rank, buf) in results.iter().enumerate() {
                for (i, &v) in buf.iter().enumerate() {
                    let want: f32 =
                        (0..n).map(|r| (r * len + i) as f32).sum();
                    assert_eq!(v, want, "n={n} rank={rank} i={i}");
                }
            }
        }
    }

    #[test]
    fn recycled_matches_naive_differential() {
        // The optimized (buffer-recycling) implementation must be
        // bit-identical to the naive reference on every topology.
        for n in [2usize, 3, 6] {
            let len = 37;
            let fast = run_group(n, move |rank, comm| {
                let mut buf: Vec<f32> =
                    (0..len).map(|i| ((rank + 2) * (i + 1)) as f32).collect();
                ring_all_reduce(comm, &mut buf);
                buf
            });
            let slow = run_group(n, move |rank, comm| {
                let mut buf: Vec<f32> =
                    (0..len).map(|i| ((rank + 2) * (i + 1)) as f32).collect();
                ring_all_reduce_naive(comm, &mut buf);
                buf
            });
            assert_eq!(fast, slow, "n={n}");
        }
    }

    #[test]
    fn ring_all_reduce_consensus_property() {
        // All workers end with bit-identical buffers (model consensus —
        // the synchronous-training invariant).
        let results = run_group(6, |rank, comm| {
            let mut buf: Vec<f32> =
                (0..100).map(|i| ((rank + 1) * (i + 1)) as f32 * 0.5).collect();
            ring_all_reduce(comm, &mut buf);
            buf
        });
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
    }

    #[test]
    fn all_gather_varlen_collects_everything() {
        let results = run_group(4, |rank, comm| {
            let mine: Vec<f64> = (0..=rank).map(|i| i as f64).collect();
            all_gather_varlen(comm, mine)
        });
        for got in &results {
            for (rank, v) in got.iter().enumerate() {
                let want: Vec<f64> = (0..=rank).map(|i| i as f64).collect();
                assert_eq!(v, &want);
            }
        }
    }

    #[test]
    fn scalar_all_reduce() {
        let results = run_group(5, |rank, comm| {
            all_reduce_scalar(comm, rank as f64 + 1.0)
        });
        for r in results {
            assert_eq!(r, 15.0);
        }
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let results = run_group(4, move |_rank, comm| {
            c2.fetch_add(1, Ordering::SeqCst);
            comm.barrier();
            // after the barrier, all 4 increments must be visible
            c2.load(Ordering::SeqCst)
        });
        for r in results {
            assert_eq!(r, 4);
        }
    }
}
