//! Generic schedule executor: run any [`Schedule`] over the thread mesh.
//!
//! This is the real-execution consumer of the `topology` subsystem: the
//! same phase/transfer object that drives the virtual-time model in
//! [`crate::sim::comm`] is interpreted here over `std::sync::mpsc`
//! channels, one OS thread per worker. Within a phase every worker
//! first ships its outgoing chunk (pre-phase buffer contents), then
//! applies its incoming chunk — the exact discipline of
//! [`super::ring_all_reduce`], which is why executing the ring
//! *schedule* is bitwise-identical to the hand-written ring collective
//! on arbitrary floats, and every other topology is bitwise-identical
//! on integer-valued payloads (where association cannot round).
//!
//! Reduction order is fixed by the schedule (receives apply in phase
//! order, one per phase), giving the bitwise-deterministic aggregation
//! synchronous training requires for reproducibility.

use std::ops::AddAssign;

use crate::topology::{Schedule, TopologyKind, TransferOp};

use super::mesh::MeshComm;

/// Element types the executor can reduce.
pub trait Element: Copy + Send + AddAssign + 'static {}

impl<T: Copy + Send + AddAssign + 'static> Element for T {}

/// Execute an all-reduce `schedule` in place on this worker's `buf`.
/// Call concurrently from every worker thread of the mesh with the same
/// schedule. After the final phase every worker holds the global sum.
pub fn schedule_all_reduce<T: Element>(
    comm: &MeshComm<T>,
    schedule: &Schedule,
    buf: &mut [T],
) {
    debug_assert_eq!(schedule.workers, comm.size, "schedule/mesh size");
    debug_assert!(schedule.validate().is_ok(), "invalid schedule");
    let len = buf.len();
    let rank = comm.rank;
    for phase in &schedule.phases {
        // 1. ship outgoing chunks (at most one per the schedule
        //    invariant) — sends are buffered, so this never blocks.
        for t in &phase.transfers {
            if t.src == rank {
                let (a, b) = t.chunk.bounds(len);
                comm.send(t.dst, buf[a..b].to_vec());
            }
        }
        // 2. apply incoming chunks in schedule order.
        for t in &phase.transfers {
            if t.dst == rank {
                let incoming = comm.recv(t.src);
                let (a, b) = t.chunk.bounds(len);
                debug_assert_eq!(incoming.len(), b - a, "chunk size");
                match t.op {
                    TransferOp::Reduce => {
                        for (dst, src) in
                            buf[a..b].iter_mut().zip(&incoming)
                        {
                            *dst += *src;
                        }
                    }
                    TransferOp::Copy => {
                        buf[a..b].copy_from_slice(&incoming);
                    }
                }
            }
        }
    }
}

/// Convenience: build the schedule for this mesh's size and execute it.
pub fn topology_all_reduce<T: Element>(
    comm: &MeshComm<T>,
    kind: TopologyKind,
    buf: &mut [T],
) {
    let schedule = kind.build(comm.size);
    schedule_all_reduce(comm, &schedule, buf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{ring_all_reduce, Communicator};
    use std::sync::Arc;
    use std::thread;

    fn run_mesh<F, R>(n: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, &MeshComm<f32>) -> R + Send + Sync + 'static,
    {
        let comms = MeshComm::<f32>::full(n);
        let f = Arc::new(f);
        comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let f = Arc::clone(&f);
                thread::spawn(move || f(rank, &comm))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    fn run_ring<F, R>(n: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, &Communicator) -> R + Send + Sync + 'static,
    {
        let comms = Communicator::ring(n);
        let f = Arc::new(f);
        comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let f = Arc::clone(&f);
                thread::spawn(move || f(rank, &comm))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    /// Integer-valued input: exact under any association, so all
    /// topologies must agree to the bit with the ring collective.
    fn int_input(rank: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((rank + 2) * (i + 1)) as f32).collect()
    }

    #[test]
    fn every_topology_matches_ring_collective_bitwise() {
        for kind in TopologyKind::ALL {
            for n in [1usize, 2, 3, 4, 6, 8] {
                let len = 23; // not divisible by tested n > 1
                let want = run_ring(n, move |rank, comm| {
                    let mut buf = int_input(rank, len);
                    ring_all_reduce(comm, &mut buf);
                    buf
                });
                let got = run_mesh(n, move |rank, comm| {
                    let mut buf = int_input(rank, len);
                    topology_all_reduce(comm, kind, &mut buf);
                    buf
                });
                for (rank, (g, w)) in got.iter().zip(&want).enumerate() {
                    let gb: Vec<u32> =
                        g.iter().map(|x| x.to_bits()).collect();
                    let wb: Vec<u32> =
                        w.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(
                        gb, wb,
                        "{} n={n} rank={rank}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn ring_schedule_matches_ring_collective_on_arbitrary_floats() {
        // The ring schedule reproduces ring_all_reduce's association
        // exactly, so agreement is bitwise even on non-integer values.
        for n in [2usize, 3, 5, 8] {
            let len = 37;
            let input = move |rank: usize| -> Vec<f32> {
                (0..len)
                    .map(|i| {
                        0.1f32 * (rank as f32 + 1.3)
                            / (i as f32 + 0.7)
                    })
                    .collect()
            };
            let want = run_ring(n, move |rank, comm| {
                let mut buf = input(rank);
                ring_all_reduce(comm, &mut buf);
                buf
            });
            let got = run_mesh(n, move |rank, comm| {
                let mut buf = input(rank);
                topology_all_reduce(comm, TopologyKind::Ring, &mut buf);
                buf
            });
            for (rank, (g, w)) in got.iter().zip(&want).enumerate() {
                let gb: Vec<u32> = g.iter().map(|x| x.to_bits()).collect();
                let wb: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, wb, "n={n} rank={rank}");
            }
        }
    }

    #[test]
    fn execution_is_deterministic_across_runs() {
        // Same schedule, same inputs, two independent runs: bitwise
        // equal (the synchronous-training reproducibility requirement).
        let run = || {
            run_mesh(6, |rank, comm| {
                let mut buf: Vec<f32> = (0..50)
                    .map(|i| (rank as f32 + 0.5) * (i as f32 + 0.25))
                    .collect();
                topology_all_reduce(
                    comm,
                    TopologyKind::Hierarchical { group: 2 },
                    &mut buf,
                );
                buf
            })
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            let xb: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb);
        }
    }

    #[test]
    fn consensus_on_every_topology() {
        // All workers end with identical buffers under every topology.
        for kind in TopologyKind::ALL {
            let results = run_mesh(9, move |rank, comm| {
                let mut buf: Vec<f32> = (0..40)
                    .map(|i| ((rank + 1) * (i + 1)) as f32)
                    .collect();
                topology_all_reduce(comm, kind, &mut buf);
                buf
            });
            for r in &results[1..] {
                assert_eq!(r, &results[0], "{}", kind.name());
            }
        }
    }
}
