//! Full-mesh communicator + tree / naive all-reduce variants.
//!
//! The paper assumes decentralized *ring* AllReduce (bandwidth-optimal,
//! Patarasuk & Yuan 2009); these alternatives exist for the design-choice
//! ablation in `benches/allreduce_ablation.rs`: a binary-tree
//! reduce+broadcast (latency-optimal, 2·log2 N hops of the full buffer)
//! and the naive all-to-all gather (N× bandwidth) — the trade-offs the
//! paper's §2 discussion takes as given.
//!
//! The mesh is also the substrate of the generic schedule executor
//! ([`super::engine`]): any [`crate::topology::Schedule`] runs over
//! these channels, which is how the `topology` subsystem's schedules
//! get exercised on real threads and not just in virtual time.
//!
//! [`MeshComm`] is generic over the element type (default `f32`) so the
//! same collectives serve f32 gradients and f64 latency statistics.

use std::ops::AddAssign;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Typed communication failure. The historical failure mode this
/// replaces was an *infinite hang*: `mpsc::Receiver::recv` blocks
/// forever while the peer's `Sender` is still alive but the peer thread
/// has stopped participating (e.g. it panicked between collectives with
/// its `MeshComm` still on its stack). Every deadline-aware receive
/// distinguishes the two observable causes so callers can degrade the
/// collective instead of wedging the whole step.
///
/// The same vocabulary is shared by the in-process mesh and the
/// real-socket transport ([`crate::transport`]): `PeerLost` is a
/// disconnect (channel dropped / socket EOF / connection reset),
/// `Timeout` is a deadline expiry with the peer possibly still alive —
/// the distinction the DropComm membership rule needs (a lost peer can
/// never arrive; a timed-out one may show up next step).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommError {
    /// The peer is gone for good: its sending endpoint disconnected
    /// (thread exited/panicked and dropped the channel, or the socket
    /// hit EOF/reset).
    PeerLost { peer: usize },
    /// Nothing arrived from `peer` within `waited`; the peer may still
    /// be alive (slow, stalled, or dropped by its own deadline).
    Timeout { peer: usize, waited: Duration },
}

impl CommError {
    /// The rank this failure implicates.
    pub fn peer(&self) -> usize {
        match self {
            CommError::PeerLost { peer } | CommError::Timeout { peer, .. } => {
                *peer
            }
        }
    }

    /// True when the peer can never deliver (disconnect, not deadline).
    pub fn is_fatal(&self) -> bool {
        matches!(self, CommError::PeerLost { .. })
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::PeerLost { peer } => {
                write!(f, "peer w{peer} lost (disconnected)")
            }
            CommError::Timeout { peer, waited } => write!(
                f,
                "recv from w{peer} timed out after {:.3}s",
                waited.as_secs_f64()
            ),
        }
    }
}

impl std::error::Error for CommError {}

impl From<CommError> for crate::util::Error {
    fn from(e: CommError) -> Self {
        crate::util::Error::Runtime(format!("collective: {e}"))
    }
}

/// Default per-receive deadline for the infallible collective wrappers:
/// long enough that no healthy in-process peer can miss it, short
/// enough that a wedged test run fails loudly instead of hanging CI.
pub const DEFAULT_RECV_DEADLINE: Duration = Duration::from_secs(30);

/// Full-mesh communicator: a channel from every rank to every rank.
pub struct MeshComm<T = f32> {
    pub rank: usize,
    pub size: usize,
    to: Vec<Sender<Vec<T>>>,
    from: Vec<Receiver<Vec<T>>>,
}

impl<T: Send + 'static> MeshComm<T> {
    /// Create `n` fully-connected communicators.
    pub fn full(n: usize) -> Vec<MeshComm<T>> {
        assert!(n > 0);
        // txs[dst][src] sends to dst's receiver for messages from src.
        let mut txs: Vec<Vec<Option<Sender<Vec<T>>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Receiver<Vec<T>>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for dst in 0..n {
            for src in 0..n {
                let (tx, rx) = channel();
                txs[dst][src] = Some(tx);
                rxs[dst][src] = Some(rx);
            }
        }
        (0..n)
            .map(|rank| MeshComm {
                rank,
                size: n,
                to: (0..n)
                    .map(|dst| txs[dst][rank].take().unwrap())
                    .collect(),
                from: rxs[rank]
                    .iter_mut()
                    .map(|r| r.take().unwrap())
                    .collect(),
            })
            .collect()
    }

    pub fn send(&self, dst: usize, data: Vec<T>) {
        self.to[dst].send(data).expect("mesh send");
    }

    /// Fallible send: a disconnected destination (its thread exited and
    /// dropped the receiving ends) surfaces as [`CommError::PeerLost`]
    /// instead of a panic.
    pub fn try_send(&self, dst: usize, data: Vec<T>) -> Result<(), CommError> {
        self.to[dst]
            .send(data)
            .map_err(|_| CommError::PeerLost { peer: dst })
    }

    pub fn recv(&self, src: usize) -> Vec<T> {
        self.from[src].recv().expect("mesh recv")
    }

    /// Receive from `src` with a deadline. Returns
    /// [`CommError::PeerLost`] when `src`'s sending endpoint is gone
    /// (its thread panicked or exited) and [`CommError::Timeout`] when
    /// the deadline elapses with the peer still connected. This is the
    /// hang-proof receive every deadline-aware collective routes
    /// through.
    pub fn recv_deadline(
        &self,
        src: usize,
        timeout: Duration,
    ) -> Result<Vec<T>, CommError> {
        match self.from[src].recv_timeout(timeout) {
            Ok(v) => Ok(v),
            Err(RecvTimeoutError::Disconnected) => {
                Err(CommError::PeerLost { peer: src })
            }
            Err(RecvTimeoutError::Timeout) => {
                Err(CommError::Timeout { peer: src, waited: timeout })
            }
        }
    }
}

/// Binary-tree all-reduce (sum): reduce to rank 0 up the tree, then
/// broadcast down. 2·ceil(log2 N) hops of the full buffer. Association
/// matches `topology::BinaryTree`'s schedule, so both paths produce
/// bitwise-identical results.
///
/// Routed through [`MeshComm::recv_deadline`] with
/// [`DEFAULT_RECV_DEADLINE`]: a dead peer aborts the collective with a
/// panic that names the lost rank instead of hanging the thread.
pub fn tree_all_reduce<T>(comm: &MeshComm<T>, buf: &mut [T])
where
    T: Copy + AddAssign + Send + 'static,
{
    try_tree_all_reduce(comm, buf, DEFAULT_RECV_DEADLINE)
        .unwrap_or_else(|e| panic!("tree all-reduce: {e}"));
}

/// Deadline-aware binary-tree all-reduce: every receive is bounded by
/// `deadline`, so a peer that died (or stalls past the deadline) turns
/// into a typed [`CommError`] the caller can use to degrade the
/// collective instead of hanging forever.
pub fn try_tree_all_reduce<T>(
    comm: &MeshComm<T>,
    buf: &mut [T],
    deadline: Duration,
) -> Result<(), CommError>
where
    T: Copy + AddAssign + Send + 'static,
{
    let n = comm.size;
    let rank = comm.rank;
    // Reduce phase: in round r (stride 2^r), ranks with bit set send to
    // rank - stride; receivers accumulate.
    let mut stride = 1;
    while stride < n {
        if rank & stride != 0 {
            // sender: ship the buffer up and exit the reduce phase
            comm.try_send(rank - stride, buf.to_vec())?;
            break;
        } else if rank + stride < n {
            let incoming = comm.recv_deadline(rank + stride, deadline)?;
            for (dst, src) in buf.iter_mut().zip(&incoming) {
                *dst += *src;
            }
        }
        stride <<= 1;
    }
    // Broadcast phase: mirror image, top-down.
    let mut stride = usize::next_power_of_two(n) >> 1;
    while stride >= 1 {
        if rank & (stride - 1) == 0 {
            if rank & stride != 0 {
                let incoming = comm.recv_deadline(rank - stride, deadline)?;
                buf.copy_from_slice(&incoming);
            } else if rank + stride < n {
                comm.try_send(rank + stride, buf.to_vec())?;
            }
        }
        stride >>= 1;
    }
    Ok(())
}

/// Naive all-reduce: every worker sends its full buffer to every other
/// worker (N-1 full-buffer sends per worker). Accumulation in rank
/// order, so the result is deterministic (and exact for integer-valued
/// payloads regardless of association).
///
/// Routed through [`MeshComm::recv_deadline`] like [`tree_all_reduce`].
pub fn naive_all_reduce<T>(comm: &MeshComm<T>, buf: &mut [T])
where
    T: Copy + AddAssign + Send + 'static,
{
    try_naive_all_reduce(comm, buf, DEFAULT_RECV_DEADLINE)
        .unwrap_or_else(|e| panic!("naive all-reduce: {e}"));
}

/// Deadline-aware naive all-reduce (see [`try_tree_all_reduce`]).
pub fn try_naive_all_reduce<T>(
    comm: &MeshComm<T>,
    buf: &mut [T],
    deadline: Duration,
) -> Result<(), CommError>
where
    T: Copy + AddAssign + Send + 'static,
{
    let n = comm.size;
    for dst in 0..n {
        if dst != comm.rank {
            comm.try_send(dst, buf.to_vec())?;
        }
    }
    for src in 0..n {
        if src != comm.rank {
            let incoming = comm.recv_deadline(src, deadline)?;
            for (dst, s) in buf.iter_mut().zip(&incoming) {
                *dst += *s;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn run_mesh<T, R, F>(n: usize, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, &MeshComm<T>) -> R + Send + Sync + 'static,
    {
        let comms = MeshComm::<T>::full(n);
        let f = Arc::new(f);
        comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let f = Arc::clone(&f);
                thread::spawn(move || f(rank, &comm))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    fn expected(n: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
            .collect()
    }

    #[test]
    fn tree_all_reduce_sums_all_sizes() {
        // powers of two and odd sizes
        for n in [1usize, 2, 3, 5, 8, 13] {
            let len = 17;
            let results = run_mesh(n, move |rank, comm: &MeshComm| {
                let mut buf: Vec<f32> =
                    (0..len).map(|i| (rank * len + i) as f32).collect();
                tree_all_reduce(comm, &mut buf);
                buf
            });
            let want = expected(n, len);
            for (rank, got) in results.iter().enumerate() {
                assert_eq!(got, &want, "tree n={n} rank={rank}");
            }
        }
    }

    #[test]
    fn naive_all_reduce_sums() {
        for n in [1usize, 2, 4, 7] {
            let len = 9;
            let results = run_mesh(n, move |rank, comm: &MeshComm| {
                let mut buf: Vec<f32> =
                    (0..len).map(|i| (rank * len + i) as f32).collect();
                naive_all_reduce(comm, &mut buf);
                buf
            });
            let want = expected(n, len);
            for got in &results {
                assert_eq!(got, &want, "naive n={n}");
            }
        }
    }

    #[test]
    fn variants_agree_with_ring_differential() {
        // tree == naive == ring on identical inputs (consensus + sums).
        let n = 6;
        let len = 23;
        let tree = run_mesh(n, move |rank, comm: &MeshComm| {
            let mut buf: Vec<f32> =
                (0..len).map(|i| ((rank + 1) * (i + 3)) as f32).collect();
            tree_all_reduce(comm, &mut buf);
            buf
        });
        let naive = run_mesh(n, move |rank, comm: &MeshComm| {
            let mut buf: Vec<f32> =
                (0..len).map(|i| ((rank + 1) * (i + 3)) as f32).collect();
            naive_all_reduce(comm, &mut buf);
            buf
        });
        assert_eq!(tree, naive);
    }

    #[test]
    fn tree_vs_naive_bitwise_f32_n1_to_8() {
        // Integer-valued f32 payloads: every association is exact, so
        // tree and naive must agree to the bit at every N (including
        // non-powers of two) and on every rank.
        for n in 1usize..=8 {
            let len = 29; // not divisible by any tested n > 1
            let tree = run_mesh(n, move |rank, comm: &MeshComm| {
                let mut buf: Vec<f32> = (0..len)
                    .map(|i| ((rank + 1) * (i + 2)) as f32)
                    .collect();
                tree_all_reduce(comm, &mut buf);
                buf
            });
            let naive = run_mesh(n, move |rank, comm: &MeshComm| {
                let mut buf: Vec<f32> = (0..len)
                    .map(|i| ((rank + 1) * (i + 2)) as f32)
                    .collect();
                naive_all_reduce(comm, &mut buf);
                buf
            });
            for (rank, (a, b)) in tree.iter().zip(&naive).enumerate() {
                let a_bits: Vec<u32> =
                    a.iter().map(|x| x.to_bits()).collect();
                let b_bits: Vec<u32> =
                    b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a_bits, b_bits, "f32 n={n} rank={rank}");
            }
        }
    }

    #[test]
    fn dead_peer_fails_typed_instead_of_hanging() {
        // Regression: a peer that exits before the collective (dropping
        // its MeshComm, as a panicking thread would) used to hang every
        // survivor forever inside `recv`. With deadline routing the
        // survivors must all come back with a typed CommError, fast.
        let n = 4;
        let deadline = Duration::from_millis(250);
        let comms = MeshComm::<f32>::full(n);
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                thread::spawn(move || {
                    if rank == 1 {
                        // dies before participating; MeshComm drops here
                        return Ok(());
                    }
                    let mut buf = vec![(rank + 1) as f32; 8];
                    try_tree_all_reduce(&comm, &mut buf, deadline)
                })
            })
            .collect();
        let sw = crate::util::Stopwatch::start();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(results[0].is_err(), "rank 0 depends on the dead peer");
        for (rank, r) in results.iter().enumerate().skip(2) {
            assert!(r.is_err(), "rank {rank} must not silently succeed");
        }
        // every survivor unwound within a couple of deadlines, not ∞
        assert!(sw.seconds() < 5.0, "survivors must not hang");
    }

    #[test]
    fn stalled_peer_times_out_with_peer_id() {
        // A peer that is alive (its channels stay open) but never sends
        // is a Timeout, not a PeerLost — and the error names the rank
        // the membership rule should exclude.
        let n = 2;
        let comms = MeshComm::<f32>::full(n);
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                thread::spawn(move || {
                    if rank == 1 {
                        // stall well past the peer's deadline with the
                        // comm alive, then exit without sending
                        thread::sleep(Duration::from_millis(400));
                        drop(comm);
                        return None;
                    }
                    let mut buf = vec![1.0f32; 4];
                    Some(try_naive_all_reduce(
                        &comm,
                        &mut buf,
                        Duration::from_millis(50),
                    ))
                })
            })
            .collect();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        match results[0] {
            Some(Err(CommError::Timeout { peer, .. })) => assert_eq!(peer, 1),
            ref other => panic!("want Timeout from w1, got {other:?}"),
        }
    }

    #[test]
    fn tree_vs_naive_bitwise_f64_n1_to_8() {
        // Same agreement over the f64 instantiation of the generic mesh
        // (used by the latency-statistics collectives).
        for n in 1usize..=8 {
            let len = 31;
            let tree = run_mesh(n, move |rank, comm: &MeshComm<f64>| {
                let mut buf: Vec<f64> = (0..len)
                    .map(|i| ((rank + 3) * (i + 1)) as f64)
                    .collect();
                tree_all_reduce(comm, &mut buf);
                buf
            });
            let naive = run_mesh(n, move |rank, comm: &MeshComm<f64>| {
                let mut buf: Vec<f64> = (0..len)
                    .map(|i| ((rank + 3) * (i + 1)) as f64)
                    .collect();
                naive_all_reduce(comm, &mut buf);
                buf
            });
            for (rank, (a, b)) in tree.iter().zip(&naive).enumerate() {
                let a_bits: Vec<u64> =
                    a.iter().map(|x| x.to_bits()).collect();
                let b_bits: Vec<u64> =
                    b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a_bits, b_bits, "f64 n={n} rank={rank}");
            }
        }
    }
}
