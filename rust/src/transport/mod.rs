//! Real-socket collective transport — the sim-to-real bridge.
//!
//! Everything else in this crate *models* the cluster: the simulator
//! draws compute times, the mpsc [`MeshComm`](crate::collective::MeshComm)
//! executes [`topology::Schedule`](crate::topology::Schedule) plans
//! between threads of one process. This module executes the *same*
//! schedules over real sockets — Unix-domain by default, TCP optional —
//! hardened for a hostile network:
//!
//! * **Deadlines.** Every receive is bounded. Phase-0 arrival
//!   collection is driven by the installed [`DropPolicy`]'s comm
//!   cutoff, so late peers are *excluded*, exactly like the paper's
//!   DropCompute rule, and the survivor subset reduces as a k-member
//!   collective over a freshly built k-worker schedule.
//! * **Retries.** Connect and send go through bounded retry with
//!   exponential backoff and deterministic jitter ([`RetryPolicy`]).
//! * **Typed degradation.** Peer death surfaces as
//!   [`CommError::PeerLost`](crate::collective::CommError); deadline
//!   expiry as [`CommError::Timeout`](crate::collective::CommError).
//!   A collective never hangs: it completes over the live sub-group or
//!   fails typed.
//! * **Fault injection.** A [`FaultPlan`](crate::sim::FaultPlan) drives
//!   a real [`Injector`]: killed workers' threads exit and drop their
//!   sockets mid-run; slowed workers stretch their (real, slept)
//!   compute.
//! * **Trace capture.** Each worker records wall-clock per-micro-batch
//!   compute durations; the run assembles a v2
//!   [`TraceRecord`](crate::sim::TraceRecord) (with transport meta)
//!   that replays bitwise through the simulator on both timing paths
//!   and feeds `budget_fit`. A [`ConformanceReport`] compares the
//!   sim-predicted completion ordering against measured wall clocks.
//!
//! Module map: [`wire`] (frame format), [`peer`] (socket mesh),
//! [`executor`] (schedule execution over survivor subsets),
//! [`injector`] (plan-driven fault behavior), [`run`] (loopback
//! harness + conformance gates).
//!
//! [`DropPolicy`]: crate::policy::DropPolicy

use std::time::Duration;

use crate::rng::SplitMix64;
use crate::util::{Error, Result};

pub mod executor;
pub mod injector;
pub mod peer;
pub mod run;
pub mod wire;

pub use executor::{subgroup_all_reduce, transport_all_reduce};
pub use injector::Injector;
pub use peer::{bind_mesh, Endpoint, MeshBinding, SocketMesh};
pub use run::{
    replay_bitwise, run_loopback, ConformanceReport, RunReport, RunSpec,
    StepSummary,
};
pub use wire::{Frame, FrameTag, Wire};

/// Which socket family carries the collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Unix-domain sockets under a run directory (loopback default).
    Uds,
    /// TCP over 127.0.0.1 with OS-assigned ports.
    Tcp,
}

impl TransportKind {
    pub const ALL: [TransportKind; 2] = [TransportKind::Uds, TransportKind::Tcp];

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Uds => "uds",
            TransportKind::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "uds" | "unix" => Ok(TransportKind::Uds),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(Error::Config(format!(
                "transport: unknown kind `{other}` (want uds|tcp)"
            ))),
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Bounded retry with exponential backoff and multiplicative jitter.
///
/// Attempt `a` (0-based) sleeps `min(base·2^a, max) · (1 − jitter·u)`
/// with `u ∈ [0, 1)` drawn from a seeded [`SplitMix64`] — deterministic
/// per rank, so two runs with the same seed back off identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts before giving up (≥ 1).
    pub attempts: u32,
    /// First backoff delay.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_max: Duration,
    /// Jitter fraction in `[0, 1)`: how much of the delay may be shaved.
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 5,
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(250),
            jitter: 0.2,
        }
    }
}

impl RetryPolicy {
    /// The delay before retry number `attempt` (0-based).
    pub fn delay(&self, attempt: u32, rng: &mut SplitMix64) -> Duration {
        let exp = self.backoff_base.as_secs_f64()
            * 2f64.powi(attempt.min(20) as i32);
        let capped = exp.min(self.backoff_max.as_secs_f64());
        // 53 high bits → uniform in [0, 1)
        let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        Duration::from_secs_f64(capped * (1.0 - self.jitter * u))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_round_trips() {
        for k in TransportKind::ALL {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(TransportKind::parse("unix").unwrap(), TransportKind::Uds);
        assert!(matches!(
            TransportKind::parse("carrier-pigeon"),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let p = RetryPolicy {
            attempts: 8,
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_millis(100),
            jitter: 0.5,
        };
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for attempt in 0..8 {
            let da = p.delay(attempt, &mut a);
            let db = p.delay(attempt, &mut b);
            assert_eq!(da, db, "same seed, same delays");
            let nominal = (0.010 * 2f64.powi(attempt as i32)).min(0.100);
            let secs = da.as_secs_f64();
            assert!(secs <= nominal + 1e-12, "attempt {attempt}: {secs}");
            assert!(secs >= nominal * 0.5 - 1e-12, "attempt {attempt}: {secs}");
        }
        // attempt 4 onward is capped at the ceiling
        let capped = p.delay(6, &mut a).as_secs_f64();
        assert!(capped <= 0.100 + 1e-12);
        // zero jitter is exact
        let exact = RetryPolicy { jitter: 0.0, ..p };
        assert_eq!(
            exact.delay(1, &mut a),
            Duration::from_secs_f64(0.020)
        );
    }
}
